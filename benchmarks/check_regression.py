"""Benchmark regression gate: compare a fresh BENCH_protocol.json against
the committed baseline and fail on a steady-state slowdown of the compiled
path.

    python -m benchmarks.check_regression \
        --fresh BENCH_protocol.json \
        --baseline benchmarks/baselines/BENCH_protocol_fast.json

A real engine regression (lost jit cache, accidental host sync, eager
fallback) degrades BOTH signals below; a slower CI machine degrades only
the first. The gate therefore fails only when both regress by more than
``--factor`` (default 2x):

  1. wall-clock: fresh compiled_steady_s vs baseline (same-machine noise +
     cross-machine speed differences land here);
  2. normalized: speedup_steady = eager / compiled measured on the SAME
     machine in the same run, so hardware cancels out.

Both signals are only meaningful when the fresh run used the same
benchmark setting as the baseline; a setting mismatch fails the gate
outright (regenerate the committed baseline alongside any setting change).
"""
from __future__ import annotations

import argparse
import json
import sys

#: setting keys that must match for wall-clock times to be comparable
_SETTING_KEYS = ("problem", "m", "n", "p", "eps", "reps")


def compare(fresh: dict, baseline: dict, factor: float = 2.0) -> list:
    """Return a list of failure messages (empty = gate passes)."""
    fs, bs = fresh["setting"], baseline["setting"]
    comparable = all(fs.get(k) == bs.get(k) for k in _SETTING_KEYS)

    wall_ratio = fresh["compiled_steady_s"] / baseline["compiled_steady_s"]
    speed_ratio = baseline["speedup_steady"] / fresh["speedup_steady"]
    print(f"settings comparable: {comparable} "
          f"({ {k: fs.get(k) for k in _SETTING_KEYS} })")
    print(f"compiled steady-state: fresh {fresh['compiled_steady_s']:.4f}s "
          f"vs baseline {baseline['compiled_steady_s']:.4f}s "
          f"({wall_ratio:.2f}x)")
    print(f"eager->compiled speedup: fresh {fresh['speedup_steady']:.1f}x "
          f"vs baseline {baseline['speedup_steady']:.1f}x "
          f"(regression {speed_ratio:.2f}x)")

    failures = []
    if comparable and wall_ratio > factor and speed_ratio > factor:
        failures.append(
            f"compiled path regressed: steady-state wall-clock {wall_ratio:.2f}x "
            f"slower AND same-machine speedup collapsed {speed_ratio:.2f}x "
            f"(threshold {factor}x)")
    if not comparable:
        # Both signals are setting-dependent (the eager/compiled ratio grows
        # with problem size), so a cross-setting comparison would misfire —
        # and silently skipping it would turn the gate into a no-op forever.
        # Fail loudly: whoever changed the benchmark setting must regenerate
        # the committed baseline in the same commit.
        failures.append(
            "benchmark settings differ from the committed baseline, so the "
            "ratio gates cannot run; regenerate it via "
            "`python -m benchmarks.bench_protocol --fast && "
            "cp BENCH_protocol.json benchmarks/baselines/"
            "BENCH_protocol_fast.json` (then `git checkout "
            "BENCH_protocol.json`)")
    if not fresh.get("ok", False):
        failures.append("fresh benchmark reported ok=false "
                        "(compiled steady-state < 3x eager)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", default="BENCH_protocol.json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/BENCH_protocol_fast.json")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="max tolerated slowdown (default 2x)")
    args = ap.parse_args(argv)
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = compare(fresh, baseline, factor=args.factor)
    for msg in failures:
        print(f"REGRESSION: {msg}", file=sys.stderr)
    print("PASS" if not failures else "FAIL")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
