"""Benchmark regression gates: compare fresh BENCH_protocol.json /
BENCH_agg.json / BENCH_attacks.json / BENCH_train.json /
BENCH_serve.json records against the committed baselines and fail on a
steady-state slowdown of a compiled hot path.

    python -m benchmarks.check_regression \
        --fresh BENCH_protocol.json \
        --baseline benchmarks/baselines/BENCH_protocol_fast.json \
        --fresh-agg BENCH_agg.json \
        --baseline-agg benchmarks/baselines/BENCH_agg_fast.json \
        --fresh-attacks BENCH_attacks.json \
        --baseline-attacks benchmarks/baselines/BENCH_attacks_fast.json

A real engine regression (lost jit cache, accidental host sync, eager
fallback, a de-batched aggregation path) degrades BOTH signals below; a
slower CI machine degrades only the first. Each gate therefore fails only
when both regress by more than ``--factor`` (default 2x):

  1. wall-clock: fresh steady-state seconds vs baseline (same-machine
     noise + cross-machine speed differences land here);
  2. normalized: the speedup over the in-run reference (eager protocol /
     per-scenario sorted loop) measured on the SAME machine in the same
     run, so hardware cancels out.

Both signals are only meaningful when the fresh run used the same
benchmark setting as the baseline; a setting mismatch fails the gate
outright (regenerate the committed baseline alongside any setting change).
"""
from __future__ import annotations

import argparse
import json
import sys


def _two_signal_gate(fresh: dict, baseline: dict, factor: float, *,
                     setting_keys, wall_key: str, speedup_key: str,
                     label: str, speedup_label: str, ok_msg: str,
                     regen_cmd: str) -> list:
    """The shared gate: fail only when the wall-clock AND the in-run
    normalized speedup both regress past ``factor``; a setting mismatch
    or a fresh ``ok=false`` fails outright."""
    fs, bs = fresh["setting"], baseline["setting"]
    comparable = all(fs.get(k) == bs.get(k) for k in setting_keys)

    wall_ratio = fresh[wall_key] / baseline[wall_key]
    speed_ratio = baseline[speedup_key] / fresh[speedup_key]
    print(f"{label} settings comparable: {comparable} "
          f"({ {k: fs.get(k) for k in setting_keys} })")
    print(f"{label} steady-state: fresh {fresh[wall_key]:.4f}s vs baseline "
          f"{baseline[wall_key]:.4f}s ({wall_ratio:.2f}x)")
    print(f"{speedup_label}: fresh {fresh[speedup_key]:.1f}x vs baseline "
          f"{baseline[speedup_key]:.1f}x (regression {speed_ratio:.2f}x)")

    failures = []
    if comparable and wall_ratio > factor and speed_ratio > factor:
        failures.append(
            f"{label} regressed: steady-state wall-clock {wall_ratio:.2f}x "
            f"slower AND same-machine speedup collapsed {speed_ratio:.2f}x "
            f"(threshold {factor}x)")
    if not comparable:
        # Both signals are setting-dependent (the speedup ratio grows with
        # problem size), so a cross-setting comparison would misfire — and
        # silently skipping it would turn the gate into a no-op forever.
        # Fail loudly: whoever changed the benchmark setting must
        # regenerate the committed baseline in the same commit.
        failures.append(
            f"{label} benchmark settings differ from the committed "
            f"baseline, so the ratio gates cannot run; regenerate it via "
            f"`{regen_cmd}`")
    if not fresh.get("ok", False):
        failures.append(f"fresh {label} benchmark reported ok=false "
                        f"({ok_msg})")
    return failures


def compare(fresh: dict, baseline: dict, factor: float = 2.0) -> list:
    """Gate for the compiled-protocol record (BENCH_protocol.json).
    Returns a list of failure messages (empty = gate passes)."""
    return _two_signal_gate(
        fresh, baseline, factor,
        setting_keys=("problem", "m", "n", "p", "eps", "reps"),
        wall_key="compiled_steady_s", speedup_key="speedup_steady",
        label="compiled protocol",
        speedup_label="eager->compiled speedup",
        ok_msg="compiled steady-state < 3x eager",
        regen_cmd="python -m benchmarks.bench_protocol --fast && "
                  "cp BENCH_protocol.json benchmarks/baselines/"
                  "BENCH_protocol_fast.json (then git checkout "
                  "BENCH_protocol.json)")


AGG_REGEN_CMD = ("python -m benchmarks.kernel_bench --fast && "
                 "cp BENCH_agg.json benchmarks/baselines/"
                 "BENCH_agg_fast.json (then git checkout BENCH_agg.json)")

#: max tolerated auto-dispatch overhead over the best measured backend at
#: any shape bucket (the in-run dispatch-quality gate, machine-independent)
AGG_AUTO_SLACK = 1.2


def compare_agg(fresh: dict, baseline: dict, factor: float = 2.0) -> list:
    """Gate for the batched-aggregation record (BENCH_agg.json schema v2,
    kernel_bench.bench_batched_agg). Shape-aware: per bucket (sweep /
    mid / large), the auto path (``backend=None`` through the measured
    dispatch table) must sit within ``AGG_AUTO_SLACK`` of the best
    measured backend IN THE SAME RUN — a stale or wrong dispatch table
    fails regardless of machine speed. The cross-run two-signal gate
    (wall-clock AND same-machine speedup vs the per-scenario sorted
    loop) runs on the sweep bucket, where the loop reference exists."""
    failures = []
    if fresh.get("schema") != 2 or baseline.get("schema") != 2:
        return [f"BENCH_agg schema mismatch (fresh "
                f"{fresh.get('schema')!r}, baseline "
                f"{baseline.get('schema')!r}; need v2); regenerate via "
                f"`{AGG_REGEN_CMD}`"]
    fb, bb = fresh.get("buckets", {}), baseline.get("buckets", {})
    if set(fb) != set(bb):
        failures.append(
            f"BENCH_agg bucket sets differ (fresh {sorted(fb)}, baseline "
            f"{sorted(bb)}); regenerate via `{AGG_REGEN_CMD}`")
    for name in sorted(set(fb) & set(bb)):
        fr, br = fb[name], bb[name]
        shape_f = tuple(fr.get(k) for k in ("B", "m", "p"))
        shape_b = tuple(br.get(k) for k in ("B", "m", "p"))
        if shape_f != shape_b:
            failures.append(
                f"agg bucket [{name}] shape differs from baseline "
                f"({shape_f} vs {shape_b}); regenerate via "
                f"`{AGG_REGEN_CMD}`")
            continue
        ratio = fr.get("auto_vs_best")
        print(f"agg [{name}] B={fr['B']} m={fr['m']} p={fr['p']}: "
              f"auto->{fr.get('auto_backend')} auto/best={ratio:.2f}x "
              f"(slack {AGG_AUTO_SLACK}x)")
        if ratio is None or ratio > AGG_AUTO_SLACK:
            failures.append(
                f"agg bucket [{name}]: auto dispatch ran {ratio:.2f}x "
                f"slower than the best measured backend (> "
                f"{AGG_AUTO_SLACK}x); the dispatch table is stale — "
                "re-tune with repro-agg-tune")
    sweep_f, sweep_b = fb.get("sweep"), bb.get("sweep")
    if sweep_f and sweep_b and "speedup_auto_vs_loop" in sweep_f \
            and "speedup_auto_vs_loop" in sweep_b:
        wall = {"setting": dict(sweep_f, **fresh["setting"]),
                "wall_s": sweep_f["backends_s"]["auto"],
                "speedup": sweep_f["speedup_auto_vs_loop"],
                "ok": fresh.get("ok", False)}
        base = {"setting": dict(sweep_b, **baseline["setting"]),
                "wall_s": sweep_b["backends_s"]["auto"],
                "speedup": sweep_b["speedup_auto_vs_loop"]}
        failures += _two_signal_gate(
            wall, base, factor,
            setting_keys=("B", "m", "p", "K", "reps", "method"),
            wall_key="wall_s", speedup_key="speedup",
            label="batched aggregation (sweep bucket)",
            speedup_label="auto speedup vs per-scenario sorted loop",
            ok_msg="auto dispatch slower than the best measured backend "
                   "at some shape bucket",
            regen_cmd=AGG_REGEN_CMD)
    return failures


def compare_attacks(fresh: dict, baseline: dict,
                    factor: float = 2.0) -> list:
    """Gate for the attack-sensitivity sweep record (BENCH_attacks.json,
    benchmarks/attack_sweep.py): steady-state sweep wall time and its
    same-machine compile-amortization ratio; ``ok=false`` (a jit group
    traced more than once across the two passes) fails outright."""
    return _two_signal_gate(
        fresh, baseline, factor,
        setting_keys=("preset", "fast", "n_scenarios", "n_groups",
                      "m", "n", "p", "reps"),
        wall_key="sweep_steady_s", speedup_key="speedup_steady",
        label="attack sweep",
        speedup_label="cold->steady compile amortization",
        ok_msg="a jit group retraced: one trace per (attack, aggregator) "
               "violated",
        regen_cmd="python -m benchmarks.attack_sweep --fast && "
                  "cp BENCH_attacks.json benchmarks/baselines/"
                  "BENCH_attacks_fast.json (then git checkout "
                  "BENCH_attacks.json)")


def compare_train(fresh: dict, baseline: dict,
                  factor: float = 2.0) -> list:
    """Gate for the quasi-Newton train-step record (BENCH_train.json,
    benchmarks/train_bench.py): steady-state protocol-step wall time and
    its same-machine cold->steady compile amortization; ``ok=false`` (the
    train step traced more than once) fails outright."""
    return _two_signal_gate(
        fresh, baseline, factor,
        setting_keys=("arch", "machines", "steps", "batch", "seq",
                      "hist", "agg"),
        wall_key="step_steady_s", speedup_key="speedup_steady",
        label="qn train step",
        speedup_label="cold->steady compile amortization",
        ok_msg="the protocol train step retraced: compile-once violated",
        regen_cmd="python -m benchmarks.train_bench --fast && "
                  "cp BENCH_train.json benchmarks/baselines/"
                  "BENCH_train_fast.json (then git checkout "
                  "BENCH_train.json)")


def compare_serve(fresh: dict, baseline: dict,
                  factor: float = 2.0) -> list:
    """Gate for the streaming-service record (BENCH_serve.json,
    benchmarks/serve_bench.py): steady-state round wall time at the
    largest fleet and its same-machine cold->steady compile
    amortization; ``ok=false`` (a service step or buffer writer traced
    more than once across a multi-flush run) fails outright."""
    return _two_signal_gate(
        fresh, baseline, factor,
        setting_keys=("fleets", "p", "rounds", "agg", "eps",
                      "ingest_block"),
        wall_key="serve_steady_s", speedup_key="speedup_steady",
        label="streaming serve",
        speedup_label="cold->steady compile amortization",
        ok_msg="the serving step retraced: compile-once violated",
        regen_cmd="python -m benchmarks.serve_bench --fast && "
                  "cp BENCH_serve.json benchmarks/baselines/"
                  "BENCH_serve_fast.json (then git checkout "
                  "BENCH_serve.json)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", default="BENCH_protocol.json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/BENCH_protocol_fast.json")
    ap.add_argument("--fresh-agg", default=None,
                    help="fresh BENCH_agg.json (omit to skip the agg gate)")
    ap.add_argument("--baseline-agg",
                    default="benchmarks/baselines/BENCH_agg_fast.json")
    ap.add_argument("--fresh-attacks", default=None,
                    help="fresh BENCH_attacks.json (omit to skip the "
                         "attack-sweep gate)")
    ap.add_argument("--baseline-attacks",
                    default="benchmarks/baselines/BENCH_attacks_fast.json")
    ap.add_argument("--fresh-train", default=None,
                    help="fresh BENCH_train.json (omit to skip the "
                         "train-step gate)")
    ap.add_argument("--baseline-train",
                    default="benchmarks/baselines/BENCH_train_fast.json")
    ap.add_argument("--fresh-serve", default=None,
                    help="fresh BENCH_serve.json (omit to skip the "
                         "streaming-serve gate)")
    ap.add_argument("--baseline-serve",
                    default="benchmarks/baselines/BENCH_serve_fast.json")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="max tolerated slowdown (default 2x)")
    args = ap.parse_args(argv)
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = compare(fresh, baseline, factor=args.factor)
    if args.fresh_agg:
        with open(args.fresh_agg) as f:
            fresh_agg = json.load(f)
        with open(args.baseline_agg) as f:
            baseline_agg = json.load(f)
        failures += compare_agg(fresh_agg, baseline_agg,
                                factor=args.factor)
    if args.fresh_attacks:
        with open(args.fresh_attacks) as f:
            fresh_attacks = json.load(f)
        with open(args.baseline_attacks) as f:
            baseline_attacks = json.load(f)
        failures += compare_attacks(fresh_attacks, baseline_attacks,
                                    factor=args.factor)
    if args.fresh_train:
        with open(args.fresh_train) as f:
            fresh_train = json.load(f)
        with open(args.baseline_train) as f:
            baseline_train = json.load(f)
        failures += compare_train(fresh_train, baseline_train,
                                  factor=args.factor)
    if args.fresh_serve:
        with open(args.fresh_serve) as f:
            fresh_serve = json.load(f)
        with open(args.baseline_serve) as f:
            baseline_serve = json.load(f)
        failures += compare_serve(fresh_serve, baseline_serve,
                                  factor=args.factor)
    for msg in failures:
        print(f"REGRESSION: {msg}", file=sys.stderr)
    print("PASS" if not failures else "FAIL")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
