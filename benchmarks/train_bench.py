"""Quasi-Newton train-step throughput: cold (compile) vs steady state.

One protocol train step (core.protocol.protocol_tree_rounds via
train/trainer.make_qn_train_step) is the model-zoo hot path: five DP
transmissions over the parameter pytree per optimizer step. This
benchmark measures the first call (including compilation) and the
steady-state mean, and asserts the compile-once contract — the step must
trace exactly once no matter how many steps run.

Writes BENCH_train.json at the repo root:

    PYTHONPATH=src python -m benchmarks.train_bench --fast

The nightly pipeline compares the record against the committed
benchmarks/baselines/BENCH_train_fast.json via check_regression.py
(fourth gate): wall-clock AND the same-machine cold->steady
amortization ratio must both regress >2x to fail, so machine speed
cancels out.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import TreeProtocolConfig
from repro.core.keys import stream_key
from repro.data.lm import make_batch
from repro.models.model import Model
from repro.train.trainer import QNTrainConfig, make_qn_train_step

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(_REPO_ROOT, "BENCH_train.json")


def measure(arch: str = "xlstm-125m", steps: int = 4, batch: int = 8,
            seq: int = 16, machines: int = 4, hist: int = 5,
            agg: str = "dcq_mad", seed: int = 0) -> dict:
    cfg = get_config(arch, reduced=True)
    model = Model(cfg, remat=True)
    params = model.init(jax.random.PRNGKey(seed))
    qcfg = QNTrainConfig(
        n_machines=machines, attack="signflip",
        protocol=TreeProtocolConfig(hist=hist, lr=0.3, aggregator=agg))
    traces = {"n": 0}
    raw_step = make_qn_train_step(model, qcfg)

    def counted(params, mem, batch, key, byz_mask):
        traces["n"] += 1
        return raw_step(params, mem, batch, key, byz_mask)

    step_fn = jax.jit(counted)
    from repro.core.bfgs import LBFGSMemory
    mem = LBFGSMemory.init_like(hist, params, machines=machines)
    byz = jnp.arange(machines) < 1
    data_key = stream_key(seed, "data")
    batches = [make_batch(jax.random.fold_in(data_key, i), cfg, batch, seq)
               for i in range(steps)]
    step_key = stream_key(seed, "protocol")

    t0 = time.perf_counter()
    params, mem, metrics = step_fn(params, mem, batches[0],
                                   jax.random.fold_in(step_key, 0), byz)
    jax.block_until_ready(params)
    t_cold = time.perf_counter() - t0            # includes compilation

    t0 = time.perf_counter()
    for i in range(1, steps):
        params, mem, metrics = step_fn(params, mem, batches[i],
                                       jax.random.fold_in(step_key, i),
                                       byz)
    jax.block_until_ready(params)
    t_steady = (time.perf_counter() - t0) / max(1, steps - 1)

    return {
        "setting": {"arch": arch, "machines": machines, "steps": steps,
                    "batch": batch, "seq": seq, "hist": hist, "agg": agg,
                    "device": jax.devices()[0].platform,
                    "jax": jax.__version__},
        "step_cold_s": t_cold,
        "step_steady_s": t_steady,
        "speedup_steady": t_cold / t_steady,
        "steps_per_s": 1.0 / t_steady,
        "traces": traces["n"],
        # compile-once: every post-compile step reuses the one executable
        "ok": traces["n"] == 1,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--machines", type=int, default=4)
    ap.add_argument("--hist", type=int, default=5)
    ap.add_argument("--agg", default="dcq_mad")
    ap.add_argument("--fast", action="store_true",
                    help="nightly/baseline setting (4 steps)")
    ap.add_argument("--out", default=BENCH_PATH)
    args = ap.parse_args(argv)
    steps = 4 if args.fast else args.steps
    record = measure(arch=args.arch, steps=steps, batch=args.batch,
                     seq=args.seq, machines=args.machines, hist=args.hist,
                     agg=args.agg)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(json.dumps(record, indent=1))
    print(f"wrote {args.out}")
    return 0 if record["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
