"""Aggregate experiments/dryrun/*.json into the §Roofline table
(one row per arch x shape x mesh) and emit the markdown used by
EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os
from typing import List


def load(outdir: str = "experiments/dryrun") -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def is_baseline(r: dict) -> bool:
    """Baseline sweep rows only (perf-variant runs carry a variant tag)."""
    return (not r.get("variant")
            and r.get("agg", "dcq") == "dcq"
            and r.get("strategy", "replicated") == "replicated"
            and not r.get("fsdp"))


def markdown_table(rows: List[dict], mesh: str = "16x16") -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | useful FLOP ratio | peak mem/dev |\n"
           "|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r.get("mesh") != mesh or not is_baseline(r):
            continue
        pm = r.get("peak_memory_bytes")
        pm_s = f"{pm/2**30:.1f} GiB" if pm else "?"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} | {pm_s} |")
    return "\n".join(lines)


def main(fast: bool = False):
    rows = load()
    if not rows:
        print("no dry-run records yet — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --arch all "
              "--shape all")
        return {"rows": 0}
    for mesh in sorted({r["mesh"] for r in rows}):
        n = sum(1 for r in rows if r["mesh"] == mesh)
        print(f"== roofline table ({mesh}; {n} rows) ==")
        print(markdown_table(rows, mesh))
    rows = [r for r in rows if is_baseline(r)]
    # summary: worst useful ratio / most collective-bound
    with_u = [r for r in rows if r.get("useful_ratio")]
    if with_u:
        worst = min(with_u, key=lambda r: r["useful_ratio"])
        collb = max(rows, key=lambda r: r["collective_s"]
                    / max(r["compute_s"] + r["memory_s"], 1e-12))
        print(f"worst useful-FLOP ratio: {worst['arch']}/{worst['shape']} "
              f"({worst['useful_ratio']:.2f})")
        print(f"most collective-bound: {collb['arch']}/{collb['shape']} "
              f"(coll {collb['collective_s']:.3g}s vs "
              f"comp {collb['compute_s']:.3g}s)")
    return {"rows": len(rows)}


if __name__ == "__main__":
    main()
