"""Paper Figures 3/6: MRSE vs the number of machines m (n fixed), normal
and Byzantine. Expect MRSE decreasing in m with a flattening tail, and the
sqrt(p/(mn)) optimal-rate scaling (Thm 4.3)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ProtocolConfig
from repro.core import DPQNProtocol, get_problem, monte_carlo_mrse
from repro.data.synthetic import make_shards, target_theta


def run(problem_name: str = "logistic", n: int = 500, p: int = 10,
        m_grid=(10, 20, 40, 80), reps: int = 4, byz_frac: float = 0.0,
        eps: float = 30.0, seed: int = 0):
    prob = get_problem(problem_name)
    t = target_theta(p)
    rows = []
    for m in m_grid:
        X, y = make_shards(jax.random.PRNGKey(seed + m), problem_name,
                           m, n, p)
        nb = int(byz_frac * m)
        byz = jnp.zeros((m,), bool).at[:nb].set(True) if nb else None
        cfg = ProtocolConfig(eps=eps, delta=0.05)
        proto = DPQNProtocol(prob, cfg)
        # one compiled Monte-Carlo batch per m (shapes differ across m, so
        # each grid point traces once and the reps ride the vmap axis)
        keys = jnp.stack([jax.random.PRNGKey(10 * m + r)
                          for r in range(reps)])
        arrs = proto.run_monte_carlo(keys, X, y, byz_mask=byz)
        rows.append({"m": m, "mrse": monte_carlo_mrse(arrs.theta_qn, t),
                     "rate": math.sqrt(p / (m * n))})
    return rows


def main(fast: bool = False):
    out = {}
    for byz in [0.0, 0.1]:
        rows = run(reps=2 if fast else 4, byz_frac=byz,
                   m_grid=(10, 20, 40) if fast else (10, 20, 40, 80))
        tag = f"m_sweep{'_byz' if byz else ''}"
        out[tag] = rows
        print(f"== MRSE vs m ({'10% byz' if byz else 'normal'}) ==")
        print(f"{'m':>5} {'mrse':>8} {'sqrt(p/mn)':>10} {'ratio':>7}")
        for r in rows:
            print(f"{r['m']:5d} {r['mrse']:8.4f} {r['rate']:10.4f} "
                  f"{r['mrse']/r['rate']:7.2f}")
        # claims: monotone decreasing in m; ratio to the optimal rate stays
        # bounded once out of the noise-dominated small-m regime (at m=10
        # the DP noise dominates and MRSE falls FASTER than sqrt(1/m) —
        # the same steep left edge as the paper's Figures 3/6)
        dec = all(b["mrse"] < a["mrse"] for a, b in zip(rows, rows[1:]))
        ratios = [r["mrse"] / r["rate"] for r in rows if r["m"] >= 20]
        bounded = max(ratios) < 4.0 * min(ratios)
        out[tag + "_ok"] = bool(dec and bounded)
        print("PASS" if dec and bounded else "FAIL",
              "(decreasing + rate-consistent for m >= 20)")
    return out


if __name__ == "__main__":
    main()
