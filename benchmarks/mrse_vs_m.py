"""Paper Figures 3/6: MRSE vs the number of machines m (n fixed), normal
and Byzantine. Expect MRSE decreasing in m with a flattening tail, and the
sqrt(p/(mn)) optimal-rate scaling (Thm 4.3).

Thin preset over the scenario-sweep engine: each m is its own jit group
(shapes differ), but the clean and Byzantine curves share every group via
the executor's engine cache, and the historical data/key schedule
(data seed + m, keys PRNGKey(10*m + r)) is preserved by
``fig_m_scenarios``."""
from __future__ import annotations

import math

from repro.sweep import SweepExecutor, fig_m_scenarios


def run(problem_name: str = "logistic", n: int = 500, p: int = 10,
        m_grid=(10, 20, 40, 80), reps: int = 4, byz_frac: float = 0.0,
        eps: float = 30.0, seed: int = 0,
        executor: SweepExecutor | None = None):
    scens = fig_m_scenarios(problem_name, n=n, p=p, m_grid=tuple(m_grid),
                            reps=reps, byz_frac=byz_frac, eps=eps, seed=seed)
    executor = executor or SweepExecutor()
    art = executor.run(scens, store_thetas=False)
    rows = []
    for m, s in zip(m_grid, scens):
        metrics = art["scenarios"][s.scenario_id()]["metrics"]
        rows.append({"m": m, "mrse": metrics["mrse_qn"],
                     "rate": math.sqrt(p / (m * n))})
    return rows


def main(fast: bool = False):
    out = {}
    executor = SweepExecutor()     # clean + byz curves share per-m groups
    for byz in [0.0, 0.1]:
        rows = run(reps=2 if fast else 4, byz_frac=byz,
                   m_grid=(10, 20, 40) if fast else (10, 20, 40, 80),
                   executor=executor)
        tag = f"m_sweep{'_byz' if byz else ''}"
        out[tag] = rows
        print(f"== MRSE vs m ({'10% byz' if byz else 'normal'}) ==")
        print(f"{'m':>5} {'mrse':>8} {'sqrt(p/mn)':>10} {'ratio':>7}")
        for r in rows:
            print(f"{r['m']:5d} {r['mrse']:8.4f} {r['rate']:10.4f} "
                  f"{r['mrse']/r['rate']:7.2f}")
        # claims: monotone decreasing in m; ratio to the optimal rate stays
        # bounded once out of the noise-dominated small-m regime (at m=10
        # the DP noise dominates and MRSE falls FASTER than sqrt(1/m) —
        # the same steep left edge as the paper's Figures 3/6)
        dec = all(b["mrse"] < a["mrse"] for a, b in zip(rows, rows[1:]))
        ratios = [r["mrse"] / r["rate"] for r in rows if r["m"] >= 20]
        bounded = max(ratios) < 4.0 * min(ratios)
        out[tag + "_ok"] = bool(dec and bounded)
        print("PASS" if dec and bounded else "FAIL",
              "(decreasing + rate-consistent for m >= 20)")
    return out


if __name__ == "__main__":
    main()
