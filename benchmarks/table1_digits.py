"""Paper Table 1 (§5.2): pairwise digit classifiers under DP + Byzantine.

MNIST is not downloadable in this container, so a deterministic
"digits-like" two-Gaussian dataset with the same pipeline stands in
(feature screening -> logistic probes; DESIGN.md §2). Validated claims are
structural: accuracy saturates for eps >= 20, Byzantine machines barely
move it, and the pair needing more features needs more budget.

Thin preset over the scenario-sweep engine: each pair's eps grid AND its
Byzantine point ride one jit group (``table1_scenarios``); pairs with the
same feature count share the compiled executable through the shared
executor. The global (non-distributed, non-private) reference is computed
directly from the scenario's data builder."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import get_problem
from repro.core.local import newton_solve
from repro.sweep import SweepExecutor, table1_scenarios
from repro.sweep.data import build_data


def global_reference_acc(scenario) -> float:
    """Pooled (non-distributed, non-private) logistic fit on the scenario's
    training shards, evaluated on its held-out split."""
    Xtr, ytr, aux = build_data(scenario)
    k = Xtr.shape[-1]
    theta_g = newton_solve(get_problem("logistic"), jnp.zeros((k,)),
                           Xtr.reshape(-1, k), ytr.reshape(-1))
    preds = (jax.nn.sigmoid(aux["Xte"] @ theta_g) > 0.5).astype(jnp.float32)
    return float((preds == aux["yte"]).mean())


def main(fast: bool = False):
    pairs = {(8, 9): 8, (6, 8): 5, (6, 9): 5}
    eps_grid = [5.0, 30.0] if fast else [5.0, 10.0, 20.0, 30.0]
    out = {}
    executor = SweepExecutor()     # (6,8)/(6,9) share the p=5 jit group
    print("== Table 1 stand-in: accuracy vs eps (digits-like pairs) ==")
    print(f"{'pair':>8} {'#feat':>5} | " +
          " ".join(f"eps={e:<4g}" for e in eps_grid) +
          " | byz(30) | global")
    for pair, k in pairs.items():
        scens = table1_scenarios(pair, k, eps_grid=tuple(eps_grid),
                                 byz_eps=(30.0,))
        art = executor.run(scens, store_thetas=False)
        accs = [art["scenarios"][s.scenario_id()]["metrics"]["accuracy"]
                for s in scens[:len(eps_grid)]]
        acc_byz = art["scenarios"][scens[-1].scenario_id()
                                   ]["metrics"]["accuracy"]
        acc_g = global_reference_acc(scens[0])
        out[str(pair)] = {"accs": accs, "byz": acc_byz, "global": acc_g}
        print(f"{str(pair):>8} {k:5d} | " +
              " ".join(f"{a:7.3f}" for a in accs) +
              f" | {acc_byz:7.3f} | {acc_g:6.3f}")
        # claims (structural, at reduced m/n): near-saturation by eps=30;
        # byzantine machines barely move the saturated accuracy
        sat = accs[-1] > acc_g - 0.05
        robust = abs(acc_byz - accs[-1]) < 0.08
        out[str(pair) + "_ok"] = bool(sat and robust)
    print("PASS" if all(v for k, v in out.items() if k.endswith("_ok"))
          else "FAIL")
    return out


if __name__ == "__main__":
    main()
