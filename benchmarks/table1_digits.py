"""Paper Table 1 (§5.2): pairwise digit classifiers under DP + Byzantine.

MNIST is not downloadable in this container, so a deterministic
"digits-like" two-Gaussian dataset with the same pipeline stands in
(feature screening -> logistic probes; DESIGN.md §2). Validated claims are
structural: accuracy saturates for eps >= 20, Byzantine machines barely
move it, and the pair needing more features needs more budget.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ProtocolConfig
from repro.core import DPQNProtocol, get_problem
from repro.data.synthetic import digits_like_dataset


def screen_features(X, y, k: int) -> jnp.ndarray:
    """Lasso-style screening stand-in: top-k |two-sample t| features."""
    mu1 = X[y == 1].mean(0)
    mu0 = X[y == 0].mean(0)
    s = X.std(0) + 1e-9
    t = jnp.abs(mu1 - mu0) / s
    return jnp.argsort(-t)[:k]


def run_pair(pair, n_features_used: int, m: int = 10, eps: float = 20.0,
             byz: bool = False, seed: int = 0, n_per_machine: int = 1000):
    n_total = (m + 1) * n_per_machine + 4000
    X, y, _ = digits_like_dataset(seed, n_total, pair=pair)
    cols = screen_features(X[:4000], y[:4000], n_features_used)
    Xs = X[:, cols]
    Xtr = Xs[:(m + 1) * n_per_machine].reshape(m + 1, n_per_machine, -1)
    ytr = y[:(m + 1) * n_per_machine].reshape(m + 1, n_per_machine)
    Xte, yte = Xs[-4000:], y[-4000:]

    cfg = ProtocolConfig(eps=eps, delta=0.05,
                         gammas=(0.5,) * 5)      # paper uses gamma=0.5 here
    nb = max(1, m // 10) if byz else 0
    mask = jnp.zeros((m,), bool).at[:nb].set(True) if nb else None
    proto = DPQNProtocol(get_problem("logistic"), cfg)
    # average out DP-noise draws: one compiled 3-replicate batch
    keys = jnp.stack([jax.random.PRNGKey(seed + 1 + 1000 * rep)
                      for rep in range(3)])
    arrs = proto.run_monte_carlo(keys, Xtr, ytr, byz_mask=mask,
                                 attack="scale", attack_factor=3.0)  # paper: +3x
    preds = (jax.nn.sigmoid(arrs.theta_qn @ Xte.T) > 0.5).astype(jnp.float32)
    acc = float((preds == yte[None, :]).mean())
    # global (non-distributed, non-private) reference
    from repro.core.local import newton_solve
    theta_g = newton_solve(get_problem("logistic"),
                           jnp.zeros((Xs.shape[1],)),
                           Xtr.reshape(-1, Xs.shape[1]), ytr.reshape(-1))
    acc_g = float(((jax.nn.sigmoid(Xte @ theta_g) > 0.5).astype(jnp.float32)
                   == yte).mean())
    return acc, acc_g


def main(fast: bool = False):
    pairs = {(8, 9): 8, (6, 8): 5, (6, 9): 5}
    eps_grid = [5, 30] if fast else [5, 10, 20, 30]
    out = {}
    print("== Table 1 stand-in: accuracy vs eps (digits-like pairs) ==")
    print(f"{'pair':>8} {'#feat':>5} | " +
          " ".join(f"eps={e:<4d}" for e in eps_grid) +
          " | byz(30) | global")
    for pair, k in pairs.items():
        accs = [run_pair(pair, k, eps=e)[0] for e in eps_grid]
        acc_byz, acc_g = run_pair(pair, k, eps=30.0, byz=True)
        out[str(pair)] = {"accs": accs, "byz": acc_byz, "global": acc_g}
        print(f"{str(pair):>8} {k:5d} | " +
              " ".join(f"{a:7.3f}" for a in accs) +
              f" | {acc_byz:7.3f} | {acc_g:6.3f}")
        # claims (structural, at reduced m/n): near-saturation by eps=30;
        # byzantine machines barely move the saturated accuracy
        sat = accs[-1] > acc_g - 0.05
        robust = abs(acc_byz - accs[-1]) < 0.08
        out[str(pair) + "_ok"] = bool(sat and robust)
    print("PASS" if all(v for k, v in out.items() if k.endswith("_ok"))
          else "FAIL")
    return out


if __name__ == "__main__":
    main()
