"""Attack-sensitivity sweep benchmark: wall-clock + compile counts for the
registry-driven threat-model grid (``--preset attack-sensitivity``).

    PYTHONPATH=src python -m benchmarks.attack_sweep --fast

Runs the preset twice through ONE executor: the first pass pays every
(attack, aggregator) jit-group compile, the second reuses the cached
executables — its wall-clock is the steady-state number a nightly re-run
should see. Writes a ``BENCH_protocol.json``-style record to
``BENCH_attacks.json``:

  * ``sweep_first_s`` / ``sweep_steady_s`` — cold vs steady wall-clock;
  * ``speedup_steady``  — first/steady, the in-run compile-amortization
    signal measured on the SAME machine (hardware cancels out, so
    benchmarks/check_regression.py can two-signal gate it against the
    committed benchmarks/baselines/BENCH_attacks_fast.json);
  * ``n_groups`` / ``n_traces`` — the compile-once contract: ``ok`` is
    false unless every jit group traced exactly once across BOTH passes.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.sweep.executor import SweepExecutor
from repro.sweep.grid import group_scenarios
from repro.sweep.presets import attack_sensitivity_scenarios, fast_variant


def bench_attack_sweep(fast: bool = False,
                       out_path: str = "BENCH_attacks.json") -> dict:
    scens = attack_sensitivity_scenarios()
    if fast:
        scens = fast_variant(scens)
    groups = group_scenarios(scens)
    s0 = scens[0]
    print(f"attack-sensitivity{' --fast' if fast else ''}: "
          f"{len(scens)} scenarios in {len(groups)} jit group(s)")

    executor = SweepExecutor()
    t0 = time.perf_counter()
    executor.run(scens, store_thetas=False)
    first_s = time.perf_counter() - t0
    traces_cold = sum(executor.trace_counts.values())

    t0 = time.perf_counter()
    executor.run(scens, store_thetas=False)
    steady_s = time.perf_counter() - t0
    traces = sum(executor.trace_counts.values())

    ok = traces_cold == len(groups) and traces == len(groups)
    record = {
        "setting": {
            "preset": "attack-sensitivity", "fast": fast,
            "n_scenarios": len(scens), "n_groups": len(groups),
            "m": s0.m, "n": s0.n, "p": s0.p, "reps": s0.reps,
            "device": jax.devices()[0].platform, "jax": jax.__version__,
        },
        "sweep_first_s": first_s,
        "sweep_steady_s": steady_s,
        "speedup_steady": first_s / steady_s,
        "n_traces": traces,
        "ok": ok,
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"cold {first_s:.1f}s -> steady {steady_s:.1f}s "
          f"({record['speedup_steady']:.1f}x); {traces} trace(s) over "
          f"{len(groups)} group(s); ok={ok}")
    print(f"wrote {out_path}")
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="reduced replicate counts (the nightly-CI scale)")
    ap.add_argument("--out", default="BENCH_attacks.json")
    args = ap.parse_args(argv)
    record = bench_attack_sweep(fast=args.fast, out_path=args.out)
    return 0 if record["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
