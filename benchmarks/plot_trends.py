"""Accountant-trend panels: MRSE vs eps, one line per privacy accountant.

Consumes the sweep artifacts the nightly ``accountant-sweep`` job emits
(``experiments/sweep_smoke_<accountant>.json``, one per repro.privacy
registry entry) and renders a panel grid — one panel per
(problem, attack, aggregator) cell of the grid, MRSE-vs-eps curves
overlaid per accountant — so a tighter accountant's smaller calibrated
sigma is visible as a downward shift of the whole curve, night over
night. A machine-readable summary (per-accountant mean
``sigma_ratio_vs_basic`` and per-panel curve data) is always written
next to the figure; the PNG itself needs matplotlib and is skipped with
a warning when the plotting stack is absent, so the job still publishes
the trend table on a minimal runner.

  python -m benchmarks.plot_trends \
      experiments/sweep_smoke_basic.json \
      experiments/sweep_smoke_advanced.json \
      experiments/sweep_smoke_rdp.json \
      --out trends/accountant_trends.png

Artifacts that share scenarios (same grid, different ``--accountant``
override) line up by the panel key, not by scenario_id — non-basic
accountants get a distinct id segment by design (sweep/grid.py).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict

from repro.sweep import artifact as artifact_mod

#: y-axis metric per scenario kind: protocol scenarios report the paper's
#: MRSE triple, train scenarios an accuracy.
_METRICS = ("mrse_qn", "accuracy")


def _panel_key(row):
    """One panel per grid cell; eps and accountant vary inside it."""
    return (str(row.get("problem", row.get("arch", "?"))),
            str(row.get("attack", "none")),
            str(row.get("aggregator", "?")),
            float(row.get("byz_frac", 0.0)))


def _metric(row):
    for name in _METRICS:
        if name in row:
            return name, float(row[name])
    return None, None


def collect(paths):
    """{panel_key: {accountant: [(eps, value), ...]}} plus the
    per-accountant mean sigma ratio over every scenario that carried one."""
    panels = defaultdict(lambda: defaultdict(list))
    ratios = defaultdict(list)
    metric_name = "mrse_qn"
    for path in paths:
        art = artifact_mod.load(path)
        for row in artifact_mod.rows(art):
            name, val = _metric(row)
            if name is None:
                continue
            metric_name = name
            acct = str(row.get("accountant", "basic"))
            panels[_panel_key(row)][acct].append(
                (float(row["eps_total"]), val))
            ratios[acct].append(float(row.get("sigma_ratio_vs_basic", 1.0)))
    for by_acct in panels.values():
        for curve in by_acct.values():
            curve.sort()
    return panels, ratios, metric_name


def summary_dict(panels, ratios, metric_name):
    return {
        "metric": metric_name,
        "accountants": sorted({a for c in panels.values() for a in c}),
        "mean_sigma_ratio_vs_basic": {
            a: sum(r) / len(r) for a, r in sorted(ratios.items())},
        "panels": [
            {"problem": k[0], "attack": k[1], "aggregator": k[2],
             "byz_frac": k[3],
             "curves": {a: [[e, v] for e, v in pts]
                        for a, pts in sorted(by_acct.items())}}
            for k, by_acct in sorted(panels.items())],
    }


def render(panels, metric_name, out_png):
    try:
        import matplotlib
    except ImportError:
        print("plot_trends: matplotlib unavailable, skipping PNG "
              f"({out_png}); the JSON summary still has every curve",
              file=sys.stderr)
        return False
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    keys = sorted(panels)
    n = len(keys)
    ncols = min(3, max(1, n))
    nrows = (n + ncols - 1) // ncols
    fig, axes = plt.subplots(nrows, ncols, squeeze=False,
                             figsize=(4.2 * ncols, 3.2 * nrows))
    for ax in axes.flat[n:]:
        ax.set_axis_off()
    for ax, key in zip(axes.flat, keys):
        problem, attack, aggregator, byz = key
        for acct, pts in sorted(panels[key].items()):
            eps = [e for e, _ in pts]
            val = [v for _, v in pts]
            ax.plot(eps, val, marker="o", label=acct)
        ax.set_title(f"{problem} / {attack} / {aggregator}"
                     + (f" / byz={byz:g}" if byz else ""), fontsize=8)
        ax.set_xlabel("eps (total)", fontsize=8)
        ax.set_ylabel(metric_name, fontsize=8)
        ax.set_yscale("log")
        ax.tick_params(labelsize=7)
        ax.legend(fontsize=7)
    fig.tight_layout()
    fig.savefig(out_png, dpi=120)
    plt.close(fig)
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.plot_trends",
        description="MRSE-vs-eps panels per privacy accountant from "
                    "sweep artifacts (nightly accountant-sweep).")
    ap.add_argument("artifacts", nargs="+",
                    help="sweep artifact JSON paths (one per accountant)")
    ap.add_argument("--out", default="trends/accountant_trends.png",
                    help="output figure path; the JSON summary lands "
                         "beside it with a .json suffix")
    args = ap.parse_args(argv)

    panels, ratios, metric_name = collect(args.artifacts)
    if not panels:
        print("plot_trends: no plottable scenarios in "
              f"{args.artifacts}", file=sys.stderr)
        return 1

    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)
    out_json = os.path.splitext(args.out)[0] + ".json"
    summary = summary_dict(panels, ratios, metric_name)
    with open(out_json, "w") as f:
        json.dump(summary, f, indent=1)
    print(f"wrote {out_json} ({len(panels)} panel(s), accountants: "
          f"{', '.join(summary['accountants'])})")
    for acct, ratio in summary["mean_sigma_ratio_vs_basic"].items():
        print(f"  {acct:>10}: mean sigma ratio vs basic {ratio:.3f}")
    if render(panels, metric_name, args.out):
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
