"""Protocol engine throughput: eager per-op pipeline vs the compile-once
jit(vmap) Monte-Carlo driver, on the mrse_vs_eps logistic setting.

Writes BENCH_protocol.json at the repo root so the perf trajectory has a
recorded datapoint:

    PYTHONPATH=src python -m benchmarks.bench_protocol [--fast]

Numbers recorded: wall-clock for ``reps`` eager ``DPQNProtocol.run`` calls,
the compiled path's first call (incl. compile) and steady-state, and the
replicate throughput of each. Acceptance: compiled steady-state >= 3x the
eager path on CPU.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.configs.base import ProtocolConfig
from repro.core import DPQNProtocol, get_problem
from repro.core.keys import stream_key
from repro.data.synthetic import make_shards

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(_REPO_ROOT, "BENCH_protocol.json")


def measure(reps: int = 20, m: int = 50, n: int = 1000, p: int = 10,
            eps: float = 30.0, seed: int = 0) -> dict:
    X, y = make_shards(jax.random.PRNGKey(seed), "logistic", m, n, p)
    prob = get_problem("logistic")
    cfg = ProtocolConfig(eps=eps, delta=0.05)
    keys = jax.random.split(stream_key(seed, "protocol"), reps)

    # eager baseline: the pre-refactor execution model — one Python-driven
    # per-op pipeline per replicate, no compilation, host sync every round
    eager = DPQNProtocol(prob, cfg, jit=False)
    t0 = time.perf_counter()
    for r in range(reps):
        eager.run(keys[r], X, y).theta_qn.block_until_ready()
    t_eager = time.perf_counter() - t0

    # compiled path: jit once, vmap over the replicate axis
    proto = DPQNProtocol(prob, cfg)
    t0 = time.perf_counter()
    jax.block_until_ready(proto.run_monte_carlo(keys, X, y))
    t_first = time.perf_counter() - t0           # includes compilation
    t0 = time.perf_counter()
    # repro: allow(key-reuse) — deliberate: the SAME replicate batch is
    # re-run to time the steady state (identical computation, cache hit);
    # the draws are timing fodder, not statistics.
    jax.block_until_ready(proto.run_monte_carlo(keys, X, y))
    t_steady = time.perf_counter() - t0

    return {
        "setting": {"problem": "logistic", "m": m, "n": n, "p": p,
                    "eps": eps, "reps": reps,
                    "device": jax.devices()[0].platform,
                    "jax": jax.__version__},
        "eager_s": t_eager,
        "compiled_first_call_s": t_first,
        "compiled_steady_s": t_steady,
        "speedup_steady": t_eager / t_steady,
        "speedup_incl_compile": t_eager / t_first,
        "replicates_per_s_eager": reps / t_eager,
        "replicates_per_s_compiled": reps / t_steady,
    }


def main(fast: bool = False, out: str = BENCH_PATH) -> dict:
    res = (measure(reps=8, m=20, n=400, p=6) if fast
           else measure(reps=20, m=50, n=1000, p=10))
    s = res["setting"]
    print(f"== protocol engine: {s['reps']} replicates, logistic "
          f"m={s['m']} n={s['n']} p={s['p']} ({s['device']}) ==")
    print(f"eager {s['reps']}x run():        {res['eager_s']:8.2f} s "
          f"({res['replicates_per_s_eager']:.2f} reps/s)")
    print(f"compiled first (incl. jit): {res['compiled_first_call_s']:8.2f} s")
    print(f"compiled steady-state:      {res['compiled_steady_s']:8.2f} s "
          f"({res['replicates_per_s_compiled']:.2f} reps/s)")
    print(f"speedup: {res['speedup_steady']:.1f}x steady, "
          f"{res['speedup_incl_compile']:.1f}x incl. compile")
    ok = res["speedup_steady"] >= 3.0
    res["ok"] = ok
    with open(out, "w") as f:
        json.dump(res, f, indent=1)
    print(f"wrote {out}")
    print("PASS" if ok else "FAIL", "(compiled steady-state >= 3x eager)")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced size (CI smoke)")
    args = ap.parse_args()
    main(fast=args.fast)
