"""Run every benchmark (one per paper table/figure + system benches).

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import time
import traceback


def _sweep_smoke(fast: bool = False):
    """The CI smoke grid through the sweep engine (one compile per jit
    group, asserted via the executor's trace counters)."""
    from repro.sweep import SweepExecutor, fast_variant, smoke_scenarios
    scens = smoke_scenarios()
    if fast:
        scens = fast_variant(scens)
    executor = SweepExecutor(progress=print)
    art = executor.run(scens, store_thetas=False)
    retraced = {k: c for k, c in executor.trace_counts.items() if c > 1}
    if retraced:
        raise RuntimeError(f"{len(retraced)} jit group(s) retraced")
    return {"n_scenarios": len(art["scenarios"]),
            "n_groups": len(executor.trace_counts)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced rep counts (CI smoke)")
    ap.add_argument("--out", default="experiments/bench_results.json")
    args = ap.parse_args(argv)

    from benchmarks import (are_dcq, attack_sweep, bench_protocol,
                            comm_cost, kernel_bench, mrse_vs_eps,
                            mrse_vs_m, roofline_report, table1_digits)
    suites = [
        ("are_dcq (paper §1.2: ARE 0.955 vs 0.637)", are_dcq.main),
        ("bench_protocol (eager vs compiled engine)", bench_protocol.main),
        ("sweep_smoke (scenario-sweep engine grid)", _sweep_smoke),
        ("attack_sweep (threat-model sensitivity grid)",
         lambda fast=False: attack_sweep.bench_attack_sweep(fast=fast)),
        ("mrse_vs_eps (Figures 1/2/4/5)", mrse_vs_eps.main),
        ("mrse_vs_m (Figures 3/6)", mrse_vs_m.main),
        ("table1_digits (Table 1 stand-in)", table1_digits.main),
        ("comm_cost (§1.2(1)/§6 budget+bytes)", comm_cost.main),
        ("kernel_bench (Pallas hot-spots)", kernel_bench.main),
        ("roofline_report (§Roofline table)", roofline_report.main),
    ]
    results, failures = {}, []
    for name, fn in suites:
        print(f"\n##### {name} #####")
        t0 = time.time()
        try:
            results[name] = {"result": fn(fast=args.fast),
                             "seconds": time.time() - t0}
        except Exception as e:
            traceback.print_exc()
            failures.append((name, repr(e)))
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"\nwrote {args.out}")
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print(f"all {len(suites)} benchmark suites completed")


if __name__ == "__main__":
    main()
