"""Paper Figures 1/2 (logistic) and 4/5 (Poisson): MRSE vs privacy budget
for theta_cq / theta_os / theta_qn, normal and 10%-Byzantine, plus the
noiseless quasi-Newton reference line.

Thin preset over the scenario-sweep engine (repro.sweep): each curve is a
``fig_eps_scenarios`` list whose eps axis rides ONE compiled executable
(the jit group batches eps/byz_frac dynamically), and the clean/Byzantine
variants share that executable too. Per-key results match the pre-refactor
``run_monte_carlo`` loops: the sweep feeds the same PRNG key schedule
(PRNGKey(1000*eps + r)) and host-calibrated noise sds into the identical
pure core (asserted to 1e-5 in tests/test_sweep.py).

Running this module as a script also emits BENCH_protocol.json
(eager-vs-compiled wall-clock) via bench_protocol.

Scaled down from the paper's N=2e6 to CPU size (the claims validated are
ordering and saturation structure, not absolute values — EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse

from repro.sweep import SweepExecutor, fig_eps_reference, fig_eps_scenarios


def run_curve(problem_name: str = "logistic", m: int = 50, n: int = 1000,
              p: int = 10, reps: int = 5, byz_frac: float = 0.0,
              eps_grid=(4, 10, 20, 30, 50), seed: int = 0,
              executor: SweepExecutor | None = None):
    """One MRSE-vs-eps curve through the sweep engine. Passing a shared
    ``executor`` lets the clean and Byzantine curves (same jit group) reuse
    one compiled executable."""
    scens = fig_eps_scenarios(problem_name, m=m, n=n, p=p, reps=reps,
                              byz_frac=byz_frac,
                              eps_grid=tuple(float(e) for e in eps_grid),
                              seed=seed)
    ref_scen = fig_eps_reference(problem_name, m=m, n=n, p=p,
                                 byz_frac=byz_frac, seed=seed)
    executor = executor or SweepExecutor()
    art = executor.run(scens + [ref_scen], store_thetas=False)
    rows = []
    for eps, s in zip(eps_grid, scens):
        metrics = art["scenarios"][s.scenario_id()]["metrics"]
        rows.append({"eps": eps, "cq": metrics["mrse_cq"],
                     "os": metrics["mrse_os"], "qn": metrics["mrse_qn"]})
    ref = art["scenarios"][ref_scen.scenario_id()]["metrics"]["mrse_qn"]
    return rows, ref


def main(fast: bool = False):
    reps = 3 if fast else 5
    out = {}
    executor = SweepExecutor()     # shared: clean + byz curves per problem
    for name in ["logistic", "poisson"]:
        for byz in [0.0, 0.1]:
            rows, ref = run_curve(name, reps=reps, byz_frac=byz,
                                  executor=executor)
            tag = f"{name}{'_byz' if byz else ''}"
            out[tag] = {"rows": rows, "noiseless_ref": ref}
            print(f"== {tag}: MRSE vs eps (noiseless qn ref {ref:.4f}) ==")
            print(f"{'eps':>5} {'cq':>8} {'os':>8} {'qn':>8}")
            for r in rows:
                print(f"{r['eps']:5d} {r['cq']:8.4f} {r['os']:8.4f} "
                      f"{r['qn']:8.4f}")
            # paper claims: ordering + saturation toward the reference
            last = rows[-1]
            ok = (last["qn"] <= last["cq"] + 1e-9
                  and last["qn"] < 2.5 * max(ref, 0.02)
                  and rows[0]["qn"] >= last["qn"] - 0.02)
            out[tag]["ok"] = ok
            print("PASS" if ok else "FAIL")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced rep counts (CI smoke)")
    ap.add_argument("--no-bench", action="store_true",
                    help="skip the eager-vs-compiled timing pass")
    args = ap.parse_args()
    main(fast=args.fast)
    if not args.no_bench:
        from benchmarks import bench_protocol
        bench_protocol.main(fast=args.fast)
