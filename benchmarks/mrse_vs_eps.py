"""Paper Figures 1/2 (logistic) and 4/5 (Poisson): MRSE vs privacy budget
for theta_cq / theta_os / theta_qn, normal and 10%-Byzantine, plus the
noiseless quasi-Newton reference line.

Replicates run through the compile-once engine: one jit(vmap) Monte-Carlo
batch per eps point instead of an eager Python loop
(DPQNProtocol.run_monte_carlo). Running this module as a script also emits
BENCH_protocol.json (eager-vs-compiled wall-clock) via bench_protocol.

Scaled down from the paper's N=2e6 to CPU size (the claims validated are
ordering and saturation structure, not absolute values — EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ProtocolConfig
from repro.core import DPQNProtocol, get_problem, monte_carlo_mrse
from repro.data.synthetic import make_shards, target_theta


def run_curve(problem_name: str = "logistic", m: int = 50, n: int = 1000,
              p: int = 10, reps: int = 5, byz_frac: float = 0.0,
              eps_grid=(4, 10, 20, 30, 50), seed: int = 0):
    X, y = make_shards(jax.random.PRNGKey(seed), problem_name, m, n, p)
    t = target_theta(p)
    prob = get_problem(problem_name)
    nb = int(byz_frac * m)
    byz = jnp.zeros((m,), bool).at[:nb].set(True) if nb else None
    rows = []
    for eps in eps_grid:
        cfg = ProtocolConfig(eps=float(eps), delta=0.05)
        proto = DPQNProtocol(prob, cfg)
        keys = jnp.stack([jax.random.PRNGKey(1000 * eps + r)
                          for r in range(reps)])
        arrs = proto.run_monte_carlo(keys, X, y, byz_mask=byz)
        errs = {name: monte_carlo_mrse(getattr(arrs, f"theta_{name}"), t)
                for name in ("cq", "os", "qn")}
        rows.append({"eps": eps, **errs})
    # noiseless reference
    res0 = DPQNProtocol(prob, ProtocolConfig(noiseless=True)).run(
        jax.random.PRNGKey(9), X, y, byz_mask=byz)
    ref = float(jnp.linalg.norm(res0.theta_qn - t))
    return rows, ref


def main(fast: bool = False):
    reps = 3 if fast else 5
    out = {}
    for name in ["logistic", "poisson"]:
        for byz in [0.0, 0.1]:
            rows, ref = run_curve(name, reps=reps, byz_frac=byz)
            tag = f"{name}{'_byz' if byz else ''}"
            out[tag] = {"rows": rows, "noiseless_ref": ref}
            print(f"== {tag}: MRSE vs eps (noiseless qn ref {ref:.4f}) ==")
            print(f"{'eps':>5} {'cq':>8} {'os':>8} {'qn':>8}")
            for r in rows:
                print(f"{r['eps']:5d} {r['cq']:8.4f} {r['os']:8.4f} "
                      f"{r['qn']:8.4f}")
            # paper claims: ordering + saturation toward the reference
            last = rows[-1]
            ok = (last["qn"] <= last["cq"] + 1e-9
                  and last["qn"] < 2.5 * max(ref, 0.02)
                  and rows[0]["qn"] >= last["qn"] - 0.02)
            out[tag]["ok"] = ok
            print("PASS" if ok else "FAIL")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced rep counts (CI smoke)")
    ap.add_argument("--no-bench", action="store_true",
                    help="skip the eager-vs-compiled timing pass")
    args = ap.parse_args()
    main(fast=args.fast)
    if not args.no_bench:
        from benchmarks import bench_protocol
        bench_protocol.main(fast=args.fast)
