# repro: allow-file(wire-boundary) — kernel benchmark: comparing the raw
# registry backends (reference vs Pallas) against each other IS the job;
# the wire would hide exactly the dispatch being measured.
"""Kernel micro-benchmarks: jnp oracle vs Pallas(interpret) correctness at
bench shapes + HLO-derived arithmetic-intensity notes for the TPU target,
plus the BATCHED-AGGREGATION benchmark that gates the sweep hot path.

Wall-times on CPU interpret mode are NOT TPU performance — the meaningful
numbers here are bytes/FLOPs per call (printed for the roofline narrative)
and the correctness deltas at production-like shapes. The batched section
IS a real CPU measurement though: it times the sweep engine's aggregation
regime (many small (m, p) problems) three ways —

  loop_sorted     one jitted sorted-jnp call per batch row (the
                  per-scenario fallback the repro.agg refactor removed)
  batched_sorted  one jit(vmap(sorted-jnp)) launch
  batched_pallas  ONE generalized order-statistics kernel launch with the
                  batch mapped onto the Pallas grid (interpret off-TPU)

and writes BENCH_agg.json; benchmarks/check_regression.py gates the
committed baseline (benchmarks/baselines/BENCH_agg_fast.json) against it.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import agg
from repro.agg import aggregate, ostat_pallas, registered
from repro.agg.reference import dcq_mad_reference
from repro.kernels.gqa_decode import gqa_decode_pallas
from repro.kernels.gqa_decode_ref import gqa_decode_reference


def _time(f, *args, reps=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / reps


def bench_batched_agg(fast: bool = False, out_path: str = "BENCH_agg.json"):
    """Batched aggregation at the sweep engine's regime: B small (m, p)
    problems per launch (B = scenarios x replicates). Steady-state
    measurement; the regression signals are the batched-pallas wall time
    and its same-machine speedup over the per-row sorted loop."""
    B, m, p = (96, 8, 10) if fast else (320, 8, 10)
    K, reps = 10, 5
    v = jax.random.normal(jax.random.PRNGKey(0), (B, m, p))

    ref_one = jax.jit(dcq_mad_reference)
    ref_batched = jax.jit(jax.vmap(dcq_mad_reference))

    def loop_sorted():
        outs = [ref_one(v[b]) for b in range(B)]
        jax.block_until_ready(outs[-1])
        return outs

    def batched_sorted():
        out = ref_batched(v)
        jax.block_until_ready(out)
        return out

    def batched_pallas():
        out = ostat_pallas(v, "dcq_mad", K=K)
        jax.block_until_ready(out)
        return out

    # correctness at the bench shape before timing anything
    err = float(jnp.abs(jnp.stack(loop_sorted()) - batched_pallas()).max())
    assert err < 5e-4, f"batched kernel disagrees with oracle: {err}"

    def steady(f):
        f()                                     # warm the jit caches
        t0 = time.perf_counter()
        for _ in range(reps):
            f()
        return (time.perf_counter() - t0) / reps

    t_loop = steady(loop_sorted)
    t_batched = steady(batched_sorted)
    t_pallas = steady(batched_pallas)
    result = {
        "setting": {"B": B, "m": m, "p": p, "K": K, "reps": reps,
                    "device": jax.devices()[0].platform,
                    "jax": jax.__version__},
        "max_err_vs_oracle": err,
        "loop_sorted_s": t_loop,
        "batched_sorted_s": t_batched,
        "batched_pallas_s": t_pallas,
        "speedup_pallas_vs_loop": t_loop / t_pallas,
        "speedup_batched_vs_loop": t_loop / t_batched,
        # the gate condition: one fused batched-kernel launch beats the
        # per-scenario sorted fallback it replaced
        "ok": t_pallas < t_loop,
    }
    print(f"  B={B} m={m} p={p}: loop_sorted={t_loop*1e3:8.2f}ms  "
          f"batched_sorted={t_batched*1e3:7.2f}ms  "
          f"batched_pallas={t_pallas*1e3:7.2f}ms")
    print(f"  batched-pallas speedup vs per-scenario sorted loop: "
          f"{result['speedup_pallas_vs_loop']:.2f}x "
          f"(batched-sorted: {result['speedup_batched_vs_loop']:.2f}x)  "
          f"max|err|={err:.2e}  {'PASS' if result['ok'] else 'FAIL'}")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1)
        print(f"  wrote {out_path}")
    return result


def main(fast: bool = False, agg_out: str = "BENCH_agg.json"):
    print("== registered aggregators: Pallas kernel vs jnp reference ==")
    out = {}
    shapes = [(16, 4096), (64, 16384)] if not fast else [(16, 2048)]
    pallas_aggs = tuple(n for n in registered() if agg.has_pallas(n))
    for m, p in shapes:
        v = jax.random.normal(jax.random.PRNGKey(0), (m, p)) * 2.5
        errs = {}
        for method in pallas_aggs:
            scale = (jnp.abs(jax.random.normal(jax.random.PRNGKey(1),
                                               (p,))) + 0.1
                     if agg.get_aggregator(method).needs_scale else None)
            ref = aggregate(v, method, scale=scale, backend="reference")
            ker = aggregate(v, method, scale=scale, backend="pallas")
            errs[method] = float(jnp.abs(ref - ker).max())
        t_ref = _time(jax.jit(dcq_mad_reference), v)
        io_bytes = (m * p + p) * 4
        flops_est = 2 * 60 * m * p + 10 * m * p     # bisection + CQ sums
        ai = flops_est / io_bytes
        worst = max(errs.values())
        print(f"  m={m:4d} p={p:6d}: max|err|={worst:.2e} over "
              f"{len(errs)} aggregators  jnp_oracle(dcq_mad)="
              f"{t_ref*1e3:7.2f}ms  "
              f"arith-intensity~{ai:.1f} flop/byte (VPU-bound)")
        out[f"agg_{m}x{p}"] = {"errs": errs, "ai": ai}

    print("== batched aggregation (the sweep hot path) ==")
    out["batched_agg"] = bench_batched_agg(fast=fast, out_path=agg_out)

    print("== GQA flash-decode kernel (1 token vs cache) ==")
    for B, S, Hq, Hkv, Dh in ([(8, 4096, 32, 8, 128)] if not fast
                              else [(4, 1024, 8, 2, 64)]):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (B, Hq, Dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, Hkv, Dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, Hkv, Dh), jnp.float32)
        clen = jnp.full((B,), S, jnp.int32)
        ref = gqa_decode_reference(q, k, v, clen)
        ker = gqa_decode_pallas(q, k, v, clen, ts=512)
        err = float(jnp.abs(ref - ker).max())
        cache_bytes = 2 * B * S * Hkv * Dh * 4
        flops = 4 * B * Hq * S * Dh
        ai = flops / cache_bytes
        print(f"  B={B} S={S} Hq={Hq} Hkv={Hkv}: max|err|={err:.2e}  "
              f"cache={cache_bytes/1e6:.0f}MB/step  "
              f"arith-intensity={ai:.2f} flop/byte (HBM-bound: "
              f"roofline = cache_bytes/819GB/s)")
        out[f"gqa_{B}x{S}"] = {"err": err, "ai": ai}
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="reduced shapes (CI smoke)")
    ap.add_argument("--agg-out", default="BENCH_agg.json",
                    help="batched-aggregation benchmark record path")
    args = ap.parse_args()
    main(fast=args.fast, agg_out=args.agg_out)
