# repro: allow-file(wire-boundary) — kernel benchmark: comparing the raw
# registry backends (reference vs Pallas) against each other IS the job;
# the wire would hide exactly the dispatch being measured.
"""Kernel micro-benchmarks: jnp oracle vs Pallas(interpret) correctness at
bench shapes + HLO-derived arithmetic-intensity notes for the TPU target,
plus the BATCHED-AGGREGATION benchmark that gates the sweep hot path.

Wall-times on CPU interpret mode are NOT TPU performance — the meaningful
numbers here are bytes/FLOPs per call (printed for the roofline narrative)
and the correctness deltas at production-like shapes. The batched section
IS a real CPU measurement though: it times the sweep engine's aggregation
regime (many small (m, p) problems) three ways —

  loop_sorted     one jitted sorted-jnp call per batch row (the
                  per-scenario fallback the repro.agg refactor removed)
  batched_sorted  one jit(vmap(sorted-jnp)) launch
  batched_pallas  ONE generalized order-statistics kernel launch with the
                  batch mapped onto the Pallas grid (interpret off-TPU)

and writes BENCH_agg.json (schema v2: one record per shape bucket —
sweep-regime small-p, gradient mid-p, model-gradient large-p — with
per-backend timings plus the auto path that consults the measured
dispatch table); benchmarks/check_regression.py gates the committed
baseline (benchmarks/baselines/BENCH_agg_fast.json) against it.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import agg
from repro.agg import aggregate, aggregate_batched, dispatch, ostat_pallas, \
    registered
from repro.agg.reference import dcq_mad_reference
from repro.kernels.gqa_decode import gqa_decode_pallas
from repro.kernels.gqa_decode_ref import gqa_decode_reference


def _time(f, *args, reps=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / reps


#: the three BENCH_agg v2 shape buckets (B, m, p): the sweep engine's
#: regime, gradient-sized mid-p, model-gradient large-p.
AGG_BUCKETS = {"sweep": (320, 8, 10), "mid": (8, 8, 4096),
               "large": (1, 8, 262144)}
AGG_BUCKETS_FAST = {"sweep": (96, 8, 10), "mid": (4, 8, 1024),
                    "large": (1, 8, 16384)}


def _steady(f, reps):
    f()                                         # warm the jit caches
    t0 = time.perf_counter()
    for _ in range(reps):
        f()
    return (time.perf_counter() - t0) / reps


def bench_batched_agg(fast: bool = False, out_path: str = "BENCH_agg.json"):
    """Batched dcq_mad aggregation at the three dispatch shape buckets.

    Per bucket, three timed paths: ``batched_sorted`` (jit(vmap) of the
    sorted-jnp reference), ``batched_pallas`` (the order-statistics
    kernel with this bucket's TUNED tile/inner/n_bisect from the
    dispatch table, defaults when unmeasured) and ``auto``
    (``backend=None`` — whatever the measured dispatch table picks). The
    per-row ``loop_sorted`` fallback is timed at the sweep bucket only
    (it is what the batched refactor removed). Gates: the auto path must
    sit within ``AUTO_SLACK`` of the best measured backend at EVERY
    bucket — a stale or wrong dispatch table fails the bench, not just a
    slow kernel."""
    AUTO_SLACK = 1.2
    buckets = AGG_BUCKETS_FAST if fast else AGG_BUCKETS
    K, reps = 10, 5
    plat = jax.default_backend()
    result = {
        "schema": 2,
        "setting": {"method": "dcq_mad", "K": K, "reps": reps,
                    "fast": bool(fast), "device": jax.devices()[0].platform,
                    "jax": jax.__version__},
        "buckets": {},
    }
    ref_one = jax.jit(dcq_mad_reference)
    ref_batched = jax.jit(jax.vmap(dcq_mad_reference))
    table = dispatch.load_table(plat)
    for name, (B, m, p) in buckets.items():
        v = jax.random.normal(jax.random.PRNGKey(0), (B, m, p))
        hit = table.best("dcq_mad", B, m, p) if table is not None else None
        params = dict(hit[1]) if hit is not None and hit[0] == "pallas" \
            else {}
        dec = dispatch.decide("dcq_mad", B, m, p)

        def batched_sorted(v=v):
            return jax.block_until_ready(ref_batched(v))

        def batched_pallas(v=v, params=params):
            return jax.block_until_ready(
                ostat_pallas(v, "dcq_mad", K=K, **params))

        # jitted like every real consumer (the sweep engine and serve
        # step trace aggregate_batched inside their compiled steps; the
        # dispatch-table lookup resolves at trace time on static shapes)
        auto_fn = jax.jit(
            lambda vv: aggregate_batched(vv, method="dcq_mad", K=K))

        def auto(v=v, auto_fn=auto_fn):
            return jax.block_until_ready(auto_fn(v))

        # correctness at the bench shape before timing anything
        # (99.9th-percentile error: isolated CQ knot-threshold tie flips
        # are inherent at large p — see repro.agg.autotune._gate_err)
        from repro.agg.autotune import _gate_err
        oracle = batched_sorted()
        err = max(_gate_err(oracle, batched_pallas()),
                  _gate_err(oracle, auto()))
        assert err < 5e-4, f"{name}: kernel disagrees with oracle: {err}"

        backends = {"batched_sorted": _steady(batched_sorted, reps),
                    "batched_pallas": _steady(batched_pallas, reps),
                    "auto": _steady(auto, reps)}
        rec = {"B": B, "m": m, "p": p, "max_err_vs_oracle": err,
               "backends_s": backends,
               "auto_backend": dec.backend, "auto_source": dec.source,
               "pallas_params": params}
        if name == "sweep":
            def loop_sorted(v=v, B=B):
                outs = [ref_one(v[b]) for b in range(B)]
                jax.block_until_ready(outs[-1])
                return outs
            backends["loop_sorted"] = _steady(loop_sorted, reps)
            rec["speedup_auto_vs_loop"] = (backends["loop_sorted"]
                                           / backends["auto"])
        best = min(backends["batched_sorted"], backends["batched_pallas"])
        rec["best_measured_s"] = best
        rec["auto_vs_best"] = backends["auto"] / best
        rec["ok"] = rec["auto_vs_best"] <= AUTO_SLACK
        result["buckets"][name] = rec
        msg = "  ".join(f"{k}={t * 1e3:8.2f}ms"
                        for k, t in sorted(backends.items()))
        print(f"  [{name}] B={B} m={m} p={p}: {msg}")
        print(f"  [{name}] auto->{dec.backend} ({dec.source})  "
              f"auto/best={rec['auto_vs_best']:.2f}x  max|err|={err:.2e}  "
              f"{'PASS' if rec['ok'] else 'FAIL'}")
    result["ok"] = all(r["ok"] for r in result["buckets"].values())
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1)
        print(f"  wrote {out_path}")
    return result


def main(fast: bool = False, agg_out: str = "BENCH_agg.json"):
    print("== registered aggregators: Pallas kernel vs jnp reference ==")
    out = {}
    shapes = [(16, 4096), (64, 16384)] if not fast else [(16, 2048)]
    pallas_aggs = tuple(n for n in registered() if agg.has_pallas(n))
    for m, p in shapes:
        v = jax.random.normal(jax.random.PRNGKey(0), (m, p)) * 2.5
        errs = {}
        for method in pallas_aggs:
            scale = (jnp.abs(jax.random.normal(jax.random.PRNGKey(1),
                                               (p,))) + 0.1
                     if agg.get_aggregator(method).needs_scale else None)
            ref = aggregate(v, method, scale=scale, backend="reference")
            ker = aggregate(v, method, scale=scale, backend="pallas")
            errs[method] = float(jnp.abs(ref - ker).max())
        t_ref = _time(jax.jit(dcq_mad_reference), v)
        io_bytes = (m * p + p) * 4
        flops_est = 2 * 60 * m * p + 10 * m * p     # bisection + CQ sums
        ai = flops_est / io_bytes
        worst = max(errs.values())
        print(f"  m={m:4d} p={p:6d}: max|err|={worst:.2e} over "
              f"{len(errs)} aggregators  jnp_oracle(dcq_mad)="
              f"{t_ref*1e3:7.2f}ms  "
              f"arith-intensity~{ai:.1f} flop/byte (VPU-bound)")
        out[f"agg_{m}x{p}"] = {"errs": errs, "ai": ai}

    print("== batched aggregation (the sweep hot path) ==")
    out["batched_agg"] = bench_batched_agg(fast=fast, out_path=agg_out)

    print("== GQA flash-decode kernel (1 token vs cache) ==")
    for B, S, Hq, Hkv, Dh in ([(8, 4096, 32, 8, 128)] if not fast
                              else [(4, 1024, 8, 2, 64)]):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (B, Hq, Dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, Hkv, Dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, Hkv, Dh), jnp.float32)
        clen = jnp.full((B,), S, jnp.int32)
        ref = gqa_decode_reference(q, k, v, clen)
        ker = gqa_decode_pallas(q, k, v, clen, ts=512)
        err = float(jnp.abs(ref - ker).max())
        cache_bytes = 2 * B * S * Hkv * Dh * 4
        flops = 4 * B * Hq * S * Dh
        ai = flops / cache_bytes
        print(f"  B={B} S={S} Hq={Hq} Hkv={Hkv}: max|err|={err:.2e}  "
              f"cache={cache_bytes/1e6:.0f}MB/step  "
              f"arith-intensity={ai:.2f} flop/byte (HBM-bound: "
              f"roofline = cache_bytes/819GB/s)")
        out[f"gqa_{B}x{S}"] = {"err": err, "ai": ai}
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="reduced shapes (CI smoke)")
    ap.add_argument("--agg-out", default="BENCH_agg.json",
                    help="batched-aggregation benchmark record path")
    args = ap.parse_args()
    main(fast=args.fast, agg_out=args.agg_out)
