"""Kernel micro-benchmarks: jnp oracle vs Pallas(interpret) correctness at
bench shapes + HLO-derived arithmetic-intensity notes for the TPU target.

Wall-times on CPU interpret mode are NOT TPU performance — the meaningful
numbers here are bytes/FLOPs per call (printed for the roofline narrative)
and the correctness deltas at production-like shapes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.dcq import dcq_pallas
from repro.kernels.dcq_ref import dcq_mad_reference
from repro.kernels.gqa_decode import gqa_decode_pallas
from repro.kernels.gqa_decode_ref import gqa_decode_reference


def _time(f, *args, reps=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / reps


def main(fast: bool = False):
    print("== DCQ aggregation kernel (m x p -> p) ==")
    out = {}
    for m, p in [(16, 4096), (64, 16384)] if not fast else [(16, 2048)]:
        v = jax.random.normal(jax.random.PRNGKey(0), (m, p))
        ref = dcq_mad_reference(v)
        ker = dcq_pallas(v, tile=512)
        err = float(jnp.abs(ref - ker).max())
        t_ref = _time(jax.jit(dcq_mad_reference), v)
        io_bytes = (m * p + p) * 4
        flops_est = 2 * 60 * m * p + 10 * m * p     # bisection + CQ sums
        ai = flops_est / io_bytes
        print(f"  m={m:4d} p={p:6d}: max|err|={err:.2e}  "
              f"jnp_oracle={t_ref*1e3:7.2f}ms  "
              f"arith-intensity~{ai:.1f} flop/byte (VPU-bound)")
        out[f"dcq_{m}x{p}"] = {"err": err, "ai": ai}

    print("== GQA flash-decode kernel (1 token vs cache) ==")
    for B, S, Hq, Hkv, Dh in ([(8, 4096, 32, 8, 128)] if not fast
                              else [(4, 1024, 8, 2, 64)]):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (B, Hq, Dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, Hkv, Dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, Hkv, Dh), jnp.float32)
        clen = jnp.full((B,), S, jnp.int32)
        ref = gqa_decode_reference(q, k, v, clen)
        ker = gqa_decode_pallas(q, k, v, clen, ts=512)
        err = float(jnp.abs(ref - ker).max())
        cache_bytes = 2 * B * S * Hkv * Dh * 4
        flops = 4 * B * Hq * S * Dh
        ai = flops / cache_bytes
        print(f"  B={B} S={S} Hq={Hq} Hkv={Hkv}: max|err|={err:.2e}  "
              f"cache={cache_bytes/1e6:.0f}MB/step  "
              f"arith-intensity={ai:.2f} flop/byte (HBM-bound: "
              f"roofline = cache_bytes/819GB/s)")
        out[f"gqa_{B}x{S}"] = {"err": err, "ai": ai}
    return out


if __name__ == "__main__":
    main()
