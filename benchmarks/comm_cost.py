"""Paper §1.2(1)/§6: communication + privacy-budget comparison.

Bytes-per-machine and privacy budget for the three strategies at equal
total (eps, delta):

  quasi-Newton (Alg 1): 5 p-vectors
  Newton (Huang&Huo):   1 p-vector + p + p^2 (full Hessian)
  GD (Jordan et al.):   T p-vectors (T rounds)

plus the measured MRSE at equal budget, and the per-vector noise sigma the
budget forces (Thm 4.5) — the paper's core budget argument made concrete.

The byte model lives in repro/sweep/comm.py (shared with the sweep
artifact, which stamps the same numbers into every scenario record).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ProtocolConfig
from repro.core import DPQNProtocol, dp, get_problem
from repro.core.baselines import gd_estimator, newton_estimator
from repro.data.synthetic import make_shards, target_theta
from repro.sweep.comm import (gd_bytes_per_machine,
                              newton_bytes_per_machine,
                              qn_bytes_per_machine)


def main(fast: bool = False):
    m, n, p = 40, 1000, 10
    reps = 2 if fast else 4
    X, y = make_shards(jax.random.PRNGKey(0), "logistic", m, n, p)
    t = target_theta(p)
    prob = get_problem("logistic")
    cfg = ProtocolConfig(eps=30.0, delta=0.05)

    qn_bytes = qn_bytes_per_machine(p, cfg)
    newton_bytes = newton_bytes_per_machine(p)
    gd_rounds = 20
    gd_bytes = gd_bytes_per_machine(p, gd_rounds)

    def avg(f):
        return sum(f(r) for r in range(reps)) / reps

    err_qn = avg(lambda r: float(jnp.linalg.norm(DPQNProtocol(prob, cfg).run(
        jax.random.PRNGKey(r), X, y).theta_qn - t)))
    err_nt = avg(lambda r: float(jnp.linalg.norm(newton_estimator(
        prob, cfg, jax.random.PRNGKey(r), X, y).theta - t)))
    err_gd = avg(lambda r: float(jnp.linalg.norm(gd_estimator(
        prob, cfg, jax.random.PRNGKey(r), X, y, rounds=gd_rounds,
        lr=2.0).theta - t)))

    # per-transmission noise sigma at equal split of the budget
    s_vec = dp.s2_grad(p, n, 2.0, cfg.eps / 5, cfg.delta / 5)
    s_hess = dp.s2_grad(p * p, n, 2.0, cfg.eps / 4, cfg.delta / 4)
    s_gd = dp.s2_grad(p, n, 2.0, cfg.eps / gd_rounds, cfg.delta / gd_rounds)

    print("== communication / budget / accuracy at equal (eps, delta) ==")
    print(f"{'strategy':>14} {'bytes/machine':>14} {'rounds':>7} "
          f"{'noise sd':>10} {'MRSE':>8}")
    print(f"{'quasi-Newton':>14} {qn_bytes:14d} {5:7d} {s_vec:10.4f} "
          f"{err_qn:8.4f}")
    print(f"{'Newton':>14} {newton_bytes:14d} {2:7d} {s_hess:10.4f} "
          f"{err_nt:8.4f}")
    print(f"{'GD(20)':>14} {gd_bytes:14d} {gd_rounds:7d} {s_gd:10.4f} "
          f"{err_gd:8.4f}")
    # advanced composition (Cor 4.1) vs basic for the 5 rounds
    eb = cfg.eps
    ea, da = dp.compose_advanced(cfg.eps / 5, cfg.delta / 5, 5, 1e-3)
    print(f"5-round composition: basic eps={eb:.2f}, advanced (Cor 4.1) "
          f"eps={ea:.2f} (delta {da:.4f})")
    # the paper's budget argument is asymptotic in p: at p=100 the Hessian
    # round dwarfs any vector strategy
    p_big = 100
    qn_big = qn_bytes_per_machine(p_big, cfg)
    gd_big = gd_bytes_per_machine(p_big, gd_rounds)
    nt_big = newton_bytes_per_machine(p_big)
    print(f"at p={p_big}: qN {qn_big} B, GD(20) {gd_big} B, "
          f"Newton {nt_big} B per machine")
    ok = (qn_bytes < gd_bytes and qn_bytes < newton_bytes
          and qn_big < gd_big < nt_big
          and err_qn < err_nt and ea <= eb)
    print("PASS" if ok else "FAIL")
    return {"qn": [qn_bytes, err_qn], "newton": [newton_bytes, err_nt],
            "gd": [gd_bytes, err_gd], "ok": ok}


if __name__ == "__main__":
    main()
