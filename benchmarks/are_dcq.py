"""Paper claim §1.2(2): asymptotic relative efficiency of the aggregators.

Monte-Carlo ARE of median / trimmed / DCQ(K) vs the mean on normal samples
+ the closed-form D_K curve. Expected: median ~ 0.637, DCQ(10) ~ 0.955.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.agg import ARE_MEDIAN, are_dcq, d_k, dcq, trimmed_mean_agg


def monte_carlo_are(m: int = 500, reps: int = 2000, K: int = 10,
                    seed: int = 0):
    """Var(mean)/Var(est) over `reps` draws of m standard normals."""
    keys = jax.random.split(jax.random.PRNGKey(seed), reps)

    def one(key):
        x = jax.random.normal(key, (m, 1))
        med = jnp.median(x, axis=0)
        est_dcq = dcq(x, jnp.ones((1,)), K=K)[0]
        est_trim = trimmed_mean_agg(x, beta=0.2)[0]
        return x.mean(), med[0], est_dcq, est_trim

    mean, med, dq, tr = jax.vmap(one)(keys)
    v = jnp.var(mean)
    return {"median": float(v / jnp.var(med)),
            "dcq": float(v / jnp.var(dq)),
            "trimmed": float(v / jnp.var(tr))}


def main(fast: bool = False):
    print("== ARE of robust aggregators vs the mean (normal samples) ==")
    print(f"theory: median = 2/pi = {float(ARE_MEDIAN):.4f}; "
          f"DCQ(K): 1/D_K")
    for K in [1, 3, 5, 10, 20]:
        print(f"  K={K:3d}: D_K={d_k(K):.4f}  ARE={are_dcq(K):.4f}")
    est = monte_carlo_are(m=500, reps=400 if fast else 2000)
    print(f"monte-carlo (m=500): median={est['median']:.3f} "
          f"dcq(10)={est['dcq']:.3f} trimmed(0.2)={est['trimmed']:.3f}")
    ok = (abs(est["median"] - 0.637) < 0.12
          and est["dcq"] > 0.85)
    print("PASS" if ok else "FAIL",
          "(expect median~0.637, dcq~0.955, trimmed<dcq)")
    return {"theory_dcq10": are_dcq(10), **est, "ok": ok}


if __name__ == "__main__":
    main()
