"""Streaming aggregation service throughput: continuous batching at
fleet scale.

One :class:`repro.serve.AggregationService` per fleet size m — ingest of
m machine p-vectors through compiled block writes into the
device-resident ring buffer, then the single compiled masked-aggregation
step (registry rule + DP noise + ledger + model update) per round. The
benchmark measures the cold first round (including compilation) and the
steady-state rounds, reporting ingest-to-update latency and updates/sec
per fleet, and asserts the compile-once contract: across an entire
multi-round run each service must trace its step exactly once.

Writes BENCH_serve.json at the repo root:

    PYTHONPATH=src python -m benchmarks.serve_bench --fast

The nightly pipeline compares the record against the committed
benchmarks/baselines/BENCH_serve_fast.json via check_regression.py
(fifth gate): steady-state wall-clock at the largest fleet AND the
same-machine cold->steady amortization ratio must both regress >2x to
fail, so machine speed cancels out.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core.keys import stream_key
from repro.serve import AggregationService, ServeConfig

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(_REPO_ROOT, "BENCH_serve.json")

FLEETS = (64, 1024, 16384)


def _fleet_record(m: int, p: int, rounds: int, agg: str, eps: float,
                  ingest_block: int, seed: int) -> dict:
    cfg = ServeConfig(method=agg, capacity=m, eps=eps, dp_n=100,
                      lr=0.1, ingest_block=min(ingest_block, m),
                      seed=seed)
    svc = AggregationService(jnp.zeros(p, jnp.float32), cfg)
    data_key = stream_key(seed, "data")
    batches = [jax.random.normal(jax.random.fold_in(data_key, r), (m, p))
               for r in range(rounds)]
    jax.block_until_ready(batches)

    t0 = time.perf_counter()
    svc.submit_many(batches[0])          # capacity trigger flushes round 0
    t_cold = time.perf_counter() - t0    # includes every compile

    t0 = time.perf_counter()
    for r in range(1, rounds):
        svc.submit_many(batches[r])
    t_steady = (time.perf_counter() - t0) / max(1, rounds - 1)

    assert svc.round_idx == rounds, (svc.round_idx, rounds)
    lat = [h["latency_s"] for h in svc.history[1:]] or \
        [svc.history[0]["latency_s"]]
    return {
        "m": m,
        "cold_s": t_cold,
        "steady_s": t_steady,
        "updates_per_s": m / t_steady,
        "ingest_to_update_ms": 1e3 * sum(lat) / len(lat),
        "traces": svc.trace_counts,
        # compile-once per service: one step trace, at most one trace per
        # buffer writer, across the whole multi-flush run
        "ok": svc.trace_counts["step"] == 1
        and svc.trace_counts["write"] <= 1
        and svc.trace_counts["write_block"] <= 1,
    }


def measure(fleets=FLEETS, p: int = 10, rounds: int = 4,
            agg: str = "dcq_mad", eps: float = 1.0,
            ingest_block: int = 1024, seed: int = 0) -> dict:
    per_fleet = [_fleet_record(m, p, rounds, agg, eps, ingest_block, seed)
                 for m in fleets]
    top = per_fleet[-1]                  # the largest fleet is the gate
    return {
        "setting": {"fleets": list(fleets), "p": p, "rounds": rounds,
                    "agg": agg, "eps": eps, "ingest_block": ingest_block,
                    "device": jax.devices()[0].platform,
                    "jax": jax.__version__},
        "per_fleet": per_fleet,
        "serve_cold_s": top["cold_s"],
        "serve_steady_s": top["steady_s"],
        "speedup_steady": top["cold_s"] / top["steady_s"],
        "updates_per_s": top["updates_per_s"],
        "traces": max(f["traces"]["step"] for f in per_fleet),
        "ok": all(f["ok"] for f in per_fleet),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fleets", type=int, nargs="*", default=list(FLEETS))
    ap.add_argument("--p", type=int, default=10)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--agg", default="dcq_mad")
    ap.add_argument("--eps", type=float, default=1.0)
    ap.add_argument("--ingest-block", type=int, default=1024)
    ap.add_argument("--fast", action="store_true",
                    help="nightly/baseline setting (4 rounds, the "
                    "standard fleet ladder)")
    ap.add_argument("--out", default=BENCH_PATH)
    args = ap.parse_args(argv)
    fleets = list(FLEETS) if args.fast else args.fleets
    rounds = 4 if args.fast else args.rounds
    record = measure(fleets=fleets, p=args.p, rounds=rounds, agg=args.agg,
                     eps=args.eps, ingest_block=args.ingest_block)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(json.dumps(record, indent=1))
    print(f"wrote {args.out}")
    return 0 if record["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
