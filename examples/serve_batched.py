"""Continuous-batching example: the streaming aggregation service.

Machine updates stream in asynchronously and a single compiled step —
one trace for the whole run — serves a robust-DP model update every
time the flush policy fires. Three scenes:

  1. full fleets: capacity-triggered flushes, bulk block ingest;
  2. stragglers: a partial fleet flushed by an explicit deadline-style
     flush — same executable, the fill level is a traced scalar;
  3. backpressure: a policy that never auto-flushes, rejecting
     arrivals once the ring buffer is full.

    PYTHONPATH=src python examples/serve_batched.py
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import FlushPolicy, serve


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--machines", type=int, default=256,
                    help="fleet size per round (ring-buffer capacity)")
    ap.add_argument("--dim", type=int, default=10,
                    help="parameter dimension (the paper's p)")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--agg", default="dcq_mad")
    ap.add_argument("--eps", type=float, default=2.0)
    args = ap.parse_args(argv)
    m, p = args.machines, args.dim
    key, key2, key3 = jax.random.split(jax.random.PRNGKey(0), 3)

    print(f"=== scene 1: {args.rounds} full fleets of m={m}, "
          f"agg={args.agg}, eps={args.eps}/round ===")
    svc = serve(jnp.zeros(p), method=args.agg, capacity=m,
                eps=args.eps, lr=0.5, ingest_block=64)
    for r in range(args.rounds):
        updates = 1.0 + jax.random.normal(jax.random.fold_in(key, r),
                                          (m, p))
        svc.submit_many(updates)     # capacity trigger flushes the round
        h = svc.history[-1]
        print(f"  round {h['round']} fill {h['fill']:4d} "
              f"latency {h['latency_s']*1e3:6.2f} ms  theta[0] "
              f"{float(svc.theta[0]):+.3f}")
    print(f"  one executable across the run: traces={svc.trace_counts}")
    print(f"  privacy spend: basic composition "
          f"{svc.accountant.total_basic()}")

    print("=== scene 2: stragglers — 40% of the fleet never arrives ===")
    svc2 = serve(jnp.zeros(p), method=args.agg, capacity=m,
                 policy=FlushPolicy(capacity_frac=None, min_fill=8))
    arrived = int(0.6 * m)
    svc2.submit_many(jax.random.normal(key2, (arrived, p)))
    svc2.flush()                     # deadline fired: flush the partial fleet
    print(f"  flushed fill={svc2.history[-1]['fill']} of capacity {m} "
          f"with the same step (traces={svc2.trace_counts})")

    print("=== scene 3: backpressure — full buffer, no auto-flush ===")
    svc3 = serve(jnp.zeros(p), method="median", capacity=8,
                 policy=FlushPolicy(capacity_frac=None,
                                    backpressure="reject"))
    accepted = svc3.submit_many(jax.random.normal(key3, (12, p)))
    print(f"  accepted {accepted}/12, rejected {svc3.rejected} "
          f"(buffer capacity 8); explicit flush -> "
          f"{'ok' if svc3.flush() is not None else 'none'}")


if __name__ == "__main__":
    main()
