"""Batched serving example: prefill a batch of prompts with the chunked
flash path, then decode with the KV/state cache — across architecture
families (dense KV cache, hybrid SSM+shared-attention cache, xLSTM
matrix-memory state).

    PYTHONPATH=src python examples/serve_batched.py --gen 24
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import Model


def serve(arch: str, batch: int, prompt_len: int, gen: int):
    cfg = get_config(arch, reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = prompt_len + gen
    cache = model.init_cache(batch, max_len)
    key = jax.random.PRNGKey(1)
    if cfg.family == "audio":
        prompt = jax.random.randint(key, (batch, prompt_len,
                                          cfg.n_codebooks), 0, cfg.vocab)
    else:
        prompt = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)

    step = jax.jit(model.decode_step)
    t0 = time.time()
    logits = None
    for t in range(prompt_len):
        tok = prompt[:, t:t + 1]
        logits, cache = step(params, cache, {"tokens": tok})
    t_pre = time.time() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1)
    out = [tok]
    t0 = time.time()
    for _ in range(gen - 1):
        t = tok[:, None]
        if cfg.family == "audio":
            t = jnp.tile(t[..., None], (1, 1, cfg.n_codebooks))
        logits, cache = step(params, cache, {"tokens": t})
        tok = jnp.argmax(logits[:, -1], axis=-1)
        out.append(tok)
    t_gen = time.time() - t0
    rate = batch * gen / max(t_gen, 1e-9)
    print(f"  {arch:24s} [{cfg.family:6s}] prefill {t_pre:5.1f}s | "
          f"decode {rate:7.1f} tok/s | sample: "
          f"{jnp.stack(out, 1)[0][:8].tolist()}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--archs", nargs="*",
                    default=["glm4-9b", "qwen3-moe-30b-a3b", "zamba2-7b",
                             "xlstm-125m", "musicgen-medium"])
    args = ap.parse_args(argv)
    print("=== batched serving across families (reduced configs) ===")
    for arch in args.archs:
        serve(arch, args.batch, args.prompt_len, args.gen)


if __name__ == "__main__":
    main()
