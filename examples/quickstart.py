"""Quickstart: the paper's full pipeline in ~60 lines.

1. Run Algorithm 1 (robust DP quasi-Newton M-estimation) on synthetic
   logistic data with Byzantine machines — the reproduction.
2. Use the same DCQ aggregator to robustly train a small LM — the
   technique as a framework feature.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ProtocolConfig
from repro.core import DPQNProtocol, get_problem
from repro.data.lm import synthetic_lm_batches
from repro.data.synthetic import make_shards, target_theta
from repro.dist.grad_agg import GradAggConfig
from repro.models.model import Model
from repro.train.optimizer import AdamW
from repro.train.trainer import TrainConfig, Trainer


def part1_protocol():
    print("=== Part 1: DP robust quasi-Newton estimation (Algorithm 1) ===")
    m, n, p = 40, 1000, 10
    X, y = make_shards(jax.random.PRNGKey(0), "logistic", m, n, p)
    byz = jnp.zeros((m,), bool).at[:4].set(True)     # 10% Byzantine
    cfg = ProtocolConfig(eps=30.0, delta=0.05, K=10)
    proto = DPQNProtocol(get_problem("logistic"), cfg)
    res = proto.run(jax.random.PRNGKey(1), X, y, byz_mask=byz,
                    attack="scale", attack_factor=-3.0)
    t = target_theta(p)
    for name, est in [("theta_cq (init)", res.theta_cq),
                      ("theta_os (one-stage)", res.theta_os),
                      ("theta_qn (quasi-Newton)", res.theta_qn)]:
        print(f"  {name:24s} ||err|| = "
              f"{float(jnp.linalg.norm(est - t)):.4f}")
    print("  privacy:", *res.accountant.summary().splitlines()[-3:],
          sep="\n    ")


def part2_robust_training():
    print("=== Part 2: DCQ-robust DP training of an LM ===")
    cfg = get_config("xlstm-125m", reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(
        n_machines=4,
        agg=GradAggConfig(method="dcq", dp_sigma=1e-4,
                          attack="scale", attack_factor=-3.0))
    byz = jnp.array([True, False, False, False])     # 25% Byzantine
    trainer = Trainer(model, AdamW(lr=3e-3), tcfg)
    batches = synthetic_lm_batches(jax.random.PRNGKey(1), cfg, 30, 8, 64)
    losses = []
    trainer.fit(params, batches, jax.random.PRNGKey(2), byz_mask=byz,
                callback=lambda i, m: losses.append(float(m["loss"])))
    print(f"  loss {losses[0]:.3f} -> {losses[-1]:.3f} under 25% Byzantine"
          f" machines + DP noise (DCQ aggregation)")


if __name__ == "__main__":
    part1_protocol()
    part2_robust_training()
