"""End-to-end driver: robust DP QUASI-NEWTON training of an LLM.

Every optimizer step is one run of the paper's Algorithm 1 over the
model's parameter pytree — the same five-transmission protocol engine
(core/protocol.protocol_tree_rounds) that produces the p=10 logistic
figures, here driving xlstm-125m. Per-round the machines transmit theta,
gradients, L-BFGS directions, gradient differences and corrected
directions; every transmission is corrupted by a registry attack on the
Byzantine machines, noised per-leaf at each leaf's own DP calibration,
and combined by a registry aggregator.

The demo contrasts three settings on the reduced (toy-depth) config:
clean mean, mean under a sign-flip attack (degrades), and DCQ-MAD under
the same attack (the paper's aggregator; trains through it).

    PYTHONPATH=src python examples/robust_llm_training.py --steps 30
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint
from repro.configs import get_config
from repro.configs.base import TreeProtocolConfig
from repro.data.lm import synthetic_lm_batches
from repro.models.model import Model
from repro.train.trainer import QNTrainConfig, QNTrainer


def run(arch: str = "xlstm-125m", reduced: bool = True, steps: int = 30,
        batch: int = 8, seq: int = 32, machines: int = 4,
        aggregator: str = "dcq_mad", attack: str = "none",
        byz_frac: float = 0.0, eps: float = 0.0, hist: int = 5,
        lr: float = 0.3, seed: int = 0, log_every: int = 10):
    """One QN training run; returns (params, mem, losses).

    ``aggregator`` is any repro.agg registry name, ``attack`` any
    repro.attacks registry name/alias; ``eps > 0`` turns on per-leaf DP
    calibration (eps/5 per transmission, each leaf's sigma from its own
    dimension).
    """
    cfg = get_config(arch, reduced=reduced)
    model = Model(cfg, remat=True)
    params = model.init(jax.random.PRNGKey(seed))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    qcfg = QNTrainConfig(
        n_machines=machines, attack=attack,
        protocol=TreeProtocolConfig(hist=hist, lr=lr, eps=eps,
                                    aggregator=aggregator))
    n_byz = int(byz_frac * machines)
    byz = (jnp.arange(machines) < n_byz) if n_byz else None
    trainer = QNTrainer(model, qcfg)
    batches = synthetic_lm_batches(jax.random.PRNGKey(1), cfg, steps,
                                   batch, seq)
    losses = []
    t0 = time.time()

    def cb(i, m):
        losses.append(float(m["loss"]))
        if i % log_every == 0:
            print(f"    step {i:4d} loss {losses[-1]:.4f} "
                  f"({time.time()-t0:.0f}s)")

    params, mem, _ = trainer.fit(params, batches, jax.random.PRNGKey(2),
                                 byz_mask=byz, callback=cb)
    tag = f"{aggregator}{f' +{attack}' if n_byz else ''}"
    print(f"  [{tag}] {n_params/1e6:.1f}M params: "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return params, mem, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--full", action="store_true",
                    help="full 125M config (slow on CPU); default is the "
                    "reduced toy-depth variant")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--machines", type=int, default=4)
    ap.add_argument("--attack", default="signflip")
    ap.add_argument("--byzantine", type=float, default=0.25)
    ap.add_argument("--eps", type=float, default=0.0)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--ckpt", default="checkpoints/robust_llm.npz")
    args = ap.parse_args(argv)
    reduced = not args.full

    print(f"=== robust DP quasi-Newton training: {args.arch} "
          f"({'reduced' if reduced else 'full'}) ===")
    common = dict(arch=args.arch, reduced=reduced, steps=args.steps,
                  batch=args.batch, seq=args.seq, machines=args.machines,
                  eps=args.eps, lr=args.lr)
    print("-- clean mean baseline --")
    run(aggregator="mean", **common)
    print(f"-- mean under {args.byzantine:.0%} {args.attack} --")
    run(aggregator="mean", attack=args.attack, byz_frac=args.byzantine,
        **common)
    print(f"-- DCQ-MAD under {args.byzantine:.0%} {args.attack} "
          f"(the paper) --")
    params, mem, _ = run(aggregator="dcq_mad", attack=args.attack,
                         byz_frac=args.byzantine, **common)
    if args.ckpt:
        checkpoint.save(args.ckpt, params, {}, step=args.steps,
                        meta={"arch": args.arch, "agg": "dcq_mad",
                              "optimizer": "qn"})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
