"""End-to-end driver (deliverable b): train a ~100M-parameter model for a
few hundred steps with the paper's robust DP aggregation, comparing
mean vs DCQ under Byzantine machines.

The full xlstm-125m config (125M params) trains on CPU; pass --small for a
quick run on the reduced config.

    PYTHONPATH=src python examples/robust_llm_training.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint
from repro.configs import get_config
from repro.data.lm import synthetic_lm_batches
from repro.dist.grad_agg import GradAggConfig
from repro.models.model import Model
from repro.train.optimizer import AdamW
from repro.train.trainer import TrainConfig, Trainer


def run(arch: str, reduced: bool, steps: int, batch: int, seq: int,
        machines: int, method: str, byz_frac: float, dp_sigma: float,
        seed: int = 0):
    cfg = get_config(arch, reduced=reduced)
    model = Model(cfg, remat=True)
    params = model.init(jax.random.PRNGKey(seed))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    attack = "scale" if byz_frac > 0 else "none"
    tcfg = TrainConfig(
        n_machines=machines,
        agg=GradAggConfig(method=method, dp_sigma=dp_sigma, attack=attack,
                          attack_factor=-3.0))
    n_byz = int(byz_frac * machines)
    byz = (jnp.arange(machines) < n_byz) if n_byz else None
    trainer = Trainer(model, AdamW(lr=1e-3), tcfg)
    batches = synthetic_lm_batches(jax.random.PRNGKey(1), cfg, steps,
                                   batch, seq)
    losses = []
    t0 = time.time()

    def cb(i, m):
        losses.append(float(m["loss"]))
        if i % 20 == 0:
            print(f"    step {i:4d} loss {losses[-1]:.4f} "
                  f"({time.time()-t0:.0f}s)")

    params, opt_state, _ = trainer.fit(params, batches,
                                       jax.random.PRNGKey(2),
                                       byz_mask=byz, callback=cb)
    print(f"  [{method}{' +byz' if n_byz else ''}] {n_params/1e6:.0f}M "
          f"params: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return params, opt_state, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--machines", type=int, default=8)
    ap.add_argument("--byzantine", type=float, default=0.125)
    ap.add_argument("--dp-sigma", type=float, default=1e-4)
    ap.add_argument("--ckpt", default="checkpoints/robust_llm.npz")
    args = ap.parse_args(argv)

    print(f"=== robust LLM training: {args.arch} "
          f"({'reduced' if args.small else 'full'}) ===")
    print("-- clean mean baseline --")
    run(args.arch, args.small, args.steps, args.batch, args.seq,
        args.machines, "mean", 0.0, 0.0)
    print(f"-- mean under {args.byzantine:.0%} Byzantine --")
    run(args.arch, args.small, args.steps, args.batch, args.seq,
        args.machines, "mean", args.byzantine, 0.0)
    print(f"-- DCQ + DP under {args.byzantine:.0%} Byzantine (the paper) --")
    params, opt_state, _ = run(args.arch, args.small, args.steps,
                               args.batch, args.seq, args.machines, "dcq",
                               args.byzantine, args.dp_sigma)
    if args.ckpt:
        checkpoint.save(args.ckpt, params, opt_state, step=args.steps,
                        meta={"arch": args.arch, "agg": "dcq"})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
