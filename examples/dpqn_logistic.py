"""Paper Experiment 1 (scaled down): MRSE vs privacy budget for the three
estimators, normal and Byzantine, plus Newton/GD baselines and the
untrusted-center variant (§4.3).

The protocol curves run through the scenario-sweep engine: all eps points
x {clean, 10% Byzantine} form ONE jit group (eps and the Byzantine mask
ride the scenario vmap axis), so the whole table below costs a single
compilation. Baselines and the §4.3 variant stay on the direct API.

    PYTHONPATH=src python examples/dpqn_logistic.py [--reps 5]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ProtocolConfig
from repro.core import DPQNProtocol, get_problem
from repro.core.baselines import gd_estimator, newton_estimator
from repro.data.synthetic import make_shards, target_theta
from repro.sweep import Scenario, SweepExecutor


def mrse(estimates, target):
    return float(jnp.mean(jnp.array(
        [jnp.linalg.norm(e - target) for e in estimates])))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=50)
    ap.add_argument("--n", type=int, default=1000)
    ap.add_argument("--p", type=int, default=10)
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args(argv)

    m, n, p = args.m, args.n, args.p
    X, y = make_shards(jax.random.PRNGKey(0), "logistic", m, n, p)
    t = target_theta(p)
    prob = get_problem("logistic")

    eps_grid = [4, 10, 20, 30, 50]
    # one scenario per (eps, byzantine?) — all ten share one jit group
    def scen(eps, byz):
        return Scenario(problem="logistic", m=m, n=n, p=p, eps=float(eps),
                        delta=0.05, byz_frac=0.1 if byz else 0.0,
                        reps=args.reps, data_seed=0,
                        rep_seeds=tuple((200 if byz else 100) + r
                                        for r in range(args.reps)))
    scens = {(eps, byz): scen(eps, byz)
             for eps in eps_grid for byz in (False, True)}
    art = SweepExecutor().run(scens.values(), store_thetas=False)

    print(f"logistic regression, m={m} machines x n={n}, p={p}, "
          f"{args.reps} reps")
    print(f"{'eps':>5} | {'cq':>7} {'os':>7} {'qn':>7} | "
          f"{'qn byz':>7} | {'newton':>7} {'gd':>7}")
    for eps in eps_grid:
        cfg = ProtocolConfig(eps=float(eps), delta=0.05)
        met = art["scenarios"][scens[(eps, False)].scenario_id()]["metrics"]
        met_b = art["scenarios"][scens[(eps, True)].scenario_id()]["metrics"]
        # repro: allow(key-reuse) — historical baseline replicate schedule:
        # the EXPERIMENTS.md comparison table was recorded under these
        # exact keys; reps stay < the 100-seed offset gap.
        newt = [newton_estimator(prob, cfg, jax.random.PRNGKey(300 + r),
                                 X, y).theta for r in range(args.reps)]
        # repro: allow(key-reuse) — same recorded schedule as above.
        gd = [gd_estimator(prob, cfg, jax.random.PRNGKey(400 + r), X, y,
                           rounds=20, lr=2.0).theta
              for r in range(args.reps)]
        print(f"{eps:5d} | {met['mrse_cq']:7.4f} "
              f"{met['mrse_os']:7.4f} "
              f"{met['mrse_qn']:7.4f} | "
              f"{met_b['mrse_qn']:7.4f} | "
              f"{mrse(newt, t):7.4f} {mrse(gd, t):7.4f}")

    # noiseless reference + untrusted center
    cfg0 = ProtocolConfig(noiseless=True)
    r0 = DPQNProtocol(prob, cfg0).run(jax.random.PRNGKey(7), X, y)
    print(f"noiseless qn reference: {mrse([r0.theta_qn], t):7.4f}")
    cfg_u = ProtocolConfig(eps=30.0, delta=0.05, center_trust="untrusted")
    ru = DPQNProtocol(prob, cfg_u).run(jax.random.PRNGKey(8), X, y)
    print(f"untrusted-center (§4.3) qn: {mrse([ru.theta_qn], t):7.4f}")


if __name__ == "__main__":
    main()
