"""Minimal pytree optimizers (AdamW, SGD+momentum) — no external deps.

API mirrors optax: ``opt.init(params) -> state``, ``opt.update(grads,
state, params) -> (updates, state)``; apply with ``apply_updates``.
Optimizer state mirrors the param tree so it inherits param shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0        # global-norm clip; 0 disables

    def init(self, params: Any) -> AdamWState:
        def zeros(p):
            return jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree_util.tree_map(zeros, params),
                          nu=jax.tree_util.tree_map(zeros, params))

    def update(self, grads: Any, state: AdamWState, params: Any
               ) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        if self.grad_clip > 0:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m, v):
            u = -self.lr * (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if self.weight_decay > 0:
                u = u - self.lr * self.weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype)
        updates = jax.tree_util.tree_map(upd, params, mu, nu)
        return updates, AdamWState(step=step, mu=mu, nu=nu)


class SGDState(NamedTuple):
    step: jnp.ndarray
    mom: Any


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: float = 0.1
    momentum: float = 0.9

    def init(self, params: Any) -> SGDState:
        return SGDState(
            step=jnp.zeros((), jnp.int32),
            mom=jax.tree_util.tree_map(
                lambda p: jnp.zeros_like(p, jnp.float32), params))

    def update(self, grads: Any, state: SGDState, params: Any
               ) -> Tuple[Any, SGDState]:
        mom = jax.tree_util.tree_map(
            lambda m, g: self.momentum * m + g.astype(jnp.float32),
            state.mom, grads)
        updates = jax.tree_util.tree_map(
            lambda p, m: (-self.lr * m).astype(p.dtype), params, mom)
        return updates, SGDState(step=state.step + 1, mom=mom)


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree_util.tree_map(lambda p, u: p + u, params, updates)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                        for leaf in leaves))
