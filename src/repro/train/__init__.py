"""Training layer: optimizers + robust-DP trainer."""
from repro.train.optimizer import AdamW, SGD, apply_updates, global_norm
from repro.train.trainer import TrainConfig, Trainer, make_train_step

__all__ = ["AdamW", "SGD", "apply_updates", "global_norm", "TrainConfig",
           "Trainer", "make_train_step"]
