"""Training loop: per-machine gradients -> DP noise -> robust aggregation
-> optimizer update. The paper's technique as a first-class feature.

The global batch is split into ``n_machines`` groups (the paper's node
machines = data-parallel ranks; on a mesh the machine axis is sharded over
pod x data). ``jax.vmap`` over the machine axis yields one gradient per
machine; dist/grad_agg.py then applies the Gaussian mechanism + Byzantine
simulation + the robust aggregator; the aggregate feeds a standard
optimizer. With ``method="mean"``/``sigma=0``/no attack this reduces to
ordinary data-parallel training (psum) — asserted in tests.

Activation memory: the block scan is rematerialised (jax.checkpoint), so
live activations are one layer's, per machine, per microbatch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.grad_agg import GradAggConfig, robust_aggregate
from repro.models import sharding as shd
from repro.models.model import Model
from repro.train.optimizer import AdamW, apply_updates, global_norm


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    n_machines: int = 4
    microbatch: int = 0            # per-machine microbatch; 0 = whole batch
    remat: bool = True
    fsdp: bool = False             # ZeRO-style weight sharding over "data"
    grad_dtype: str = ""           # "" = native; "bfloat16" halves the
    #                                aggregation payload (§Perf knob)
    agg: GradAggConfig = GradAggConfig(method="mean")


def _split_machines(batch: Dict[str, jnp.ndarray], m: int):
    return jax.tree_util.tree_map(
        lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)


def make_loss_fn(model: Model, remat: bool = True):
    def loss_fn(params, batch):
        loss, aux = model.loss(params, batch)
        return loss, aux
    return loss_fn


def make_train_step(model: Model, opt: AdamW, tcfg: TrainConfig,
                    mesh: Optional[Mesh] = None):
    """Returns train_step(params, opt_state, batch, key, byz_mask) ->
    (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(model, tcfg.remat)
    m = tcfg.n_machines

    def machine_grad(params, mb):
        """Gradient of one machine's local loss (optionally microbatched)."""
        if tcfg.microbatch:
            B = mb["tokens"].shape[0]
            k = max(1, B // tcfg.microbatch)
            chunks = jax.tree_util.tree_map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), mb)

            def acc_step(carry, chunk):
                lsum, gsum = carry
                (lv, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, chunk)
                return (lsum + lv / k,
                        jax.tree_util.tree_map(
                            lambda a, b: a + b / k, gsum, g)), None
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros(()), zero), chunks)
            return loss, grads
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb)
        return loss, grads

    def train_step(params, opt_state, batch, key,
                   byz_mask: Optional[jnp.ndarray] = None):
        mb = _split_machines(batch, m)
        losses, grads = jax.vmap(lambda b: machine_grad(params, b))(mb)
        if tcfg.grad_dtype:
            dt = jnp.dtype(tcfg.grad_dtype)
            grads = jax.tree_util.tree_map(lambda g: g.astype(dt), grads)
        machine_specs = None
        if mesh is not None:
            # machine axis on pod x data; payload dims keep the PARAM
            # sharding (dropping it replicates every machine's grad over
            # the model axis — a 16x memory/collective blow-up, found and
            # fixed in EXPERIMENTS.md §Perf HC-train it1).
            ax = shd.batch_axes(mesh)

            def mspec(kp, g):
                path = tuple(str(getattr(k, "key", getattr(k, "idx", "")))
                             for k in kp)
                ps = shd.param_spec(path, tuple(g.shape[1:]), mesh,
                                    fsdp=tcfg.fsdp)
                return P(*((ax,) + tuple(ps)))
            machine_specs = jax.tree_util.tree_map_with_path(mspec, grads)
            grads = jax.lax.with_sharding_constraint(
                grads, jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, s), machine_specs))
        if tcfg.agg.strategy != "sharded":
            machine_specs = None
        agg = robust_aggregate(grads, tcfg.agg, key, byz_mask,
                               mesh=mesh, machine_specs=machine_specs)
        updates, opt_state = opt.update(agg, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": losses.mean(),
                   "loss_per_machine": losses,
                   "grad_norm": global_norm(agg)}
        return params, opt_state, metrics

    return train_step


class Trainer:
    """Convenience loop for the examples: synthetic LM data, logging."""

    def __init__(self, model: Model, opt: AdamW, tcfg: TrainConfig,
                 mesh: Optional[Mesh] = None):
        self.model, self.opt, self.tcfg = model, opt, tcfg
        self.step_fn = jax.jit(make_train_step(model, opt, tcfg, mesh))

    def fit(self, params, batches, key, byz_mask=None, log_every: int = 10,
            callback=None):
        opt_state = self.opt.init(params)
        history = []
        for i, batch in enumerate(batches):
            key, sub = jax.random.split(key)
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch, sub, byz_mask)
            if i % log_every == 0 or callback:
                loss = float(metrics["loss"])
                history.append({"step": i, "loss": loss})
                if callback:
                    callback(i, metrics)
        return params, opt_state, history
