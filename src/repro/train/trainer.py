"""Training loop: per-machine gradients -> DP noise -> robust aggregation
-> optimizer update. The paper's technique as a first-class feature.

The global batch is split into ``n_machines`` groups (the paper's node
machines = data-parallel ranks; on a mesh the machine axis is sharded over
pod x data). ``jax.vmap`` over the machine axis yields one gradient per
machine; dist/grad_agg.py then applies the Gaussian mechanism + Byzantine
simulation + the robust aggregator; the aggregate feeds a standard
optimizer. With ``method="mean"``/``sigma=0``/no attack this reduces to
ordinary data-parallel training (psum) — asserted in tests.

Activation memory: the block scan is rematerialised (jax.checkpoint), so
live activations are one layer's, per machine, per microbatch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import TreeProtocolConfig
from repro.core.protocol import protocol_tree_rounds
from repro.dist.collectives import tree_machine_specs
from repro.dist.grad_agg import (GradAggConfig, robust_aggregate,
                                 spend_record)
from repro.models.model import Model
from repro.train.optimizer import AdamW, apply_updates, global_norm


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    n_machines: int = 4
    microbatch: int = 0            # per-machine microbatch; 0 = whole batch
    remat: bool = True
    fsdp: bool = False             # ZeRO-style weight sharding over "data"
    grad_dtype: str = ""           # "" = native; "bfloat16" halves the
    #                                aggregation payload (§Perf knob)
    agg: GradAggConfig = dataclasses.field(
        default_factory=lambda: GradAggConfig(method="mean"))


def _split_machines(batch: Dict[str, jnp.ndarray], m: int):
    return jax.tree_util.tree_map(
        lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)


def make_loss_fn(model: Model, remat: bool = True):
    def loss_fn(params, batch):
        loss, aux = model.loss(params, batch)
        return loss, aux
    return loss_fn


def make_train_step(model: Model, opt: AdamW, tcfg: TrainConfig,
                    mesh: Optional[Mesh] = None):
    """Returns train_step(params, opt_state, batch, key, byz_mask) ->
    (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(model, tcfg.remat)
    m = tcfg.n_machines

    def machine_grad(params, mb):
        """Gradient of one machine's local loss (optionally microbatched)."""
        if tcfg.microbatch:
            B = mb["tokens"].shape[0]
            k = max(1, B // tcfg.microbatch)
            chunks = jax.tree_util.tree_map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), mb)

            def acc_step(carry, chunk):
                lsum, gsum = carry
                (lv, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, chunk)
                return (lsum + lv / k,
                        jax.tree_util.tree_map(
                            lambda a, b: a + b / k, gsum, g)), None
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros(()), zero), chunks)
            return loss, grads
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb)
        return loss, grads

    def train_step(params, opt_state, batch, key,
                   byz_mask: Optional[jnp.ndarray] = None):
        mb = _split_machines(batch, m)
        losses, grads = jax.vmap(lambda b: machine_grad(params, b))(mb)
        if tcfg.grad_dtype:
            dt = jnp.dtype(tcfg.grad_dtype)
            grads = jax.tree_util.tree_map(lambda g: g.astype(dt), grads)
        machine_specs = None
        if mesh is not None:
            # machine axis on pod x data; payload dims keep the PARAM
            # sharding (collectives.tree_machine_specs — dropping it
            # replicates every machine's grad over the model axis, a 16x
            # blow-up; EXPERIMENTS.md §Perf HC-train it1).
            machine_specs = tree_machine_specs(grads, mesh, fsdp=tcfg.fsdp)
            grads = jax.lax.with_sharding_constraint(
                grads, jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, s), machine_specs))
        if tcfg.agg.strategy != "sharded":
            machine_specs = None
        agg = robust_aggregate(grads, tcfg.agg, key, byz_mask,
                               mesh=mesh, machine_specs=machine_specs)
        updates, opt_state = opt.update(agg, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": losses.mean(),
                   "loss_per_machine": losses,
                   "grad_norm": global_norm(agg)}
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------- quasi-Newton (protocol)

@dataclasses.dataclass(frozen=True)
class QNTrainConfig:
    """Robust DP quasi-Newton training: every optimizer step IS one run of
    Algorithm 1's five transmissions over the parameter pytree."""
    n_machines: int = 4
    protocol: TreeProtocolConfig = dataclasses.field(
        default_factory=TreeProtocolConfig)
    attack: str = "none"           # repro.attacks registry name/alias
    attack_factor: float = -3.0
    remat: bool = True


def make_qn_train_step(model: Model, qcfg: QNTrainConfig,
                       mesh: Optional[Mesh] = None):
    """Returns train_step(params, mem, batch, key, byz_mask) ->
    (params, mem, metrics): one five-transmission protocol step
    (core.protocol.protocol_tree_rounds). The curvature state ``mem`` is
    the per-machine L-BFGS history from the SHARED core/bfgs.py
    implementation — the same two-loop the convex head uses, not a
    reimplementation — threaded through successive steps.

    ``n`` for the per-leaf DP calibration is the per-machine batch size
    (each batch row is one sample draw from the machine's shard).
    """
    loss_fn = make_loss_fn(model, qcfg.remat)
    m = qcfg.n_machines

    def grad_fn(params, mb):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb)
        return loss, grads

    def train_step(params, mem, batch, key,
                   byz_mask: Optional[jnp.ndarray] = None):
        mb = _split_machines(batch, m)
        n = jax.tree_util.tree_leaves(mb)[0].shape[1]
        if mesh is not None:
            # machine axis over the mesh's batch axes, payload dims on the
            # param rules — GSPMD propagates these through all five rounds
            specs = tree_machine_specs(mb, mesh)
            mb = jax.lax.with_sharding_constraint(
                mb, jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, s), specs))
        out = protocol_tree_rounds(
            key, params, mb, grad_fn, qcfg.protocol, mem=mem,
            byz_mask=byz_mask, attack=qcfg.attack,
            attack_factor=qcfg.attack_factor, n=n)
        metrics = {"loss": out.losses.mean(),
                   "loss_per_machine": out.losses,
                   "grad_norm": out.grad_norm}
        return out.theta_qn, out.mem, metrics

    return train_step


class QNTrainer:
    """Protocol-driven loop: the model zoo trained by the SAME engine as
    the p=10 convex head — five DP transmissions, registry attacks and
    aggregators, per-leaf calibrated noise, L-BFGS curvature memory."""

    def __init__(self, model: Model, qcfg: QNTrainConfig,
                 mesh: Optional[Mesh] = None):
        self.model, self.qcfg = model, qcfg
        self.step_fn = jax.jit(make_qn_train_step(model, qcfg, mesh))

    def init_memory(self, params):
        from repro.core.bfgs import LBFGSMemory
        return LBFGSMemory.init_like(self.qcfg.protocol.hist, params,
                                     machines=self.qcfg.n_machines)

    def fit(self, params, batches, key, byz_mask=None, log_every: int = 10,
            callback=None):
        mem = self.init_memory(params)
        history = []
        for i, batch in enumerate(batches):
            key, sub = jax.random.split(key)
            params, mem, metrics = self.step_fn(
                params, mem, batch, sub, byz_mask)
            if i % log_every == 0 or callback:
                history.append({"step": i, "loss": float(metrics["loss"])})
                if callback:
                    callback(i, metrics)
        return params, mem, history


class Trainer:
    """Convenience loop for the examples: synthetic LM data, logging."""

    def __init__(self, model: Model, opt: AdamW, tcfg: TrainConfig,
                 mesh: Optional[Mesh] = None):
        self.model, self.opt, self.tcfg = model, opt, tcfg
        self.step_fn = jax.jit(make_train_step(model, opt, tcfg, mesh))
        self.ledger = None  # populated by fit(): per-step DP spend records

    def fit(self, params, batches, key, byz_mask=None, log_every: int = 10,
            callback=None):
        opt_state = self.opt.init(params)
        # every step transmits one noised gradient pytree; the noise
        # config is static, so one per-step ledger entry covers them all
        # (basic composition: total spend = steps * per-step budget)
        per_step = spend_record(params, self.tcfg.agg, name="grad step")
        steps = 0
        history = []
        for i, batch in enumerate(batches):
            key, sub = jax.random.split(key)
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch, sub, byz_mask)
            steps = i + 1
            if i % log_every == 0 or callback:
                loss = float(metrics["loss"])
                history.append({"step": i, "loss": loss})
                if callback:
                    callback(i, metrics)
        eps = self.tcfg.agg.dp_eps
        self.ledger = {"per_step": per_step, "steps": steps,
                       "total_eps": steps * eps if eps > 0 else None}
        return params, opt_state, history
