"""repro: Distributed quasi-Newton robust estimation under differential
privacy (Wang, Zhu & Zhu 2024) as a production JAX framework."""
from repro import compat

# Fill mesh-API gaps (AxisType, make_mesh axis_types, set_mesh, shard_map)
# on older jax before any mesh-building code runs.
compat.install()

__version__ = "1.0.0"
