"""repro: Distributed quasi-Newton robust estimation under differential
privacy (Wang, Zhu & Zhu 2024) as a production JAX framework."""
__version__ = "1.0.0"
