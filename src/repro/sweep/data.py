"""Scenario -> arrays: data builders, Byzantine masks, replicate keys, and
per-scenario metrics. Kept separate from the executor so presets and tests
can reproduce exactly what a scenario feeds the compiled protocol core.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import monte_carlo_mrse
from repro.data.synthetic import (digits_like_dataset, make_shards,
                                  target_theta)
from repro.sweep.grid import Scenario

#: held-out rows for the digits pipeline (screening + test, table1 layout)
_DIGITS_SCREEN = 4000
_DIGITS_TEST = 4000


def byz_mask(scenario: Scenario) -> jnp.ndarray:
    """(m,) bool mask over NODE machines: the first floor(byz_frac * m)
    are Byzantine (the deterministic layout every benchmark preset uses;
    machine order is exchangeable for i.i.d. shards)."""
    mask = jnp.zeros((scenario.m,), bool)
    nb = scenario.n_byzantine()
    return mask.at[:nb].set(True) if nb else mask


def replicate_keys(scenario: Scenario) -> jnp.ndarray:
    """(reps, 2) PRNG keys. Explicit ``rep_seeds`` reproduce a benchmark's
    historical key schedule; otherwise keys derive deterministically from
    the scenario id so resumed sweeps repeat the same draws."""
    if scenario.rep_seeds is not None:
        return jnp.stack([jax.random.PRNGKey(s) for s in scenario.rep_seeds])
    sid_hash = int.from_bytes(
        hashlib.sha1(scenario.scenario_id().encode()).digest()[:4], "big")
    base = jax.random.PRNGKey(sid_hash)
    return jax.random.split(base, scenario.reps)


def screen_features(X, y, k: int) -> jnp.ndarray:
    """Lasso-style screening stand-in: top-k |two-sample t| features
    (shared with the Table 1 benchmark)."""
    mu1 = X[y == 1].mean(0)
    mu0 = X[y == 0].mean(0)
    s = X.std(0) + 1e-9
    t = jnp.abs(mu1 - mu0) / s
    return jnp.argsort(-t)[:k]


def build_data(scenario: Scenario
               ) -> Tuple[jnp.ndarray, jnp.ndarray, Dict]:
    """(X, y, aux): X (m+1, n, p), y (m+1, n); aux carries what the metric
    needs — the target parameter for synthetic designs, the held-out test
    split for digits."""
    if scenario.dataset == "synthetic":
        X, y = make_shards(jax.random.PRNGKey(scenario.data_seed),
                           scenario.problem, scenario.m, scenario.n,
                           scenario.p)
        return X, y, {"target": target_theta(scenario.p)}
    if scenario.dataset == "digits":
        m, n, k = scenario.m, scenario.n, scenario.p
        n_total = (m + 1) * n + _DIGITS_TEST
        X, y, _ = digits_like_dataset(scenario.data_seed, n_total,
                                      pair=scenario.pair)
        cols = screen_features(X[:_DIGITS_SCREEN], y[:_DIGITS_SCREEN], k)
        Xs = X[:, cols]
        Xtr = Xs[:(m + 1) * n].reshape(m + 1, n, -1)
        ytr = y[:(m + 1) * n].reshape(m + 1, n)
        return Xtr, ytr, {"Xte": Xs[-_DIGITS_TEST:], "yte": y[-_DIGITS_TEST:]}
    raise ValueError(f"unknown dataset {scenario.dataset!r}")


def compute_metrics(scenario: Scenario, thetas: Dict[str, jnp.ndarray],
                    aux: Dict) -> Dict[str, float]:
    """Per-scenario summary metrics from the (reps, p) estimator stacks."""
    if scenario.dataset == "synthetic":
        t = aux["target"]
        return {f"mrse_{name}": monte_carlo_mrse(thetas[name], t)
                for name in ("cq", "os", "qn")}
    if scenario.dataset == "digits":
        Xte, yte = aux["Xte"], aux["yte"]
        preds = (jax.nn.sigmoid(thetas["qn"] @ Xte.T) > 0.5
                 ).astype(jnp.float32)
        return {"accuracy": float((preds == yte[None, :]).mean())}
    raise ValueError(f"unknown dataset {scenario.dataset!r}")
