"""The sweep executor: one compiled executable per jit group.

Scenarios are bucketed by ``Scenario.group_key()`` (static config +
shapes). For each group the executor builds ONE function —

    jit(vmap_scenarios(vmap_replicates(protocol_rounds)))

— and pushes the whole group through it in a single call: the scenario
axis carries data, Byzantine masks, privacy budgets (as host-calibrated
``sigma_base`` rows, bit-identical to the compile-once static path), and
attack factors; the replicate axis carries PRNG keys. A grid over
eps x alpha x seeds therefore compiles once per (loss, attack, aggregator,
trust, shape) combination instead of once per point.

``trace_counts`` counts actual retraces per group; tests assert each group
compiles exactly one executable. Passing a ``mesh`` swaps the machine map
for the shard_map SPMD implementation (dist/sharded_protocol.py) and
shards every scenario's machine axis over the mesh — the sweep path and
the multi-device path are the same code.

Oversized jit groups are CHUNKED: with ``chunk_size=c`` a group larger
than ``c`` runs as ceil(len/c) batches of exactly ``c`` scenario rows
(the last chunk is padded by repeating its final scenario, so every chunk
reuses the single compiled executable — the compile-once contract holds),
bounding peak memory at ``c * reps`` replicates per launch. The artifact
is written atomically after every chunk, so an interrupted oversized
group resumes from its completed chunks.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import n_transmissions, protocol_rounds, vmap_machines
from repro.core.protocol import calibrate_sigma_base
from repro.sweep import artifact as artifact_mod
from repro.sweep.comm import comm_record
from repro.sweep.data import (build_data, byz_mask, compute_metrics,
                              replicate_keys)
from repro.sweep.grid import (Scenario, TrainScenario, group_label,
                              group_scenarios)


class SweepExecutor:
    """Runs scenario lists through per-jit-group compiled engines.

    One executor instance caches one engine per group key, so successive
    ``run`` calls (e.g. a resumed sweep in the same process) reuse compiled
    executables. ``trace_counts[group_key]`` is the number of times the
    group's engine was traced — the compile-counter contract is that it
    stays at 1 no matter how many scenarios or calls ride through it.
    """

    def __init__(self, mesh=None,
                 progress: Optional[Callable[[str], None]] = None,
                 chunk_size: Optional[int] = None):
        self.mesh = mesh
        if mesh is None:
            self._mmap = vmap_machines
        else:
            from repro.dist.sharded_protocol import machine_map
            self._mmap = machine_map(mesh, mesh.axis_names[0])
        self.progress = progress or (lambda msg: None)
        if chunk_size is not None and chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        self.chunk_size = chunk_size
        self.trace_counts: Dict[Tuple, int] = {}
        self._engines: Dict[Tuple, Callable] = {}
        self._data_cache: Dict[Tuple, Tuple] = {}

    # ------------------------------------------------------------- engines

    def _engine(self, scenario: Scenario) -> Callable:
        gkey = scenario.group_key()
        if gkey in self._engines:
            return self._engines[gkey]
        cfg = scenario.protocol_config()
        problem = _problem_for(scenario)
        attack = scenario.attack
        mmap = self._mmap
        self.trace_counts[gkey] = 0

        def one_rep(key, X, y, mask, eps, delta, factor, sigma_base):
            self.trace_counts[gkey] += 1
            return protocol_rounds(
                key, X, y, problem, cfg, byz_mask=mask, attack=attack,
                attack_factor=factor, eps=eps, delta=delta,
                sigma_base=sigma_base, machine_map=mmap)

        over_reps = jax.vmap(one_rep, in_axes=(0,) + (None,) * 7)
        over_scenarios = jax.vmap(over_reps, in_axes=(0,) * 8)
        engine = jax.jit(over_scenarios)
        self._engines[gkey] = engine
        return engine

    def _train_engine(self, scenario: TrainScenario):
        """One compiled protocol train STEP per zoo group: eps rides as
        traced per-leaf sigma trees, byz_frac as the mask, attack_factor
        as a traced scalar and the PRNG key per step — every scenario in
        the group (and every step of every scenario) reuses the single
        executable, extending the compile-once contract to training."""
        gkey = scenario.group_key()
        if gkey in self._engines:
            return self._engines[gkey]
        from repro.configs import get_config
        from repro.core.protocol import protocol_tree_rounds
        from repro.models.model import Model
        cfg = get_config(scenario.arch, reduced=True)
        model = Model(cfg, remat=True)
        tcfg = scenario.protocol_config()
        attack = scenario.attack
        mmap = self._mmap
        self.trace_counts[gkey] = 0

        def grad_fn(params, mb):
            (loss, _), g = jax.value_and_grad(
                model.loss, has_aux=True)(params, mb)
            return loss, g

        def step(key, params, mem, mb, mask, factor, sigmas):
            self.trace_counts[gkey] += 1
            return protocol_tree_rounds(
                key, params, mb, grad_fn, tcfg, mem=mem, byz_mask=mask,
                attack=attack, attack_factor=factor, sigmas=sigmas,
                machine_map=mmap)

        engine = (jax.jit(step), model, cfg)
        self._engines[gkey] = engine
        return engine

    def _run_train_group(self, gkey, scens: List[TrainScenario],
                         label: str) -> List[Dict]:
        """Run one zoo jit group scenario-by-scenario through its shared
        compiled step; returns one artifact record per scenario."""
        from repro.core import dp
        from repro.core.bfgs import LBFGSMemory
        from repro.core.transport import tree_size
        from repro.data.lm import make_batch
        from repro.train.trainer import _split_machines
        step_fn, model, cfg = self._train_engine(scens[0])
        records = []
        for s in scens:
            m = s.machines
            params = model.init(jax.random.PRNGKey(s.seed))
            mem = LBFGSMemory.init_like(s.hist, params, machines=m)
            mask = jnp.arange(m) < s.n_byzantine()
            if s.eps > 0:
                sigmas = jax.tree_util.tree_map(
                    lambda v: jnp.float32(v),
                    dp.calibrate_tree_sigmas(
                        params, s.n_per_machine(), s.eps, s.delta,
                        (s.gamma,) * 5, s.tail,
                        accountant=s.accountant))
            else:
                sigmas = {name: jnp.float32(0.0)
                          for name in dp.TREE_TRANSMISSIONS}
            # repro: allow(key-reuse) — historical derivation: every preset
            # artifact (and tests/golden/zoo_smoke.json) is byte-pinned to
            # these exact streams; new code uses repro.core.keys.stream_key.
            key = jax.random.PRNGKey(1000 + s.seed)
            # repro: allow(key-reuse) — same historical pin as above.
            data_key = jax.random.PRNGKey(s.seed + 1)
            t0 = time.perf_counter()
            losses, gnorm = [], 0.0
            for i in range(s.steps):
                batch = make_batch(jax.random.fold_in(data_key, i), cfg,
                                   s.batch, s.seq)
                mb = _split_machines(batch, m)
                if self.mesh is not None:
                    from jax.sharding import NamedSharding
                    from jax.sharding import PartitionSpec as P
                    sharding = NamedSharding(
                        self.mesh, P(self.mesh.axis_names[0]))
                    mb = jax.tree_util.tree_map(
                        lambda x: jax.device_put(x, sharding), mb)
                key, sub = jax.random.split(key)
                out = step_fn(sub, params, mem, mb, mask,
                              jnp.float32(s.attack_factor), sigmas)
                params, mem = out.theta_qn, out.mem
                losses.append(float(out.losses.mean()))
                gnorm = float(out.grad_norm)
            dt = time.perf_counter() - t0
            p_total = tree_size(params)
            records.append({
                "scenario": s.to_json(),
                "metrics": {"loss_first": losses[0],
                            "loss_last": losses[-1],
                            "loss_drop": losses[0] - losses[-1],
                            "losses": losses,
                            "grad_norm_last": gnorm},
                "spend": _train_spend_record(s, params),
                "comm": {"n_transmissions": len(dp.TREE_TRANSMISSIONS),
                         "bytes_per_round": 4 * p_total,
                         "bytes_per_machine":
                             4 * p_total * len(dp.TREE_TRANSMISSIONS),
                         "n_params": p_total,
                         "eps_per_round":
                             s.eps / len(dp.TREE_TRANSMISSIONS),
                         "delta_per_round":
                             s.delta / len(dp.TREE_TRANSMISSIONS)},
                "thetas_qn": None,
                "timing": {"group": label,
                           "group_seconds": dt, "group_size": len(scens),
                           "steps": s.steps,
                           "traces": self.trace_counts[s.group_key()]},
            })
        return records

    # ------------------------------------------------------------- batching

    def _data_for(self, s: Scenario):
        """build_data memoized on the fields that determine the arrays —
        a fig-eps group's five budgets share one dataset, so the shards
        are built once, not once per scenario."""
        key = (s.dataset, s.problem, s.m, s.n, s.p, s.data_seed, s.pair)
        if key not in self._data_cache:
            self._data_cache[key] = build_data(s)
        return self._data_cache[key]

    def _batch_inputs(self, scens: List[Scenario]):
        """Stack the dynamic axes of one jit group. Every scenario gets its
        own data/mask/budget row; replicate keys ride the inner axis."""
        X_rows, y_rows, auxes = [], [], []
        for s in scens:
            X, y, aux = self._data_for(s)
            X_rows.append(X)
            y_rows.append(y)
            auxes.append(aux)
        X = jnp.stack(X_rows)
        y = jnp.stack(y_rows)
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            axis = self.mesh.axis_names[0]
            n_dev = self.mesh.shape[axis]
            if X.shape[1] % n_dev:
                raise ValueError(
                    f"{X.shape[1]} machines do not shard evenly over "
                    f"{n_dev} devices on axis {axis!r}")
            spec = NamedSharding(self.mesh, P(None, axis))
            X = jax.device_put(X, spec)
            y = jax.device_put(y, spec)
        keys = jnp.stack([replicate_keys(s) for s in scens])
        masks = jnp.stack([byz_mask(s) for s in scens])
        eps = jnp.asarray([s.eps for s in scens], jnp.float32)
        delta = jnp.asarray([s.delta for s in scens], jnp.float32)
        factors = jnp.asarray([s.attack_factor for s in scens], jnp.float32)
        # float64 host calibration per scenario -> bit-parity with the
        # static compile-once path (see core/protocol.calibrate_sigma_base)
        sigma_rows = np.stack([
            np.asarray(calibrate_sigma_base(
                s.protocol_config(), s.p, s.n), np.float32)
            for s in scens])
        return (keys, X, y, masks, eps, delta, factors,
                jnp.asarray(sigma_rows)), auxes

    # ------------------------------------------------------------------ run

    def run(self, scenarios: Iterable[Scenario],
            artifact_path: Optional[str] = None, resume: bool = True,
            store_thetas: bool = True, meta: Optional[Dict] = None) -> Dict:
        """Execute scenarios group-by-group; returns the artifact dict.

        With ``artifact_path`` the artifact is written atomically after
        every jit group, and (when ``resume``) scenarios already present
        in a schema-valid artifact at that path are skipped.
        """
        scenarios = list(scenarios)
        art = artifact_mod.new_artifact(meta=_run_meta(meta))
        done: set = set()
        if artifact_path and resume:
            done = artifact_mod.load_done_ids(artifact_path)
            if done:
                art = artifact_mod.load(artifact_path)
                art["meta"].update(_run_meta(meta))
        pending = [s for s in scenarios
                   if s.scenario_id() not in done]
        skipped = len(scenarios) - len(pending)
        if skipped:
            self.progress(f"resume: {skipped} scenario(s) already in "
                          f"{artifact_path}, {len(pending)} to run")
        groups = group_scenarios(pending)
        for gi, (gkey, scens) in enumerate(groups.items()):
            label = group_label(gkey)
            if gkey[0] == "zoo":
                self.progress(f"[group {gi + 1}/{len(groups)}] {label}: "
                              f"{len(scens)} training run(s) x "
                              f"{scens[0].steps} step(s)")
                for s, record in zip(scens,
                                     self._run_train_group(gkey, scens,
                                                           label)):
                    art["scenarios"][s.scenario_id()] = record
                if artifact_path:
                    artifact_mod.save(art, artifact_path)
                continue
            chunks = self._chunks(scens)
            tag = (f" in {len(chunks)} chunk(s) of <= {self.chunk_size}"
                   if len(chunks) > 1 else "")
            self.progress(f"[group {gi + 1}/{len(groups)}] {label}: "
                          f"{len(scens)} scenario(s) x {scens[0].reps} reps"
                          f"{tag}")
            engine = self._engine(scens[0])
            for ci, chunk in enumerate(chunks):
                # pad split chunks to the fixed chunk_size by repeating the
                # last scenario: every chunk reuses the ONE compiled
                # executable (padded rows are dropped below).
                n_real = len(chunk)
                padded = chunk
                if len(chunks) > 1 and n_real < self.chunk_size:
                    padded = chunk + [chunk[-1]] * (self.chunk_size - n_real)
                inputs, auxes = self._batch_inputs(padded)
                t0 = time.perf_counter()
                arrs = engine(*inputs)
                jax.block_until_ready(arrs.theta_qn)
                dt = time.perf_counter() - t0
                for i, (s, aux) in enumerate(zip(chunk, auxes[:n_real])):
                    thetas = {"cq": arrs.theta_cq[i],
                              "os": arrs.theta_os[i],
                              "qn": arrs.theta_qn[i]}
                    record = {
                        "scenario": s.to_json(),
                        "metrics": compute_metrics(s, thetas, aux),
                        "spend": _spend_record(
                            s, np.asarray(arrs.sigmas[i, 0])),
                        "comm": comm_record(s.p, s.protocol_config()),
                        "thetas_qn": (np.asarray(arrs.theta_qn[i],
                                                 np.float64).tolist()
                                      if store_thetas else None),
                        "timing": {"group": label, "group_seconds": dt,
                                   "group_size": n_real,
                                   "chunk": ci, "n_chunks": len(chunks),
                                   "traces": self.trace_counts[gkey]},
                    }
                    art["scenarios"][s.scenario_id()] = record
                if artifact_path:
                    # per-chunk atomic write: an interrupted oversized
                    # group resumes from its completed chunks
                    artifact_mod.save(art, artifact_path)
        artifact_mod.validate(art)
        return art

    def _chunks(self, scens: List[Scenario]) -> List[List[Scenario]]:
        """Split one jit group into bounded scenario batches."""
        c = self.chunk_size
        if c is None or len(scens) <= c:
            return [scens]
        return [scens[i:i + c] for i in range(0, len(scens), c)]


def run_scenarios(scenarios: Iterable[Scenario], mesh=None,
                  artifact_path: Optional[str] = None, resume: bool = True,
                  store_thetas: bool = True, meta: Optional[Dict] = None,
                  progress: Optional[Callable[[str], None]] = None,
                  chunk_size: Optional[int] = None) -> Dict:
    """One-shot convenience wrapper around :class:`SweepExecutor`."""
    executor = SweepExecutor(mesh=mesh, progress=progress,
                             chunk_size=chunk_size)
    return executor.run(scenarios, artifact_path=artifact_path,
                        resume=resume, store_thetas=store_thetas, meta=meta)


# ---------------------------------------------------------------- internals

def _problem_for(scenario: Scenario):
    from repro.core import get_problem
    return get_problem(scenario.problem)


def _spend_record(s: Scenario, sigmas: np.ndarray) -> Dict:
    """Host-side exact privacy spend for the artifact (the traced ledger
    carries the same numbers as f32; the accountant math stays in float).

    Schema v3: the record names the accountant that certified the
    per-round budget, its sigma ratio vs basic composition, and the
    per-transmission sensitivity failure probabilities (nonzero for every
    transmission under the "subexp" high-probability accountant)."""
    from repro.core.protocol import _failure_probs
    from repro.privacy import get_accountant, multiplier_ratio
    cfg = s.protocol_config()
    k = n_transmissions(cfg)
    acct = get_accountant(s.accountant)
    eps_r, delta_r = acct.per_round(s.eps, s.delta, k)
    probs = _failure_probs(cfg, s.p, s.n)
    return {"eps_total": s.eps, "delta_total": s.delta,
            "n_transmissions": k, "eps_per_round": eps_r,
            "delta_per_round": delta_r,
            "sigmas": [float(v) for v in sigmas],
            "accountant": acct.name,
            "sigma_ratio_vs_basic":
                multiplier_ratio(s.accountant, s.eps, s.delta, k),
            "failure_probs": [float(f) for f in probs],
            "failure_prob_total": min(1.0, float(sum(probs)))}


def _train_spend_record(s: TrainScenario, params) -> Dict:
    """Per-STEP spend for one zoo training run, with the per-leaf ledger:
    every transmission's sigma at every leaf's own dimension (the per-leaf
    calibration made auditable, core.dp.tree_spend_ledger)."""
    from repro.core import dp
    from repro.privacy import get_accountant, multiplier_ratio
    k = len(dp.TREE_TRANSMISSIONS)
    if s.eps <= 0:
        return {"eps_total": 0.0, "delta_total": 0.0, "n_transmissions": k,
                "eps_per_round": 0.0, "delta_per_round": 0.0,
                "sigmas": [0.0] * k, "accountant": s.accountant,
                "sigma_ratio_vs_basic": 1.0, "per_leaf": []}
    acct = get_accountant(s.accountant)
    eps_r, delta_r = acct.per_round(s.eps, s.delta, k)
    ledger = dp.tree_spend_ledger(params, s.n_per_machine(), s.eps,
                                  s.delta, (s.gamma,) * 5, s.tail,
                                  accountant=s.accountant)
    sig_max = {name: max(r["sigma"] for r in ledger
                         if r["transmission"] == name)
               for name in dp.TREE_TRANSMISSIONS}
    return {"eps_total": s.eps, "delta_total": s.delta,
            "n_transmissions": k, "eps_per_round": eps_r,
            "delta_per_round": delta_r,
            "sigmas": [sig_max[name] for name in dp.TREE_TRANSMISSIONS],
            "accountant": acct.name,
            "sigma_ratio_vs_basic":
                multiplier_ratio(s.accountant, s.eps, s.delta, k),
            "per_leaf": ledger}


def _run_meta(meta: Optional[Dict]) -> Dict:
    out = {"jax": jax.__version__,
           "device": jax.devices()[0].platform,
           "n_devices": jax.device_count()}
    out.update(meta or {})
    return out
