"""Declarative scenario grids over the paper's experimental axes.

A ``Scenario`` is one point of the paper's §5 evaluation space — loss
family x Byzantine attack x robust aggregator x privacy budget eps x
machine count m x Byzantine fraction alpha x center-trust mode — plus the
bookkeeping needed to reproduce it exactly (data seed, replicate seeds).

``ScenarioGrid`` expands a Cartesian product of those axes into scenarios;
``group_scenarios`` buckets them by *jit group key*: the subset of fields
that is static under jax.jit (shapes + config baked into the trace). Every
field NOT in the group key — eps, delta, byz_frac, attack_factor, data and
replicate seeds — rides a vmap axis in the executor, so one compiled
executable serves the whole group (tests/test_sweep.py asserts exactly one
trace per group via compile counters).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Dict, Iterable, List, Optional, Tuple

from repro.agg import registered as registered_aggregators
from repro.attacks import registered as registered_attacks
from repro.attacks import resolve as resolve_attack
from repro.configs.base import ProtocolConfig, TreeProtocolConfig
from repro.privacy import registered as registered_accountants


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One protocol evaluation point. Field groups:

    jit-static (part of the group key — changing them recompiles):
        problem, m, n, p, reps, attack, aggregator, center_trust, K,
        trim_beta, gammas, lambda_s, tail, newton_steps, noiseless,
        accountant (sigma calibration is host-side per scenario, so the
        scaled sigmas still ride the vmap axis as traced arrays — but the
        ledger semantics differ per accountant, so groups never mix them)
    dynamic (batched along the executor's scenario vmap axis):
        eps, delta, byz_frac, attack_factor, data_seed, rep_seeds
    data-only (select which arrays are fed, not how they are traced):
        dataset, pair
    """
    problem: str = "logistic"          # loss family (repro.core.losses)
    dataset: str = "synthetic"         # synthetic | digits
    m: int = 50                        # node machines (center is machine 0)
    n: int = 1000                      # samples per machine
    p: int = 10                        # parameter dimension
    eps: float = 30.0                  # total privacy budget
    delta: float = 0.05
    byz_frac: float = 0.0              # alpha: fraction of Byzantine machines
    attack: str = "scale"              # repro.attacks registry name | "none"
    attack_factor: float = -3.0
    aggregator: str = "dcq"            # dcq | median | trimmed | geomedian | mean
    center_trust: str = "trusted"      # trusted | untrusted (paper §4.3)
    K: int = 10
    trim_beta: float = 0.2
    gammas: Tuple[float, ...] = (2.0, 2.0, 2.0, 2.0, 2.0)
    lambda_s: Optional[float] = None
    tail: str = "subexp"
    newton_steps: int = 25
    noiseless: bool = False
    accountant: str = "basic"          # repro.privacy registry name
    reps: int = 5                      # Monte-Carlo replicates
    data_seed: int = 0
    # Explicit per-replicate PRNG seeds (tuple of ints, len == reps). None
    # derives deterministic keys from the scenario id, so resumed sweeps
    # reproduce the same draws.
    rep_seeds: Optional[Tuple[int, ...]] = None
    pair: Optional[Tuple[int, int]] = None   # digits dataset class pair

    def __post_init__(self):
        if self.rep_seeds is not None and len(self.rep_seeds) != self.reps:
            raise ValueError(
                f"rep_seeds has {len(self.rep_seeds)} entries for "
                f"reps={self.reps}")
        if self.dataset == "digits" and self.pair is None:
            raise ValueError("digits scenarios need a class `pair`")
        if self.aggregator not in registered_aggregators():
            # the repro.agg registry is the source of truth: a newly
            # registered aggregator is immediately sweepable, a typo is
            # rejected before any compilation happens
            raise ValueError(
                f"unknown aggregator {self.aggregator!r}; registered: "
                f"{registered_aggregators()}")
        # canonicalize launcher aliases ("sign"/"noise") so group_key and
        # scenario_id are stable regardless of which name the caller used
        object.__setattr__(self, "attack", resolve_attack(self.attack))
        if self.attack not in registered_attacks():
            # same contract on the adversary axis: the repro.attacks
            # registry is the source of truth for sweepable threat models
            raise ValueError(
                f"unknown attack {self.attack!r}; registered: "
                f"{registered_attacks()}")
        if self.accountant not in registered_accountants():
            # and on the privacy axis: the repro.privacy registry is the
            # source of truth for composition rules
            raise ValueError(
                f"unknown accountant {self.accountant!r}; registered: "
                f"{registered_accountants()}")

    # ------------------------------------------------------------- identity

    def canonical(self) -> Tuple:
        """Stable full-field tuple (dict ordering is field order).

        ``accountant`` is EXCLUDED at its default "basic" so every
        scenario id minted before the accountant axis existed — committed
        golden keys, resumable artifacts — is byte-unchanged; non-basic
        accountants hash in like any other field."""
        return tuple(sorted(
            (f.name, repr(getattr(self, f.name)))
            for f in dataclasses.fields(self)
            if not (f.name == "accountant"
                    and getattr(self, f.name) == "basic")))

    def scenario_id(self) -> str:
        """Human-readable id, unique via a canonical-field hash; stable
        across processes (used as the resume key in artifacts)."""
        h = hashlib.sha1(repr(self.canonical()).encode()).hexdigest()[:8]
        acct = "" if self.accountant == "basic" else f"-{self.accountant}"
        return (f"{self.dataset}-{self.problem}-m{self.m}-n{self.n}"
                f"-p{self.p}-eps{self.eps:g}-byz{self.byz_frac:g}"
                f"-{self.attack}-{self.aggregator}-{self.center_trust}"
                f"{acct}-{h}")

    def group_key(self) -> Tuple:
        """Everything baked into the jit trace: static config + shapes.
        Scenarios sharing a key share one compiled executable."""
        return (self.problem, self.m, self.n, self.p, self.reps,
                self.attack, self.aggregator, self.center_trust, self.K,
                self.trim_beta, self.gammas, self.lambda_s, self.tail,
                self.newton_steps, self.noiseless, self.accountant)

    def protocol_config(self) -> ProtocolConfig:
        """Static protocol config for this scenario's jit group. eps/delta
        are included for the single-scenario path but are OVERRIDDEN by
        the executor's dynamic budget axis within a group."""
        return ProtocolConfig(
            K=self.K, eps=self.eps, delta=self.delta, gammas=self.gammas,
            lambda_s=self.lambda_s, tail=self.tail,
            aggregator=self.aggregator, trim_beta=self.trim_beta,
            center_trust=self.center_trust, newton_steps=self.newton_steps,
            noiseless=self.noiseless, accountant=self.accountant)

    def n_byzantine(self) -> int:
        return int(self.byz_frac * self.m)

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        # tuples -> lists happens in json anyway; keep plain dict
        return d


def scenario_from_json(d: Dict) -> "Scenario | TrainScenario":
    kw = dict(d)
    if kw.pop("kind", None) == "train":
        return TrainScenario(**kw)
    for key in ("gammas", "rep_seeds", "pair"):
        if kw.get(key) is not None:
            kw[key] = tuple(kw[key])
    return Scenario(**kw)


# ------------------------------------------------- model-zoo training points

@dataclasses.dataclass(frozen=True)
class TrainScenario:
    """One robust-DP quasi-Newton TRAINING run of a model-zoo config: the
    same five-transmission engine as :class:`Scenario`'s convex protocol
    (core.protocol.protocol_tree_rounds), driven for ``steps`` optimizer
    steps over the arch's parameter pytree.

    jit-static (group key — one compiled train step per group):
        arch, steps, batch, seq, machines, aggregator, attack, hist,
        lr, local_lr, local_steps, tail, K, trim_beta, noiseless
    dynamic (fed as traced args to the shared step):
        eps/delta (as host-calibrated per-leaf sigma trees), byz_frac
        (as the mask), attack_factor, seed (PRNG key)
    """
    arch: str = "xlstm-125m"           # repro.configs zoo name
    steps: int = 3                     # optimizer steps (= protocol runs)
    batch: int = 8                     # global batch, split over machines
    seq: int = 16
    machines: int = 4
    eps: float = 0.0                   # per-step budget; <= 0 = noiseless
    delta: float = 0.05
    byz_frac: float = 0.0
    attack: str = "none"
    attack_factor: float = -3.0
    aggregator: str = "dcq_mad"        # repro.agg registry name
    hist: int = 5                      # L-BFGS memory length
    lr: float = 0.3
    local_lr: float = 0.1
    local_steps: int = 1
    gamma: float = 2.0
    tail: str = "subexp"
    K: int = 10
    trim_beta: float = 0.2
    accountant: str = "basic"          # repro.privacy registry name
    seed: int = 0

    def __post_init__(self):
        from repro.configs import ARCHS
        if self.arch not in ARCHS:
            raise ValueError(f"unknown arch {self.arch!r}; available: "
                             f"{ARCHS}")
        if self.batch % self.machines:
            raise ValueError(f"batch {self.batch} does not split over "
                             f"{self.machines} machines")
        if self.aggregator not in registered_aggregators():
            raise ValueError(
                f"unknown aggregator {self.aggregator!r}; registered: "
                f"{registered_aggregators()}")
        object.__setattr__(self, "attack", resolve_attack(self.attack))
        if self.attack not in registered_attacks():
            raise ValueError(
                f"unknown attack {self.attack!r}; registered: "
                f"{registered_attacks()}")
        if self.accountant not in registered_accountants():
            raise ValueError(
                f"unknown accountant {self.accountant!r}; registered: "
                f"{registered_accountants()}")

    # ------------------------------------------------------------- identity

    def canonical(self) -> Tuple:
        # accountant excluded at "basic" for id stability, as in Scenario.
        return tuple(sorted(
            (f.name, repr(getattr(self, f.name)))
            for f in dataclasses.fields(self)
            if not (f.name == "accountant"
                    and getattr(self, f.name) == "basic")))

    def scenario_id(self) -> str:
        h = hashlib.sha1(repr(self.canonical()).encode()).hexdigest()[:8]
        acct = "" if self.accountant == "basic" else f"-{self.accountant}"
        return (f"zoo-{self.arch}-t{self.steps}-b{self.batch}"
                f"-s{self.seq}-m{self.machines}-eps{self.eps:g}"
                f"-byz{self.byz_frac:g}-{self.attack}-{self.aggregator}"
                f"{acct}-{h}")

    def group_key(self) -> Tuple:
        """Leads with "zoo" so mixed sweeps bucket train and protocol
        scenarios apart; eps rides as sigma trees, byz_frac as the mask
        and attack_factor as a traced scalar, so they stay dynamic."""
        return ("zoo", self.arch, self.steps, self.batch, self.seq,
                self.machines, self.aggregator, self.attack, self.hist,
                self.lr, self.local_lr, self.local_steps, self.tail,
                self.K, self.trim_beta, self.eps <= 0.0, self.accountant)

    def protocol_config(self) -> TreeProtocolConfig:
        """Static per-group config. eps is reduced to the NOISELESS FLAG
        (the executor feeds each scenario's actual budget as traced
        per-leaf sigma trees, so budgets share one trace)."""
        return TreeProtocolConfig(
            hist=self.hist, lr=self.lr, local_lr=self.local_lr,
            local_steps=self.local_steps,
            eps=1.0 if self.eps > 0 else 0.0, delta=self.delta,
            gammas=(self.gamma,) * 5, tail=self.tail,
            aggregator=self.aggregator, K=self.K,
            trim_beta=self.trim_beta, accountant=self.accountant)

    def n_byzantine(self) -> int:
        return int(self.byz_frac * self.machines)

    def n_per_machine(self) -> int:
        return self.batch // self.machines

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["kind"] = "train"
        return d


@dataclasses.dataclass(frozen=True)
class ScenarioGrid:
    """Cartesian product over the paper's scenario axes. Axes are tuples;
    scalars are shared by every expanded scenario."""
    problems: Tuple[str, ...] = ("logistic",)
    attacks: Tuple[str, ...] = ("scale",)
    aggregators: Tuple[str, ...] = ("dcq",)
    eps_grid: Tuple[float, ...] = (30.0,)
    m_grid: Tuple[int, ...] = (50,)
    byz_fracs: Tuple[float, ...] = (0.0,)
    center_trusts: Tuple[str, ...] = ("trusted",)
    attack_factors: Tuple[float, ...] = (-3.0,)
    accountants: Tuple[str, ...] = ("basic",)
    # shared scalars
    n: int = 1000
    p: int = 10
    reps: int = 5
    delta: float = 0.05
    K: int = 10
    trim_beta: float = 0.2
    gammas: Tuple[float, ...] = (2.0, 2.0, 2.0, 2.0, 2.0)
    lambda_s: Optional[float] = None
    tail: str = "subexp"
    newton_steps: int = 25
    noiseless: bool = False
    data_seed: int = 0
    # "shared": every scenario reuses PRNGKey(data_seed) per (m, problem);
    # "per-m": seed = data_seed + m (the mrse_vs_m convention, fresh data
    # per machine count).
    data_seed_mode: str = "shared"

    def size(self) -> int:
        return (len(self.problems) * len(self.attacks)
                * len(self.aggregators) * len(self.eps_grid)
                * len(self.m_grid) * len(self.byz_fracs)
                * len(self.center_trusts) * len(self.attack_factors)
                * len(self.accountants))

    def expand(self) -> List[Scenario]:
        if self.data_seed_mode not in ("shared", "per-m"):
            raise ValueError(f"unknown data_seed_mode {self.data_seed_mode!r}")
        out = []
        for (prob, attack, agg, eps, m, byz, trust, factor, acct) in \
                itertools.product(self.problems, self.attacks,
                                  self.aggregators, self.eps_grid,
                                  self.m_grid, self.byz_fracs,
                                  self.center_trusts, self.attack_factors,
                                  self.accountants):
            seed = (self.data_seed + m if self.data_seed_mode == "per-m"
                    else self.data_seed)
            out.append(Scenario(
                problem=prob, m=m, n=self.n, p=self.p, eps=float(eps),
                delta=self.delta, byz_frac=byz, attack=attack,
                attack_factor=factor, aggregator=agg, center_trust=trust,
                K=self.K, trim_beta=self.trim_beta, gammas=self.gammas,
                lambda_s=self.lambda_s, tail=self.tail,
                newton_steps=self.newton_steps, noiseless=self.noiseless,
                accountant=acct, reps=self.reps, data_seed=seed))
        return out

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def group_scenarios(scenarios: Iterable[Scenario]
                    ) -> "Dict[Tuple, List[Scenario]]":
    """Bucket scenarios by jit group key, preserving first-seen order."""
    groups: Dict[Tuple, List[Scenario]] = {}
    for s in scenarios:
        groups.setdefault(s.group_key(), []).append(s)
    return groups


def group_label(key: Tuple) -> str:
    """Short human-readable tag for a jit group (artifact/timing records).
    The accountant rides last in both key layouts (after the noiseless
    flag) and is tagged only when non-basic."""
    accountant = key[-1]
    if key[0] == "zoo":
        _, arch, steps, batch, seq, machines, agg, attack = key[:8]
        tag = (f"zoo-{arch}-t{steps}-b{batch}-s{seq}-m{machines}"
               f"-{attack}-{agg}")
        if key[-2]:
            tag += "-noiseless"
    else:
        problem, m, n, p, reps, attack, agg, trust = key[:8]
        tag = f"{problem}-m{m}-n{n}-p{p}-r{reps}-{attack}-{agg}-{trust}"
        if key[-2]:
            tag += "-noiseless"
    if accountant != "basic":
        tag += f"-{accountant}"
    return tag
