"""Versioned sweep artifact: JSON on disk, one record per scenario.

Schema (version 3)::

    {
      "schema_version": 3,
      "kind": "repro.sweep",
      "meta": {"jax": ..., "device": ..., "preset": ...},
      "grid": {...} | null,             # originating ScenarioGrid, if any
      "scenarios": {
        "<scenario_id>": {
          "scenario": {<Scenario fields>},
          "metrics":  {"mrse_cq": .., "mrse_os": .., "mrse_qn": ..}
                      | {"accuracy": ..},
          "spend":    {"eps_total": .., "delta_total": ..,
                       "n_transmissions": .., "eps_per_round": ..,
                       "sigmas": [..], "accountant": ..,
                       "sigma_ratio_vs_basic": ..,
                       "failure_probs": [..] | absent,
                       "per_leaf": [..] | absent},
          "comm":     {"bytes_per_machine": .., "bytes_per_round": ..,
                       "n_transmissions": .., "eps_per_round": ..,
                       "newton_bytes_per_machine": ..,
                       "gd20_bytes_per_machine": ..},
          "thetas_qn": [[..p floats..] x reps] | null,
          "timing":   {"group": <label>, "group_seconds": ..,
                       "group_size": .., "traces": ..}
        }, ...
      }
    }

v2 added the "comm" record (repro/sweep/comm.py): transmission cost and
per-round budget ride the same versioned artifact as MRSE. v3 added
privacy accounting to the spend record: the repro.privacy registry
accountant that certified the per-round budget, its noise ratio vs basic
composition, and the high-probability failure ledger. Older artifacts
fail validation, so a resume against one restarts cleanly instead of
mixing schemas.

Artifacts are written atomically (tmp + rename) after EVERY jit-group
chunk, so an interrupted sweep resumes from the completed scenarios
(``load_done_ids``). ``to_csv`` flattens the records for plotting.
"""
from __future__ import annotations

import csv
import json
import os
import tempfile
from typing import Dict, Iterable, List, Optional, Set

SCHEMA_VERSION = 3
KIND = "repro.sweep"

_REQUIRED_RECORD_KEYS = ("scenario", "metrics", "spend", "comm", "timing")
_REQUIRED_SPEND_KEYS = ("eps_total", "delta_total", "n_transmissions",
                        "sigmas", "accountant")
_REQUIRED_COMM_KEYS = ("bytes_per_machine", "bytes_per_round",
                       "n_transmissions")


def new_artifact(meta: Optional[Dict] = None,
                 grid: Optional[Dict] = None) -> Dict:
    return {"schema_version": SCHEMA_VERSION, "kind": KIND,
            "meta": dict(meta or {}), "grid": grid, "scenarios": {}}


def validate(artifact: Dict) -> None:
    """Raise ValueError on any schema violation (tested round-trip)."""
    if not isinstance(artifact, dict):
        raise ValueError("artifact must be a JSON object")
    if artifact.get("kind") != KIND:
        raise ValueError(f"artifact kind {artifact.get('kind')!r} != {KIND!r}")
    version = artifact.get("schema_version")
    if version != SCHEMA_VERSION:
        raise ValueError(f"schema_version {version!r} unsupported "
                         f"(expected {SCHEMA_VERSION})")
    scen = artifact.get("scenarios")
    if not isinstance(scen, dict):
        raise ValueError("artifact.scenarios must be an object")
    for sid, rec in scen.items():
        for key in _REQUIRED_RECORD_KEYS:
            if key not in rec:
                raise ValueError(f"scenario {sid!r} missing {key!r}")
        if not isinstance(rec["metrics"], dict) or not rec["metrics"]:
            raise ValueError(f"scenario {sid!r} has empty metrics")
        for key in _REQUIRED_SPEND_KEYS:
            if key not in rec["spend"]:
                raise ValueError(f"scenario {sid!r} spend missing {key!r}")
        for key in _REQUIRED_COMM_KEYS:
            if key not in rec["comm"]:
                raise ValueError(f"scenario {sid!r} comm missing {key!r}")


def save(artifact: Dict, path: str) -> None:
    """Atomic write: partial artifacts on disk are always schema-valid."""
    validate(artifact)
    directory = os.path.dirname(os.path.abspath(path))
    os.makedirs(directory, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(artifact, f, indent=1, sort_keys=False)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load(path: str) -> Dict:
    with open(path) as f:
        artifact = json.load(f)
    validate(artifact)
    return artifact


def load_done_ids(path: str) -> Set[str]:
    """Scenario ids already completed in a partial artifact; empty set when
    the file is missing or unreadable/invalid (sweep restarts cleanly)."""
    if not os.path.exists(path):
        return set()
    try:
        return set(load(path)["scenarios"].keys())
    except (ValueError, json.JSONDecodeError, OSError):
        return set()


def rows(artifact: Dict) -> List[Dict]:
    """Flatten to one plain dict per scenario (CSV/pandas-friendly)."""
    out = []
    for sid, rec in artifact["scenarios"].items():
        row: Dict = {"scenario_id": sid}
        for key, val in rec["scenario"].items():
            if isinstance(val, (list, tuple)):
                val = "x".join(str(v) for v in val)
            row[key] = val
        row.update(rec["metrics"])
        row["eps_total"] = rec["spend"]["eps_total"]
        row["delta_total"] = rec["spend"]["delta_total"]
        row["n_transmissions"] = rec["spend"]["n_transmissions"]
        row["accountant"] = rec["spend"].get(
            "accountant", rec["scenario"].get("accountant", "basic"))
        row["sigma_ratio_vs_basic"] = rec["spend"].get(
            "sigma_ratio_vs_basic", 1.0)
        row["bytes_per_machine"] = rec["comm"]["bytes_per_machine"]
        row["bytes_per_round"] = rec["comm"]["bytes_per_round"]
        row["group"] = rec["timing"]["group"]
        row["group_seconds"] = rec["timing"]["group_seconds"]
        out.append(row)
    return out


def to_csv(artifact: Dict, path: str) -> None:
    flat = rows(artifact)
    if not flat:
        raise ValueError("artifact has no scenarios to export")
    fields: List[str] = []
    for row in flat:              # union of keys, first-seen order
        for key in row:
            if key not in fields:
                fields.append(key)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w", newline="") as f:
        writer = csv.DictWriter(f, fieldnames=fields)
        writer.writeheader()
        writer.writerows(flat)


def merge(base: Dict, other: Dict) -> Dict:
    """Union two artifacts (other wins on id collisions); meta from base."""
    validate(base)
    validate(other)
    out = new_artifact(meta=base["meta"], grid=base.get("grid"))
    out["scenarios"] = dict(base["scenarios"])
    out["scenarios"].update(other["scenarios"])
    return out


def get_metric(artifact: Dict, scenario_id: str, name: str) -> float:
    return artifact["scenarios"][scenario_id]["metrics"][name]


def thetas_qn(artifact: Dict, scenario_id: str) -> Iterable:
    t = artifact["scenarios"][scenario_id].get("thetas_qn")
    if t is None:
        raise KeyError(f"scenario {scenario_id!r} stored no thetas")
    return t
