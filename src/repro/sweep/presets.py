"""Named scenario grids: the paper's §5 evaluation as sweep presets.

Builders are parameterized so the figure benchmarks stay thin wrappers
(they reproduce their pre-refactor PRNG key schedules exactly via
``rep_seeds``); the CLI exposes them through ``PRESETS``:

  smoke      2 losses x 2 attacks x 2 aggregators x 2 eps, plus one
             registry-path group (alie x dcq) — CI gate, <5 min CPU
  zoo-smoke  model-zoo TRAINING smoke: short robust-DP quasi-Newton runs
             (the same five-transmission engine) on one reduced config
             per family + a clean-mean baseline + a two-budget DP group
  fig-eps    Figures 1/2/4/5: MRSE vs eps, normal + 10% Byzantine
  fig-m      Figures 3/6:     MRSE vs machine count m
  table1     Table 1 stand-in: digit-pair accuracy vs eps (+ Byzantine)
  untrusted  §4.3 sensitivity: center_trust x EVERY registered aggregator
             (the grid is driven by the repro.agg registry — a newly
             registered aggregator appears in this preset automatically)
  attack-sensitivity
             threat-model grid: EVERY registered attack x its declared
             factor grid x {dcq, median, trimmed} x byz_frac {0.1, 0.2}
             (driven by the repro.attacks registry — a newly registered
             attack appears here automatically; factors and Byzantine
             fractions ride the vmap axis, so the grid compiles once per
             (attack, aggregator))
  paper      everything above except smoke/untrusted/attack-sensitivity,
             in one artifact
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.agg import registered as registered_aggregators
from repro.attacks import get_attack
from repro.attacks import registered as registered_attacks
from repro.sweep.grid import Scenario, ScenarioGrid, TrainScenario

#: Figure 1-3 default privacy budgets (paper §5.1)
EPS_GRID = (4.0, 10.0, 20.0, 30.0, 50.0)
#: Table 1 digit pairs -> screened feature count (paper §5.2)
TABLE1_PAIRS: Dict[Tuple[int, int], int] = {(8, 9): 8, (6, 8): 5, (6, 9): 5}


# ------------------------------------------------------------------- smoke

def smoke_scenarios() -> List[Scenario]:
    """CI smoke grid: 2 losses x 2 attacks x 2 aggregators x 2 eps = 16
    scenarios in 8 jit groups (eps rides each group's vmap axis), plus
    one new-attack registry group (alie x dcq, 2 eps) so the
    repro.attacks omniscient path is compiled and executed on every PR.

    m = 7 so the machine axis (m+1 = 8 rows, center included) shards
    evenly over 1/2/4/8 devices — ``--preset smoke --sharded`` works on
    typical hosts; byz_frac 0.15 keeps one Byzantine machine."""
    grid = ScenarioGrid(
        problems=("logistic", "poisson"),
        attacks=("scale", "signflip"),
        aggregators=("dcq", "median"),
        eps_grid=(10.0, 30.0),
        m_grid=(7,), byz_fracs=(0.15,),
        n=200, p=5, reps=2)
    alie = ScenarioGrid(
        problems=("logistic",),
        attacks=("alie",), attack_factors=(1.0,),
        aggregators=("dcq",),
        eps_grid=(10.0, 30.0),
        m_grid=(7,), byz_fracs=(0.15,),
        n=200, p=5, reps=2)
    return grid.expand() + alie.expand()


# --------------------------------------------------------------- zoo-smoke

#: one reduced config per model family the protocol engine must drive
#: (ssm/xlstm, dense, MoE, hybrid mamba+attn)
ZOO_SMOKE_ARCHS: Tuple[str, ...] = (
    "xlstm-125m", "glm4-9b", "qwen3-moe-30b-a3b", "zamba2-7b")


def zoo_smoke_scenarios() -> List[Scenario]:
    """Model-zoo training smoke: the SAME five-transmission engine that
    produces the convex figures drives short robust QN training runs on
    one reduced config per family, plus (on xlstm) a clean-mean baseline
    and a two-budget DP group. eps rides the group's dynamic sigma axis,
    so the two DP budgets share one compiled step (compile-once extends
    to training; asserted in tests/test_protocol_pytree.py)."""
    common = dict(steps=2, batch=8, seq=16, machines=4, lr=0.3)
    out: List[Scenario] = [
        TrainScenario(arch=arch, aggregator="dcq_mad", attack="signflip",
                      byz_frac=0.25, **common)
        for arch in ZOO_SMOKE_ARCHS]
    # clean mean baseline (the degenerate no-defense configuration)
    out.append(TrainScenario(arch="xlstm-125m", aggregator="mean",
                             **common))
    # two per-step budgets through ONE compiled step (dynamic sigma trees)
    out += [TrainScenario(arch="xlstm-125m", aggregator="dcq_mad",
                          attack="signflip", byz_frac=0.25, eps=eps,
                          **common)
            for eps in (5.0, 50.0)]
    return out


# ------------------------------------------------- Figures 1/2/4/5 (vs eps)

def fig_eps_scenarios(problem: str = "logistic", m: int = 50, n: int = 1000,
                      p: int = 10, reps: int = 5, byz_frac: float = 0.0,
                      eps_grid: Tuple[float, ...] = EPS_GRID,
                      seed: int = 0) -> List[Scenario]:
    """One MRSE-vs-eps curve. ``rep_seeds`` reproduce the historical
    benchmark key schedule PRNGKey(1000*eps + r) per eps point."""
    return [Scenario(
        problem=problem, m=m, n=n, p=p, eps=float(eps), delta=0.05,
        byz_frac=byz_frac, reps=reps, data_seed=seed,
        rep_seeds=tuple(int(1000 * eps) + r for r in range(reps)))
        for eps in eps_grid]


def fig_eps_reference(problem: str = "logistic", m: int = 50, n: int = 1000,
                      p: int = 10, byz_frac: float = 0.0,
                      seed: int = 0) -> Scenario:
    """The noiseless quasi-Newton reference line (historical key 9)."""
    return Scenario(problem=problem, m=m, n=n, p=p, noiseless=True,
                    byz_frac=byz_frac, reps=1, data_seed=seed,
                    rep_seeds=(9,))


# ----------------------------------------------------- Figures 3/6 (vs m)

def fig_m_scenarios(problem: str = "logistic", n: int = 500, p: int = 10,
                    m_grid: Tuple[int, ...] = (10, 20, 40, 80),
                    reps: int = 4, byz_frac: float = 0.0, eps: float = 30.0,
                    seed: int = 0) -> List[Scenario]:
    """One MRSE-vs-m curve: fresh data per machine count (seed + m), keys
    PRNGKey(10*m + r) — the historical mrse_vs_m schedule."""
    return [Scenario(
        problem=problem, m=m, n=n, p=p, eps=eps, delta=0.05,
        byz_frac=byz_frac, reps=reps, data_seed=seed + m,
        rep_seeds=tuple(10 * m + r for r in range(reps)))
        for m in m_grid]


# ------------------------------------------------ untrusted center (§4.3)

def untrusted_scenarios(eps_grid: Tuple[float, ...] = (10.0, 30.0),
                        m: int = 10, n: int = 400, p: int = 5,
                        reps: int = 3, byz_frac: float = 0.1
                        ) -> List[Scenario]:
    """Center-trust x aggregator grid over every registered aggregator.

    The aggregator axis is read from the repro.agg registry, so
    ``register(...)``-ing a new rule makes it sweepable here with no
    preset change. eps and the Byzantine fraction ride the vmap axis;
    each (aggregator, trust) pair is one jit group."""
    grid = ScenarioGrid(
        problems=("logistic",),
        attacks=("scale",),
        aggregators=registered_aggregators(),
        eps_grid=eps_grid,
        m_grid=(m,), byz_fracs=(0.0, byz_frac),
        center_trusts=("trusted", "untrusted"),
        n=n, p=p, reps=reps)
    return grid.expand()


# --------------------------------------- attack-factor sensitivity (§5.1)

#: aggregators the attack grid stresses (the paper's estimator + the two
#: Yin-style robust baselines the related work attacks hardest)
ATTACK_AGGREGATORS: Tuple[str, ...] = ("dcq", "median", "trimmed")


def attack_sensitivity_scenarios(
        aggregators: Tuple[str, ...] = ATTACK_AGGREGATORS,
        byz_fracs: Tuple[float, ...] = (0.1, 0.2),
        m: int = 10, n: int = 300, p: int = 5, reps: int = 3,
        eps: float = 30.0) -> List[Scenario]:
    """Threat-model sensitivity grid, driven by the repro.attacks registry.

    EVERY registered attack with a non-empty ``factor_grid`` x its
    declared factors x ``aggregators`` x ``byz_fracs``. attack_factor and
    byz_frac are dynamic fields (they ride the executor's vmap axis), so
    the whole grid compiles exactly once per (attack, aggregator) pair —
    ``register(...)``-ing a new attack makes it sweepable here with no
    preset change."""
    out: List[Scenario] = []
    for attack in registered_attacks():
        factors = get_attack(attack).factor_grid
        if not factors:                      # e.g. "none": nothing to sweep
            continue
        for agg in aggregators:
            out += [Scenario(
                problem="logistic", m=m, n=n, p=p, eps=eps, delta=0.05,
                byz_frac=byz, attack=attack, attack_factor=float(factor),
                aggregator=agg, reps=reps)
                for factor in factors for byz in byz_fracs]
    return out


# --------------------------------------------------------- Table 1 (digits)

def table1_scenarios(pair: Tuple[int, int], n_features: int,
                     eps_grid: Tuple[float, ...] = (5.0, 10.0, 20.0, 30.0),
                     byz_eps: Tuple[float, ...] = (30.0,),
                     m: int = 10, n_per_machine: int = 1000,
                     seed: int = 0, reps: int = 3) -> List[Scenario]:
    """One digit pair: clean accuracy across ``eps_grid`` plus Byzantine
    points at ``byz_eps`` (paper: +3x scaling attack, gamma = 0.5)."""
    def scen(eps: float, byz: bool) -> Scenario:
        return Scenario(
            problem="logistic", dataset="digits", pair=pair,
            m=m, n=n_per_machine, p=n_features, eps=float(eps), delta=0.05,
            byz_frac=0.1 if byz else 0.0, attack="scale", attack_factor=3.0,
            gammas=(0.5,) * 5, reps=reps, data_seed=seed,
            rep_seeds=tuple(seed + 1 + 1000 * r for r in range(reps)))
    return ([scen(eps, False) for eps in eps_grid]
            + [scen(eps, True) for eps in byz_eps])


# ---------------------------------------------------------------- registry

def _build_smoke() -> List[Scenario]:
    return smoke_scenarios()


def _build_fig_eps() -> List[Scenario]:
    out: List[Scenario] = []
    for problem in ("logistic", "poisson"):
        for byz in (0.0, 0.1):
            out += fig_eps_scenarios(problem, byz_frac=byz)
            out.append(fig_eps_reference(problem, byz_frac=byz))
    return out


def _build_fig_m() -> List[Scenario]:
    out: List[Scenario] = []
    for byz in (0.0, 0.1):
        out += fig_m_scenarios(byz_frac=byz)
    return out


def _build_table1() -> List[Scenario]:
    out: List[Scenario] = []
    for pair, k in TABLE1_PAIRS.items():
        out += table1_scenarios(pair, k)
    return out


def _build_untrusted() -> List[Scenario]:
    return untrusted_scenarios()


def _build_attack_sensitivity() -> List[Scenario]:
    return attack_sensitivity_scenarios()


def _build_paper() -> List[Scenario]:
    return _build_fig_eps() + _build_fig_m() + _build_table1()


def _build_zoo_smoke() -> List[Scenario]:
    return zoo_smoke_scenarios()


PRESETS = {
    "smoke": _build_smoke,
    "zoo-smoke": _build_zoo_smoke,
    "fig-eps": _build_fig_eps,
    "fig-m": _build_fig_m,
    "table1": _build_table1,
    "untrusted": _build_untrusted,
    "attack-sensitivity": _build_attack_sensitivity,
    "paper": _build_paper,
}


def build_preset(name: str) -> List[Scenario]:
    if name not in PRESETS:
        raise KeyError(
            f"unknown preset {name!r}; available: {sorted(PRESETS)}")
    return PRESETS[name]()


def fast_variant(scenarios: List[Scenario], reps: int = 2) -> List[Scenario]:
    """Reduced-replicate copy of a preset (CI smoke of the full figures).
    Explicit rep_seeds are truncated to keep per-key reproducibility;
    training scenarios are cut to ``reps`` steps instead."""
    out = []
    for s in scenarios:
        if isinstance(s, TrainScenario):
            out.append(dataclasses.replace(s, steps=min(reps, s.steps)))
            continue
        r = min(reps, s.reps)
        seeds = s.rep_seeds[:r] if s.rep_seeds is not None else None
        out.append(dataclasses.replace(s, reps=r, rep_seeds=seeds))
    return out
