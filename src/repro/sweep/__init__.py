"""Scenario-sweep engine over the paper's experiment grid (§5).

Declarative grids (``ScenarioGrid``) expand into ``Scenario`` points,
which the executor buckets by jit group key and pushes through one
compiled ``jit(vmap(vmap(protocol_rounds)))`` per group — the whole paper
grid compiles a handful of times instead of once per point. Results land
in a versioned, resumable JSON artifact (``repro.sweep.artifact``).

CLI: ``python -m repro.sweep --preset smoke`` (see repro/sweep/cli.py).
"""
from repro.sweep.artifact import (SCHEMA_VERSION, load, rows, save, to_csv,
                                  validate)
from repro.sweep.executor import SweepExecutor, run_scenarios
from repro.sweep.grid import (Scenario, ScenarioGrid, TrainScenario,
                              group_label, group_scenarios,
                              scenario_from_json)
from repro.sweep.presets import (PRESETS, attack_sensitivity_scenarios,
                                 build_preset, fast_variant,
                                 fig_eps_reference, fig_eps_scenarios,
                                 fig_m_scenarios, smoke_scenarios,
                                 table1_scenarios, untrusted_scenarios,
                                 zoo_smoke_scenarios)

__all__ = ["SCHEMA_VERSION", "load", "rows", "save", "to_csv", "validate",
           "SweepExecutor", "run_scenarios",
           "Scenario", "ScenarioGrid", "TrainScenario", "group_label",
           "group_scenarios", "scenario_from_json",
           "zoo_smoke_scenarios",
           "PRESETS", "attack_sensitivity_scenarios", "build_preset",
           "fast_variant", "fig_eps_reference", "fig_eps_scenarios",
           "fig_m_scenarios", "smoke_scenarios", "table1_scenarios",
           "untrusted_scenarios"]
