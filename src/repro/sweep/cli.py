"""``python -m repro.sweep`` — run a scenario sweep preset end-to-end.

Examples::

    python -m repro.sweep --preset smoke
    python -m repro.sweep --preset paper --out experiments/paper.json
    python -m repro.sweep --preset fig-eps --list     # show grid, don't run

The artifact (versioned JSON, see repro/sweep/artifact.py) is written
after every jit group; re-running the same command resumes from the
completed scenarios unless ``--no-resume``. ``--csv`` additionally emits a
flat per-scenario table.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.sweep import artifact as artifact_mod
from repro.sweep.executor import SweepExecutor
from repro.sweep.grid import group_label, group_scenarios
from repro.sweep.presets import PRESETS, build_preset, fast_variant


def _default_out(preset: str) -> str:
    return f"experiments/sweep_{preset}.json"


def _summarize(art) -> str:
    lines = []
    header = (f"{'scenario':<58} {'metric':>10} {'value':>9}")
    lines.append(header)
    lines.append("-" * len(header))
    for sid, rec in art["scenarios"].items():
        for name, val in sorted(rec["metrics"].items()):
            if isinstance(val, (int, float)):   # skip e.g. loss curves
                lines.append(f"{sid:<58} {name:>10} {val:9.4f}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sweep",
        description="Scenario-sweep engine over the paper's §5 grid "
                    "(losses x attacks x aggregators x eps x m x alpha).")
    ap.add_argument("--preset", default="smoke", choices=sorted(PRESETS),
                    help="scenario grid to run (default: smoke)")
    ap.add_argument("--out", default=None,
                    help="artifact path (default: experiments/"
                         "sweep_<preset>.json)")
    ap.add_argument("--csv", default=None,
                    help="also write a flat CSV of per-scenario rows")
    ap.add_argument("--fast", action="store_true",
                    help="reduced replicate counts (CI smoke of big grids)")
    ap.add_argument("--no-resume", action="store_true",
                    help="ignore any partial artifact at --out")
    ap.add_argument("--no-thetas", action="store_true",
                    help="do not store per-replicate theta_qn in the "
                         "artifact")
    ap.add_argument("--list", action="store_true",
                    help="print the expanded grid and jit groups, then exit")
    ap.add_argument("--sharded", action="store_true",
                    help="shard the machine axis over all visible devices "
                         "(dist/sharded_protocol machine map)")
    ap.add_argument("--max-batch", type=int, default=None, metavar="N",
                    help="chunk jit groups larger than N scenarios into "
                         "bounded batches (caps peak memory; the artifact "
                         "is written after every chunk)")
    from repro.privacy import registered as registered_accountants
    ap.add_argument("--accountant", default=None,
                    choices=registered_accountants(),
                    help="override every scenario's privacy accountant "
                         "(repro.privacy registry) — the nightly "
                         "accountant-sweep runs one preset per entry")
    args = ap.parse_args(argv)

    scenarios = build_preset(args.preset)
    if args.fast:
        scenarios = fast_variant(scenarios)
    if args.accountant is not None:
        import dataclasses
        scenarios = [dataclasses.replace(s, accountant=args.accountant)
                     for s in scenarios]
    groups = group_scenarios(scenarios)
    print(f"preset {args.preset!r}: {len(scenarios)} scenarios in "
          f"{len(groups)} jit group(s)")
    if args.list:
        for key, scens in groups.items():
            print(f"  {group_label(key)}  [{len(scens)} scenario(s)]")
            for s in scens:
                print(f"    {s.scenario_id()}")
        return 0

    mesh = None
    if args.sharded:
        import jax
        from repro.compat import make_mesh
        n_dev = jax.device_count()
        mesh = make_mesh((n_dev,), ("machines",))
        print(f"sharding machine axis over {n_dev} device(s)")

    out = args.out or _default_out(args.preset)
    executor = SweepExecutor(mesh=mesh, progress=print,
                             chunk_size=args.max_batch)
    t0 = time.time()
    art = executor.run(scenarios, artifact_path=out,
                       resume=not args.no_resume,
                       store_thetas=not args.no_thetas,
                       meta={"preset": args.preset, "fast": args.fast})
    dt = time.time() - t0
    print(_summarize(art))
    print(f"\n{len(art['scenarios'])} scenario(s) in artifact; "
          f"this run: {dt:.1f}s, "
          f"{sum(c for c in executor.trace_counts.values())} trace(s) over "
          f"{len(executor.trace_counts)} jit group(s)")
    print(f"wrote {out}")
    if args.csv:
        artifact_mod.to_csv(art, args.csv)
        print(f"wrote {args.csv}")
    # compile-once contract: a group that traced more than once is a bug
    over = {k: c for k, c in executor.trace_counts.items() if c > 1}
    if over:
        print(f"WARNING: {len(over)} jit group(s) retraced: "
              f"{[group_label(k) for k in over]}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
