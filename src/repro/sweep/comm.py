"""Communication/budget cost model (paper §1.2(1)/§6), shared between the
sweep artifact and benchmarks/comm_cost.py.

Bytes-per-machine and per-transmission privacy budget for the paper's
quasi-Newton protocol and the two strategies it argues against, at equal
total (eps, delta):

  quasi-Newton (Alg 1): n_tx p-vectors (5 trusted / 6 untrusted — the
                        extra "R2b var" vector is transmitted too)
  Newton (Huang&Huo):   1 p-vector + p + p^2 (full Hessian)
  GD (Jordan et al.):   T p-vectors (T rounds)

The sweep executor stamps :func:`comm_record` into every scenario record
(artifact schema v2), so transmission cost rides the same versioned
artifact as MRSE and the privacy spend.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ProtocolConfig

#: wire width of one transmitted scalar (fp32)
BYTES_PER_SCALAR = 4


def qn_bytes_per_machine(p: int, cfg: ProtocolConfig) -> int:
    """Algorithm 1 payload per node machine: one p-vector per DP
    transmission (including the untrusted-center variance vector)."""
    from repro.core.protocol import n_transmissions
    return BYTES_PER_SCALAR * n_transmissions(cfg) * p


def newton_bytes_per_machine(p: int) -> int:
    """Distributed one-step Newton: theta + gradient + full p x p Hessian."""
    return BYTES_PER_SCALAR * (2 * p + p * p)


def gd_bytes_per_machine(p: int, rounds: int) -> int:
    """Multi-round distributed GD: one p-vector per round."""
    return BYTES_PER_SCALAR * p * rounds


def comm_record(p: int, cfg: ProtocolConfig) -> Dict:
    """The per-scenario transmission-cost record stamped into the sweep
    artifact (schema v2). Budget numbers mirror the spend record; byte
    numbers make the paper's communication argument queryable per point
    (with newton/gd_20 reference columns at the same p)."""
    from repro.core.protocol import n_transmissions, round_budget
    k = n_transmissions(cfg)
    eps_r, delta_r = round_budget(cfg)
    return {
        "n_transmissions": k,
        "bytes_per_round": BYTES_PER_SCALAR * p,
        "bytes_per_machine": qn_bytes_per_machine(p, cfg),
        "eps_per_round": eps_r,
        "delta_per_round": delta_r,
        "newton_bytes_per_machine": newton_bytes_per_machine(p),
        "gd20_bytes_per_machine": gd_bytes_per_machine(p, 20),
    }
