"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Shared by the multi-pod dry-run (launch/dryrun.py), the roofline analysis
and the smoke tests (which call it with concrete=True on reduced configs).
No device allocation happens here — decode caches come from
``jax.eval_shape`` over ``Model.init_cache``.

The modality carve-out: audio gives EnCodec codebook token streams; vlm
gives precomputed vision-tower patch embeddings (stub frontend).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import Model, VISION_DIM

N_PATCHES_SPEC = 576   # llava-next base-tile patches

LONG_WINDOW = 4096     # sliding window used by non-SSM archs at 500k


def adapt_config(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """long_500k needs sub-quadratic attention: dense/moe/vlm/audio archs
    switch to the sliding-window variant; ssm/hybrid run natively (hybrid's
    shared attention also windows)."""
    if shape.name == "long_500k" and cfg.family != "ssm":
        if cfg.sliding_window == 0:
            return cfg.with_sliding_window(LONG_WINDOW)
    return cfg


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Returns {name: ShapeDtypeStruct} for the step the shape exercises.

    train/prefill: full-sequence batch; decode: one-token batch + cache.
    """
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32

    if shape.kind in ("train", "prefill"):
        batch: Dict[str, Any] = {}
        if cfg.family == "vlm":
            s_text = S - cfg.n_patches
            batch["tokens"] = jax.ShapeDtypeStruct((B, s_text), tok)
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, VISION_DIM), jnp.bfloat16)
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((B, s_text), tok)
        elif cfg.family == "audio":
            batch["tokens"] = jax.ShapeDtypeStruct((B, S, cfg.n_codebooks),
                                                   tok)
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((B, S), tok)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), tok)
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((B, S), tok)
        return batch

    # decode: one new token against a cache of length S
    cfg = adapt_config(cfg, shape)
    model = Model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    if cfg.family == "audio":
        tokens = jax.ShapeDtypeStruct((B, 1, cfg.n_codebooks), tok)
    else:
        tokens = jax.ShapeDtypeStruct((B, 1), tok)
    # the cache is "at position S-1" in the dry-run; pos is part of cache
    return {"tokens": tokens, "cache": cache}


def concrete_batch(key: jax.Array, cfg: ModelConfig, shape: ShapeConfig
                   ) -> Dict[str, jnp.ndarray]:
    """Materialised random batch matching input_specs (smoke tests)."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, spec in specs.items():
        if name == "cache":
            out[name] = Model(adapt_config(cfg, shape)).init_cache(
                shape.global_batch, shape.seq_len)
            continue
        key, sub = jax.random.split(key)
        if spec.dtype == jnp.int32:
            out[name] = jax.random.randint(sub, spec.shape, 0, cfg.vocab,
                                           dtype=jnp.int32)
        else:
            out[name] = jax.random.normal(sub, spec.shape, spec.dtype)
    return out
