"""Architecture config registry.

``get_config(arch_id)`` returns the full assigned config;
``get_config(arch_id, reduced=True)`` the CPU smoke variant.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, ProtocolConfig, ShapeConfig, SHAPES

_ARCH_MODULES: Dict[str, str] = {
    "mistral-large-123b":    "repro.configs.mistral_large_123b",
    "musicgen-medium":       "repro.configs.musicgen_medium",
    "zamba2-7b":             "repro.configs.zamba2_7b",
    "qwen3-moe-30b-a3b":     "repro.configs.qwen3_moe_30b_a3b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "xlstm-125m":            "repro.configs.xlstm_125m",
    "phi3.5-moe-42b-a6.6b":  "repro.configs.phi35_moe_42b_a66b",
    "starcoder2-15b":        "repro.configs.starcoder2_15b",
    "minitron-8b":           "repro.configs.minitron_8b",
    "glm4-9b":               "repro.configs.glm4_9b",
}

ARCHS: List[str] = list(_ARCH_MODULES)


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCHS}")
    cfg: ModelConfig = importlib.import_module(_ARCH_MODULES[arch]).CONFIG
    return cfg.reduced() if reduced else cfg


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = ["ModelConfig", "ProtocolConfig", "ShapeConfig", "SHAPES",
           "ARCHS", "get_config", "get_shape"]
