"""Zamba2-7B: Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]

81 Mamba2 layers; one *shared-weight* attention+MLP block applied after
every 6 Mamba2 layers (13 insertions). The released model alternates two
shared blocks with LoRA adapters; simplified to one (DESIGN.md §7).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000,
    ssm=SSMConfig(d_state=64, n_groups=1, d_conv=4, expand=2, headdim=64),
    attn_every=6,
    citation="arXiv:2411.15242",
)
