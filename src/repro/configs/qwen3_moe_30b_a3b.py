"""Qwen3-30B-A3B: 128-expert top-8 MoE. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab=151936, d_head=128, rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
    citation="hf:Qwen/Qwen3-30B-A3B",
)
