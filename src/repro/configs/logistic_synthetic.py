"""The paper's own experiment config (§5.1): logistic/Poisson regression."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class RegressionConfig:
    model: str = "logistic"   # logistic | poisson | linear
    p: int = 10               # parameter dimension (paper: 10, 20)
    m: int = 500              # node machines (paper: 500..5000)
    n: int = 4000             # samples per machine (N = (m+1)*n)
    rho: float = 0.6          # Toeplitz correlation of X
    alpha: float = 0.0        # Byzantine fraction (paper: 0, 0.10)
    attack: str = "scale"     # scaling attack, factor -3 (paper §5.1)
    attack_factor: float = -3.0


CONFIG = RegressionConfig()
