"""MusicGen-medium decoder backbone over EnCodec tokens. [arXiv:2306.05284]

Backbone only: the EnCodec frontend is a stub; input_specs() provides the
4 codebook token streams; embeddings are summed (delay pattern collapsed).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048, n_codebooks=4,
    citation="arXiv:2306.05284",
)
