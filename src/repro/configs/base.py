"""Configuration dataclasses for models, input shapes and the protocol.

Every assigned architecture gets a module in this package exporting
``CONFIG: ModelConfig`` built from the exact numbers in the assignment
(citation kept in ``citation``). ``ModelConfig.reduced()`` yields the
CPU-smoke variant (<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # §Perf knob: constrain the dispatch buffer to expert-parallel layout
    # (P("model") on E) so GSPMD routes tokens with an all-to-all instead
    # of all-gathering the token stream onto every expert shard.
    shard_buffers: bool = False
    # §Perf knob: sort/scatter dispatch within each of N token shards
    # (capacity per shard) instead of globally — keeps the scatter local
    # to the data shard so no giant all-reduce materialises the (T*k, d)
    # unsort buffer. 1 = global dispatch (baseline).
    dispatch_shards: int = 1


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    n_groups: int = 1
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256
    # heads for the SSD formulation; d_inner = expand*d_model, headdim = d_inner/heads
    headdim: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    citation: str = ""
    d_head: Optional[int] = None     # default d_model // n_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: one shared attention block after every `attn_every` ssm blocks
    attn_every: int = 0
    # xlstm: which layer indices are sLSTM (rest mLSTM)
    slstm_at: Tuple[int, ...] = ()
    sliding_window: int = 0          # 0 = full attention; >0 = window size
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # vlm/audio frontend stubs
    n_patches: int = 0               # vlm: patch embeddings prepended
    n_codebooks: int = 0             # audio: EnCodec codebooks summed at input
    dtype: str = "bfloat16"

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        # xLSTM/Mamba-style: no softmax attention anywhere.
        return self.family == "ssm" and self.attn_every == 0

    def with_sliding_window(self, window: int) -> "ModelConfig":
        return dataclasses.replace(self, sliding_window=window)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        moe = None
        if self.moe is not None:
            moe = MoEConfig(n_experts=4, top_k=min(2, self.moe.top_k),
                            d_ff_expert=128, capacity_factor=2.0)
        ssm = None
        if self.ssm is not None:
            ssm = SSMConfig(d_state=16, n_groups=1, d_conv=4, expand=2,
                            chunk=32, headdim=32)
        return dataclasses.replace(
            self,
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_head=None,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            moe=moe,
            ssm=ssm,
            attn_every=1 if self.attn_every else 0,
            slstm_at=(1,) if self.slstm_at else (),
            n_patches=16 if self.n_patches else 0,
            n_codebooks=self.n_codebooks,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


@dataclasses.dataclass(frozen=True)
class TreeProtocolConfig:
    """Algorithm 1's five transmissions at model scale (the pytree engine,
    core/protocol.py protocol_tree_rounds). Quasi-Newton state is an
    L-BFGS (s, y) history — 2*hist parameter copies, never a p x p matrix.
    """
    hist: int = 5                # L-BFGS memory length
    lr: float = 0.5              # center step on aggregated directions
    local_lr: float = 0.1        # R1 machine-local SGD step size
    local_steps: int = 1         # R1 local steps (the local-estimator analog)
    eps: float = 0.0             # TOTAL privacy budget; <= 0 => noiseless
    delta: float = 0.05
    gammas: Tuple[float, ...] = (2.0, 2.0, 2.0, 2.0, 2.0)
    tail: str = "subexp"         # subexp | subgauss (Thm 4.5 vs Lemma 39)
    # Registry aggregator. Default is the MAD-self-calibrated DCQ: the
    # training wire transmits no variance estimates, so the oracle-scale
    # "dcq" of the convex path does not apply.
    aggregator: str = "dcq_mad"
    K: int = 10
    trim_beta: float = 0.2
    # Registry accountant (repro.privacy): how the total (eps, delta) is
    # split/composed over the five transmissions. "basic" = the historical
    # eps/5 split, byte-identical.
    accountant: str = "basic"


@dataclasses.dataclass(frozen=True)
class ProtocolConfig:
    """Algorithm 1 configuration (paper §4)."""
    K: int = 10                  # composite-quantile levels (paper uses 10)
    eps: float = 30.0            # total privacy budget (split over 5 rounds)
    delta: float = 0.05
    # Algorithm 1's fixed 5 vector rounds (validated — the per-transmission
    # budget is derived from the ACTUAL transmission count, which adds a 6th
    # "R2b var" DP transmission in untrusted-center mode; see
    # core/protocol.py round_budget/transmission_names).
    n_rounds: int = 5
    gammas: Tuple[float, ...] = (2.0, 2.0, 2.0, 2.0, 2.0)  # gamma_1..gamma_5
    # Lower bound on the Hessian eigenvalue (Assumption 7.3). None => each
    # machine calibrates from the eigenvalues of its LOCAL Hessian (local
    # data only, so no extra privacy cost) — see protocol.py R1/R3.
    lambda_s: float | None = None
    tail: str = "subexp"         # subexp | subgauss (Thm 4.5 vs Lemma 39)
    aggregator: str = "dcq"      # dcq | median | trimmed | mean
    trim_beta: float = 0.2       # trimmed-mean fraction
    center_trust: str = "trusted"  # trusted | untrusted (paper §4.3)
    newton_steps: int = 25       # local solver iterations
    noiseless: bool = False      # ablation: no DP noise
    # Registry accountant (repro.privacy): how the total (eps, delta) is
    # split/composed over the transmissions. "basic" = the historical
    # eps/5 (eps/6 untrusted) split, byte-identical.
    accountant: str = "basic"
