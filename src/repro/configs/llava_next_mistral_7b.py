"""LLaVA-NeXT (Mistral-7B backbone), anyres tiling. [hf:llava-hf/llava-v1.6-mistral-7b-hf]

Backbone only: vision tower + projector are stubs; input_specs() provides
576 precomputed patch embeddings prepended to the text tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, n_patches=576, rope_theta=1e6,
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
