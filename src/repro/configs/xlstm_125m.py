"""xLSTM-125M: sLSTM + mLSTM blocks, no FFN (d_ff=0). [arXiv:2405.04517]

sLSTM at layers {1, 7} (~7:1 mLSTM:sLSTM), mLSTM elsewhere, in the
stabilised parallel formulation. 4 heads are the mLSTM memory heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, slstm_at=(1, 7),
    citation="arXiv:2405.04517",
)
