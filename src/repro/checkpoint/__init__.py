"""Checkpointing (flat-path npz, atomic)."""
from repro.checkpoint.checkpoint import save, restore

__all__ = ["save", "restore"]
