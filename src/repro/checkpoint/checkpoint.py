"""Checkpointing: flat-path .npz snapshots with atomic rename.

Saves params + optimizer state + step + config metadata. Paths are
"a/b/c" joins of the pytree dict keys (list indices as numbers), so a
checkpoint is restorable without pickling arbitrary objects.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        flat[path] = np.asarray(leaf)
    return flat


def save(path: str, params: Any, opt_state: Any = None,
         step: int = 0, meta: Optional[Dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        payload.update({f"opt/{k}": v
                        for k, v in _flatten(opt_state).items()})
    payload["__step__"] = np.asarray(step)
    payload["__meta__"] = np.frombuffer(
        json.dumps(meta or {}).encode(), dtype=np.uint8)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
    os.replace(tmp, path)


def restore(path: str, params_like: Any, opt_like: Any = None
            ) -> Tuple[Any, Any, int, Dict]:
    """Restore into the structure of templates (shape/dtype validated)."""
    with np.load(path) as z:
        step = int(z["__step__"])
        meta = json.loads(bytes(z["__meta__"]).decode() or "{}")

        def fill(template, prefix):
            leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
            out = []
            for kp, leaf in leaves:
                p = prefix + "/".join(
                    str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in kp)
                arr = z[p]
                if tuple(arr.shape) != tuple(leaf.shape):
                    raise ValueError(
                        f"shape mismatch at {p}: ckpt {arr.shape} vs "
                        f"template {leaf.shape}")
                out.append(jnp.asarray(arr, dtype=leaf.dtype))
            return jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(template), out)

        params = fill(params_like, "params/")
        opt_state = fill(opt_like, "opt/") if opt_like is not None else None
    return params, opt_state, step, meta
