"""Production meshes. Functions (not module constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax call.

Target hardware: TPU v5e pods; 256 chips/pod in a (16, 16) = (data, model)
layout; the multi-pod config stacks a leading "pod" axis (2, 16, 16).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1) -> Mesh:
    """Whatever devices exist locally, data-major (CPU tests/examples)."""
    n = len(jax.devices())
    data = n // model
    return make_mesh((data, model), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))


def make_machine_mesh(m: int) -> Mesh:
    """1-D mesh for the SPMD protocol (one device per machine)."""
    return make_mesh((m,), ("machines",), axis_types=(AxisType.Auto,))


# roofline hardware constants (TPU v5e, per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link
