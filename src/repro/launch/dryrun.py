import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any jax import (jax locks the device
# count at first init). Everything else follows.
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")  # quiet SPMD warnings
"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes, record memory/cost/collective analysis.

Usage:
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod
  python -m repro.launch.dryrun ... --agg dcq --strategy sharded

Outputs one JSON per combination under experiments/dryrun/.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.shapes import adapt_config, input_specs
from repro.dist.grad_agg import GradAggConfig
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.models import sharding as shd
from repro.models.model import Model
from repro.train.optimizer import AdamW
from repro.train.trainer import TrainConfig, make_train_step


def lower_one(arch: str, shape_name: str, multi_pod: bool,
              agg: str = "dcq", strategy: str = "replicated",
              fsdp: bool = False, donate: bool = True,
              cfg_override=None, kv_mode: str = "auto",
              grad_dtype: str = "", moe_cf: float = 0.0,
              microbatch: int = 0, moe_shard: bool = False,
              moe_dispatch: int = 0):
    """Build + lower + compile one combination; returns (compiled, meta)."""
    import dataclasses
    shape = SHAPES[shape_name]
    cfg = cfg_override if cfg_override is not None \
        else adapt_config(get_config(arch), shape)
    if cfg.moe is not None and (moe_cf or moe_shard or moe_dispatch):
        moe_new = cfg.moe
        if moe_cf:
            moe_new = dataclasses.replace(moe_new, capacity_factor=moe_cf)
        if moe_shard:
            moe_new = dataclasses.replace(moe_new, shard_buffers=True)
        if moe_dispatch:
            moe_new = dataclasses.replace(moe_new,
                                          dispatch_shards=moe_dispatch)
        cfg = dataclasses.replace(cfg, moe=moe_new)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    model = Model(cfg, remat=True)
    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    # robust aggregation uses the data axis as the machine axis => weights
    # cannot be FSDP-sharded over it in robust mode unless requested.
    pshard = shd.param_shardings(params_shapes, mesh, cfg, fsdp=fsdp)
    specs = input_specs(cfg, shape)

    with mesh:
        if shape.kind == "train":
            n_machines = chips // mesh.shape["model"]
            tcfg = TrainConfig(
                n_machines=n_machines, remat=True, fsdp=fsdp,
                grad_dtype=grad_dtype, microbatch=microbatch,
                agg=GradAggConfig(method=agg, dp_sigma=1e-5,
                                  strategy=strategy))
            opt = AdamW(lr=1e-4)
            opt_shapes = jax.eval_shape(opt.init, params_shapes)
            opt_shard = type(opt_shapes)(
                step=NamedSharding(mesh, P()),
                mu=pshard, nu=pshard)
            bshard = shd.batch_shardings(specs, mesh)
            step_fn = make_train_step(model, opt, tcfg, mesh)
            key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
            lowered = jax.jit(
                step_fn,
                in_shardings=(pshard, opt_shard, bshard, None),
                donate_argnums=(0, 1) if donate else (),
            ).lower(params_shapes, opt_shapes, specs, key_spec)
        elif shape.kind == "prefill":
            bshard = shd.batch_shardings(specs, mesh)

            def prefill(params, batch):
                logits, _ = model.forward(params, batch)
                # serving returns last-position logits only
                return logits[:, -1]
            lowered = jax.jit(
                prefill, in_shardings=(pshard, bshard),
            ).lower(params_shapes, specs)
        else:  # decode
            cache_spec = specs["cache"]
            cshard = shd.cache_shardings(cache_spec, mesh, kv_mode=kv_mode)
            tok_shard = shd.batch_shardings({"tokens": specs["tokens"]},
                                            mesh)

            def serve_step(params, cache, batch):
                logits, cache = model.decode_step(params, cache, batch)
                return jnp.argmax(logits[:, -1], axis=-1), cache
            lowered = jax.jit(
                serve_step,
                in_shardings=(pshard, cshard, tok_shard),
                donate_argnums=(1,) if donate else (),
            ).lower(params_shapes, cache_spec,
                    {"tokens": specs["tokens"]})
        compiled = lowered.compile()

    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "chips": chips, "agg": agg, "strategy": strategy, "fsdp": fsdp,
            "kind": shape.kind, "kv_mode": kv_mode,
            "sliding_window": cfg.sliding_window}
    return compiled, cfg, shape, meta


def _probe_costs(arch, shape_name, multi_pod, agg, strategy, fsdp, cfg,
                 kw=None):
    """L=1 / L=2 probe compiles to correct scan-once cost analysis.

    XLA's HloCostAnalysis counts a while-loop body once (verified
    empirically), so probes trace in repro.models.modes.probe_mode:
      * layer scans unrolled -> per-layer byte/collective increments;
      * exact_chunks=True additionally collapses flash/mLSTM chunk scans
        into one chunk (same algebraic FLOP count as the chunked
        schedule) -> exact FLOP increments.
    FLOPs are taken from the exact probes; bytes/collectives from the
    chunked probes (= KV streamed once per layer, the fused-kernel ideal;
    recorded in EXPERIMENTS.md §Roofline methodology).
    The hybrid family gets extra probes (attn_every=0 vs 1) to price the
    shared attention block separately from the cond's accounting.
    """
    import dataclasses
    from repro.models import modes

    def probe(n_layers, exact, attn_every=None):
        c = dataclasses.replace(
            cfg, n_layers=n_layers,
            attn_every=(attn_every if attn_every is not None
                        else cfg.attn_every),
            slstm_at=())
        with modes.probe_mode(unroll_layers=True, exact_chunks=exact):
            comp, *_ = lower_one(arch, shape_name, multi_pod, agg,
                                 strategy, fsdp, donate=False,
                                 cfg_override=c, **(kw or {}))
            return roofline.module_costs(comp)

    every = 0 if cfg.family == "hybrid" else None
    out = {}
    for tag, exact in (("bytes", False), ("flops", True)):
        c1 = probe(1, exact, attn_every=every)
        c2 = probe(2, exact, attn_every=every)
        out[tag] = {"c1": c1, "c2": c2}
        if cfg.family == "hybrid":
            out[tag]["c_attn"] = probe(1, exact, attn_every=1)
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool, outdir: str,
            agg: str, strategy: str, fsdp: bool, kv_mode: str = "auto",
            grad_dtype: str = "", moe_cf: float = 0.0,
            microbatch: int = 0, tag_extra: str = "",
            moe_shard: bool = False, moe_dispatch: int = 0,
            skip_probes: bool = False) -> dict:
    t0 = time.time()
    kw = dict(kv_mode=kv_mode, grad_dtype=grad_dtype, moe_cf=moe_cf,
              microbatch=microbatch, moe_shard=moe_shard,
              moe_dispatch=moe_dispatch)
    compiled, cfg, shape, meta = lower_one(arch, shape_name, multi_pod,
                                           agg, strategy, fsdp, **kw)
    costs = None
    if cfg.family != "ssm" and not skip_probes:
        # xlstm python-loops layers: HLO is exact; skip_probes (multi-pod
        # sweep) records raw scan-once costs — the roofline table is
        # single-pod only
        probes = _probe_costs(arch, shape_name, multi_pod, agg, strategy,
                              fsdp, cfg, kw)
        raw = roofline.module_costs(compiled)
        cost_b = roofline.extrapolate_layers(
            raw, probes["bytes"]["c1"], probes["bytes"]["c2"],
            cfg.n_layers)
        cost_f = roofline.extrapolate_layers(
            raw, probes["flops"]["c1"], probes["flops"]["c2"],
            cfg.n_layers)
        costs = {"flops": cost_f["flops"], "bytes": cost_b["bytes"],
                 "coll": cost_b["coll"], "corrected": True}
        if cfg.family == "hybrid":
            # add the shared-attn increment for its n_shared applications
            n_shared = cfg.n_layers // cfg.attn_every
            for tag, field in (("flops", "flops"), ("bytes", "bytes")):
                ca = probes[tag]["c_attn"]
                c1 = probes[tag]["c1"]
                costs[field] += n_shared * max(ca[field] - c1[field], 0)
            ca, c1 = probes["bytes"]["c_attn"], probes["bytes"]["c1"]
            for op in costs["coll"]:
                costs["coll"][op] += n_shared * max(
                    ca["coll"].get(op, 0) - c1["coll"].get(op, 0), 0)
        costs["coll"]["total"] = sum(
            v for k, v in costs["coll"].items() if k != "total")
    report = roofline.analyze(compiled, cfg, shape, meta["mesh"],
                              meta["chips"], arch, costs=costs)
    mem = compiled.memory_analysis()
    meta.update(report.asdict())
    meta["compile_s"] = time.time() - t0
    meta["memory_analysis"] = {
        "argument_size": getattr(mem, "argument_size_in_bytes", None),
        "output_size": getattr(mem, "output_size_in_bytes", None),
        "temp_size": getattr(mem, "temp_size_in_bytes", None),
        "alias_size": getattr(mem, "alias_size_in_bytes", None),
        "generated_code_size": getattr(mem, "generated_code_size_in_bytes",
                                       None),
    }
    meta["variant"] = tag_extra
    os.makedirs(outdir, exist_ok=True)
    tag = f"{arch}_{shape_name}_{meta['mesh']}_{agg}_{strategy}" \
          + ("_fsdp" if fsdp else "") + tag_extra
    with open(os.path.join(outdir, tag + ".json"), "w") as f:
        json.dump(meta, f, indent=1, default=str)
    print(f"[dryrun] {tag}: OK in {meta['compile_s']:.1f}s | "
          f"dominant={meta['dominant']} compute={meta['compute_s']:.4g}s "
          f"memory={meta['memory_s']:.4g}s "
          f"collective={meta['collective_s']:.4g}s | "
          f"peak_mem={meta['peak_memory_bytes']}")
    return meta


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--agg", default="dcq",
                    choices=["mean", "median", "trimmed", "dcq"])
    ap.add_argument("--strategy", default="replicated",
                    choices=["replicated", "sharded"])
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--kv-mode", default="auto",
                    choices=["auto", "seq", "replicate"])
    ap.add_argument("--grad-dtype", default="")
    ap.add_argument("--moe-cf", type=float, default=0.0)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--moe-shard", action="store_true")
    ap.add_argument("--moe-dispatch", type=int, default=0)
    ap.add_argument("--skip-probes", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--outdir", default="experiments/dryrun")
    args = ap.parse_args(argv)

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_one(arch, shape, mp, args.outdir, args.agg,
                            args.strategy, args.fsdp, args.kv_mode,
                            args.grad_dtype, args.moe_cf, args.microbatch,
                            args.tag, args.moe_shard, args.moe_dispatch,
                            args.skip_probes)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mp, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("all dry-runs OK")


if __name__ == "__main__":
    main()
