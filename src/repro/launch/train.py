"""Training launcher: robust-DP data-parallel training of any --arch.

CPU-scale entry point (reduced configs train for real; full configs only
lower — use launch/dryrun.py for those). Demonstrates the paper's
aggregation as a production training feature:

  python -m repro.launch.train --arch xlstm-125m --steps 50 \
      --agg dcq --dp-sigma 1e-4 --byzantine 0.1 --attack scale
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.attacks import ALIASES as ATTACK_ALIASES
from repro.attacks import registered as registered_attacks
from repro.checkpoint import checkpoint
from repro.configs import get_config
from repro.data.lm import synthetic_lm_batches
from repro.dist.grad_agg import GradAggConfig
from repro.models.model import Model
from repro.train.optimizer import AdamW
from repro.train.trainer import TrainConfig, Trainer


def build_parser() -> argparse.ArgumentParser:
    """The launcher CLI; --attack accepts every registered repro.attacks
    name plus the historical aliases (resolved by the registry)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--machines", type=int, default=4)
    ap.add_argument("--agg", default="dcq",
                    choices=["mean", "median", "trimmed", "dcq"])
    ap.add_argument("--dp-sigma", type=float, default=0.0)
    ap.add_argument("--byzantine", type=float, default=0.0)
    ap.add_argument("--attack", default="scale",
                    choices=sorted(set(registered_attacks())
                                   | set(ATTACK_ALIASES)))
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    model = Model(cfg, remat=True)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name} ({'reduced' if args.reduced else 'full'}): "
          f"{n_params/1e6:.1f}M params, {args.machines} machines, "
          f"agg={args.agg} sigma={args.dp_sigma} byz={args.byzantine}")

    attack = args.attack if args.byzantine > 0 else "none"
    tcfg = TrainConfig(
        n_machines=args.machines, remat=True,
        agg=GradAggConfig(method=args.agg, dp_sigma=args.dp_sigma,
                          attack=attack))
    opt = AdamW(lr=args.lr)
    trainer = Trainer(model, opt, tcfg)

    n_byz = int(args.byzantine * args.machines)
    byz_mask = (jnp.arange(args.machines) < n_byz) if n_byz else None
    batches = synthetic_lm_batches(jax.random.PRNGKey(1), cfg, args.steps,
                                   args.batch, args.seq)

    t0 = time.time()
    losses = []

    def cb(i, metrics):
        losses.append(float(metrics["loss"]))
        if i % 10 == 0:
            print(f"  step {i:4d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({time.time()-t0:.1f}s)")

    params, opt_state, _ = trainer.fit(params, batches,
                                       jax.random.PRNGKey(2),
                                       byz_mask=byz_mask, callback=cb)
    print(f"[train] done: first loss {losses[0]:.4f} -> last "
          f"{losses[-1]:.4f} in {time.time()-t0:.1f}s")
    if args.ckpt:
        checkpoint.save(args.ckpt, params, opt_state, step=args.steps,
                        meta={"arch": args.arch, "agg": args.agg})
        print(f"[train] checkpoint -> {args.ckpt}")
    return losses


if __name__ == "__main__":
    main()
