"""Training launcher: robust-DP training of any model-zoo --config.

CPU-scale entry point (reduced configs train for real; full configs only
lower — use launch/dryrun.py for those). Two optimizer paths share the
wire layer (core/transport.py):

  * ``--optimizer adamw`` (default): per-machine gradients -> attack ->
    DP noise -> robust aggregation -> AdamW (train/trainer.Trainer);
  * ``--optimizer qn``: every step IS one run of the paper's Algorithm 1
    over the parameter pytree — five DP transmissions, per-leaf
    calibrated noise, shared L-BFGS curvature (train/trainer.QNTrainer).

  python -m repro.launch.train --config xlstm-125m --steps 50 \
      --optimizer qn --eps 10 --byzantine 0.25 --attack signflip

``--sharded`` places the machine axis over all visible devices (pair
with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` on CPU).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.agg import registered as registered_aggregators
from repro.attacks import ALIASES as ATTACK_ALIASES
from repro.attacks import registered as registered_attacks
from repro.checkpoint import checkpoint
from repro.configs import get_config
from repro.core.keys import stream_key
from repro.configs.base import TreeProtocolConfig
from repro.data.lm import synthetic_lm_batches
from repro.dist.grad_agg import GradAggConfig
from repro.launch.cli import add_common_flags, machine_mesh
from repro.models.model import Model
from repro.train.optimizer import AdamW
from repro.train.trainer import (QNTrainConfig, QNTrainer, TrainConfig,
                                 Trainer)


def build_parser() -> argparse.ArgumentParser:
    """The launcher CLI; --attack accepts every registered repro.attacks
    name plus the historical aliases (resolved by the registry)."""
    ap = add_common_flags(argparse.ArgumentParser())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--machines", type=int, default=4)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "qn"],
                    help="adamw: robust-aggregated data parallel; "
                    "qn: the paper's five-transmission quasi-Newton "
                    "protocol as the train step")
    ap.add_argument("--agg", default="dcq",
                    choices=sorted(registered_aggregators()),
                    help="robust aggregator (repro.agg registry); \"dcq\" "
                    "means the MAD-self-calibrated \"dcq_mad\" on both "
                    "paths — the training wire carries no variance "
                    "estimates")
    ap.add_argument("--dp-sigma", type=float, default=0.0)
    ap.add_argument("--eps", type=float, default=0.0,
                    help="per-step DP budget; > 0 turns on per-leaf "
                    "calibrated noise (eps/5 per transmission on the qn "
                    "path, mean-mechanism sigma on the adamw path)")
    ap.add_argument("--byzantine", type=float, default=0.0)
    ap.add_argument("--attack", default="scale",
                    choices=sorted(set(registered_attacks())
                                   | set(ATTACK_ALIASES)))
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--hist", type=int, default=5,
                    help="L-BFGS memory length (qn path)")
    ap.add_argument("--ckpt", default="")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    model = Model(cfg, remat=True)
    params = model.init(stream_key(args.seed, "params"))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name} ({'reduced' if args.reduced else 'full'}): "
          f"{n_params/1e6:.1f}M params, {args.machines} machines, "
          f"opt={args.optimizer} agg={args.agg} sigma={args.dp_sigma} "
          f"eps={args.eps} byz={args.byzantine}")

    mesh = None
    if args.sharded:
        mesh = machine_mesh(args.machines)
        print(f"[train] machine axis sharded over "
              f"{jax.device_count()} device(s)")

    attack = args.attack if args.byzantine > 0 else "none"
    if args.optimizer == "qn":
        # the qn wire transmits no variance estimates, so oracle-scale
        # "dcq" maps to its MAD-self-calibrated variant (grad_agg does
        # the same mapping on the adamw path)
        agg = "dcq_mad" if args.agg == "dcq" else args.agg
        qcfg = QNTrainConfig(
            n_machines=args.machines, attack=attack,
            protocol=TreeProtocolConfig(hist=args.hist, lr=args.lr,
                                        eps=args.eps, aggregator=agg,
                                        accountant=args.accountant))
        trainer = QNTrainer(model, qcfg, mesh=mesh)
    else:
        tcfg = TrainConfig(
            n_machines=args.machines, remat=True,
            agg=GradAggConfig(method=args.agg, dp_sigma=args.dp_sigma,
                              attack=attack, dp_eps=args.eps,
                              dp_n=args.batch // args.machines))
        opt = AdamW(lr=args.lr)
        trainer = Trainer(model, opt, tcfg, mesh=mesh)

    n_byz = int(args.byzantine * args.machines)
    byz_mask = (jnp.arange(args.machines) < n_byz) if n_byz else None
    batches = synthetic_lm_batches(stream_key(args.seed, "batches"), cfg,
                                   args.steps, args.batch, args.seq)

    t0 = time.time()
    losses = []

    def cb(i, metrics):
        losses.append(float(metrics["loss"]))
        if i % 10 == 0:
            print(f"  step {i:4d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({time.time()-t0:.1f}s)")

    params, opt_state, _ = trainer.fit(params, batches,
                                       stream_key(args.seed, "protocol"),
                                       byz_mask=byz_mask, callback=cb)
    print(f"[train] done: first loss {losses[0]:.4f} -> last "
          f"{losses[-1]:.4f} in {time.time()-t0:.1f}s")
    if args.ckpt:
        checkpoint.save(args.ckpt, params, opt_state, step=args.steps,
                        meta={"arch": args.arch, "agg": args.agg,
                              "optimizer": args.optimizer})
        print(f"[train] checkpoint -> {args.ckpt}")
    return losses


if __name__ == "__main__":
    main()
