"""Shared CLI flags for the launchers (train / serve).

Both launchers address the same model zoo and the same reproducibility
and placement knobs; this module is the single definition of those
flags so ``python -m repro.launch.train --help`` and
``... launch.serve --help`` never drift apart on them.
"""
from __future__ import annotations

import argparse


def add_common_flags(ap: argparse.ArgumentParser,
                     arch_default: str = "xlstm-125m"
                     ) -> argparse.ArgumentParser:
    """The flags every launcher shares: model selection, root seed,
    device placement."""
    ap.add_argument("--config", "--arch", dest="arch", default=arch_default,
                    help="model-zoo config name (repro.configs.ARCHS)")
    ap.add_argument("--seed", type=int, default=0,
                    help="root seed; per-purpose keys are derived as "
                    "independent fold_in streams (repro.core.keys)")
    ap.add_argument("--sharded", action="store_true",
                    help="shard the machine axis over all visible devices")
    from repro.privacy import registered as registered_accountants
    ap.add_argument("--accountant", default="basic",
                    choices=registered_accountants(),
                    help="repro.privacy accountant splitting the total "
                    "(eps, delta) over the DP transmissions (default: "
                    "basic, the paper's even split)")
    return ap


def machine_mesh(n_machines: int):
    """A 1-D device mesh over the machine axis, validating divisibility
    (pair with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` on
    CPU)."""
    import jax

    from repro.compat import make_mesh
    n_dev = jax.device_count()
    if n_machines % n_dev:
        raise SystemExit(f"--machines {n_machines} does not divide over "
                         f"{n_dev} devices")
    return make_mesh((n_dev,), ("machines",))
