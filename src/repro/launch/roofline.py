"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch x shape x mesh), all in seconds-per-step:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-partition module,
so already per-device). Collective bytes are parsed from the
post-optimisation HLO: we sum the *result-shape* bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(async "-start" forms counted once; "-done" skipped). Result-shape bytes
are the payload a device receives — a consistent, implementation-honest
proxy for wire bytes per device.

MODEL_FLOPS (the "useful" 6ND / 2ND accounting) uses parameter counts from
eval_shape, with MoE active-parameter correction.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import mesh as meshmod

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shapes appearing in an instruction's result, e.g. bf16[16,1024]{1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of collective ops in (post-opt) HLO text."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        lhs, _, rhs = line.partition("=")
        rhs = rhs.strip()
        # find which collective op this is (skip -done; count -start once)
        opname = None
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(-start)?\(", rhs):
                opname = c
                break
        if opname is None or f"{opname}-done(" in rhs:
            continue
        # result shapes live between '=' and the op name
        head = rhs.split(opname)[0]
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        out[opname] += nbytes
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def count_params(cfg: ModelConfig) -> Dict[str, int]:
    """Total and active parameter counts (active: MoE uses top_k experts)."""
    import math
    from repro.models.model import Model
    model = Model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    total = sum(math.prod(leaf.shape)
                for leaf in jax.tree_util.tree_leaves(shapes))
    active = total
    if cfg.moe is not None:
        per_expert = 3 * cfg.d_model * cfg.moe.d_ff_expert
        inactive = (cfg.moe.n_experts - cfg.moe.top_k) * per_expert \
            * cfg.n_layers
        active = total - inactive
    return {"total": total, "active": active}


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N_active*D for training, 2*N_active*D for inference forward."""
    n = count_params(cfg)["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    peak_memory_bytes: Optional[float] = None

    def asdict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def module_costs(compiled) -> Dict[str, float]:
    """flops / bytes / collective bytes of one compiled executable.

    CAVEAT (handled by ``extrapolate_layers``): XLA's HloCostAnalysis
    visits a while-loop body ONCE — a model that lax.scans its L layers
    reports ~1 layer of FLOPs. The dry-run therefore compiles L=1 and L=2
    probes and linearly extrapolates: cost(L) = c1 + (L-1) * (c2 - c1).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, list):     # older API returned [dict]
        cost = cost[0]
    coll = parse_collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": dict(coll)}


def extrapolate_layers(c_full: Dict, c1: Optional[Dict], c2: Optional[Dict],
                       n_layers: int) -> Dict[str, float]:
    """Correct scan-once costs: full-module HLO counts the scanned layer
    body once; probes at L=1/L=2 give the per-layer increment."""
    if c1 is None or c2 is None:
        out = dict(c_full)
        out["corrected"] = False
        return out
    out = {}
    for k in ("flops", "bytes"):
        d = max(c2[k] - c1[k], 0.0)
        out[k] = c1[k] + (n_layers - 1) * d
    coll = {}
    for op in set(c_full["coll"]) | set(c1["coll"]):
        d = max(c2["coll"].get(op, 0) - c1["coll"].get(op, 0), 0)
        coll[op] = c1["coll"].get(op, 0) + (n_layers - 1) * d
    out["coll"] = coll
    out["corrected"] = True
    return out


def analyze(compiled, cfg: ModelConfig, shape: ShapeConfig,
            mesh_name: str, chips: int, arch: str,
            costs: Optional[Dict] = None) -> RooflineReport:
    raw = module_costs(compiled)
    c = costs if costs is not None else raw
    flops = c["flops"]
    nbytes = c["bytes"]
    coll = c["coll"]
    compute_s = flops / meshmod.PEAK_FLOPS_BF16
    memory_s = nbytes / meshmod.HBM_BW
    collective_s = coll["total"] / meshmod.ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful = mf / max(flops * chips, 1.0)
    peak = None
    try:
        ma = compiled.memory_analysis()
        peak = float(getattr(ma, "temp_size_in_bytes", 0)
                     + getattr(ma, "argument_size_in_bytes", 0)
                     + getattr(ma, "output_size_in_bytes", 0)
                     - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=nbytes,
        collective_bytes=coll, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dominant, model_flops=mf,
        useful_ratio=useful, peak_memory_bytes=peak)
