"""Serving launcher: the streaming aggregation service over a simulated
fleet.

Stands up :class:`repro.serve.AggregationService` around a model-zoo
parameter pytree and drives it with synthetic fleet traffic — machine
updates stream in (optionally Byzantine-corrupted through the
``repro.attacks`` registry and thinned by a straggler dropout rate), the
device-resident ring buffer absorbs them with compiled donated writes,
and the single compiled masked-aggregation step serves a model update
every time the flush policy fires. Partial fleets (stragglers) flush at
the deadline with the SAME executable — ``fill`` is a traced scalar.

  python -m repro.launch.serve --config xlstm-125m --machines 64 \
      --rounds 5 --agg dcq_mad --eps 1.0 --byzantine 0.2 --attack signflip

``--sharded`` places the ring buffer's capacity axis over all visible
devices (pair with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
on CPU).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.agg import has_masked
from repro.agg import registered as registered_aggregators
from repro.attacks import ALIASES as ATTACK_ALIASES
from repro.attacks import registered as registered_attacks
from repro.configs import get_config
from repro.core.keys import stream_key
from repro.core.transport import wire_corrupt
from repro.launch.cli import add_common_flags, machine_mesh
from repro.models.model import Model
from repro.serve import AggregationService, FlushPolicy, ServeConfig


def build_parser() -> argparse.ArgumentParser:
    """The serving CLI; mirrors launch/train.py (shared flags come from
    launch/cli.py, --agg/--attack from the registries)."""
    ap = add_common_flags(argparse.ArgumentParser())
    ap.add_argument("--machines", type=int, default=64,
                    help="fleet size per round (ring-buffer capacity)")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--agg", default="dcq_mad",
                    choices=sorted(n for n in registered_aggregators()
                                   if has_masked(n)),
                    help="robust aggregator (repro.agg registry, masked "
                    "partial-fill form required for serving)")
    ap.add_argument("--eps", type=float, default=0.0,
                    help="per-round DP budget; > 0 adds per-leaf "
                    "calibrated noise inside the compiled step")
    ap.add_argument("--delta", type=float, default=1e-6)
    ap.add_argument("--byzantine", type=float, default=0.0,
                    help="fraction of the fleet sending corrupted updates")
    ap.add_argument("--attack", default="scale",
                    choices=sorted(set(registered_attacks())
                                   | set(ATTACK_ALIASES)))
    ap.add_argument("--attack-factor", type=float, default=-3.0)
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="straggler fraction: each round this share of "
                    "the fleet never arrives and the round flushes "
                    "partial (same executable, traced fill)")
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--ingest-block", type=int, default=64,
                    help="bulk-ingest chunk (one compiled write per chunk)")
    ap.add_argument("--min-fill", type=int, default=1)
    return ap


def fleet_round(key: jax.Array, params, m: int, byz_mask, attack: str,
                factor: float):
    """One round of synthetic fleet traffic: unit-scale machine updates
    around a shared drift, Byzantine rows corrupted on the wire."""
    k_drift, k_noise, k_byz = jax.random.split(key, 3)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    kd = jax.random.split(k_drift, len(leaves))
    kn = jax.random.split(k_noise, len(leaves))
    ups = [jax.random.normal(d, x.shape, x.dtype)
           + 0.3 * jax.random.normal(n, (m,) + x.shape, x.dtype)
           for x, d, n in zip(leaves, kd, kn)]
    updates = jax.tree_util.tree_unflatten(treedef, ups)
    return wire_corrupt(k_byz, updates, byz_mask, attack=attack,
                        factor=factor)


def main(argv=None):
    args = build_parser().parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    model = Model(cfg)
    params = model.init(stream_key(args.seed, "params"))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))

    sharding = None
    if args.sharded:
        from jax.sharding import NamedSharding, PartitionSpec
        mesh = machine_mesh(args.machines)
        sharding = NamedSharding(mesh, PartitionSpec("machines"))
        print(f"[serve] ring buffer sharded over "
              f"{jax.device_count()} device(s)")

    scfg = ServeConfig(method=args.agg, capacity=args.machines,
                       lr=args.lr, eps=args.eps, delta=args.delta,
                       ingest_block=min(args.ingest_block, args.machines),
                       seed=args.seed, accountant=args.accountant)
    policy = FlushPolicy(min_fill=args.min_fill)
    svc = AggregationService(params, scfg, policy=policy,
                             sharding=sharding)
    print(f"[serve] {cfg.name}: {n_params/1e6:.1f}M params, fleet "
          f"m={args.machines}, agg={args.agg} eps={args.eps} "
          f"byz={args.byzantine} dropout={args.dropout}")

    n_byz = int(args.byzantine * args.machines)
    byz_mask = (jnp.arange(args.machines) < n_byz) if n_byz else None
    attack = args.attack if n_byz else "none"

    t0 = time.time()
    for r in range(args.rounds):
        key = stream_key(args.seed, "serve", index=r + 1)
        updates = fleet_round(key, params, args.machines, byz_mask,
                              attack, args.attack_factor)
        arrive = args.machines
        if args.dropout > 0:
            arrive = max(args.min_fill,
                         args.machines - int(args.dropout * args.machines))
            updates = jax.tree_util.tree_map(lambda x: x[:arrive], updates)
        svc.submit_many(updates)
        if svc.fill:             # stragglers: deadline-style partial flush
            svc.flush()
        h = svc.history[-1]
        print(f"  round {h['round']:3d} fill {h['fill']:5d}/"
              f"{args.machines} latency {h['latency_s']*1e3:7.2f} ms")
    dt = time.time() - t0

    served = sum(h["fill"] for h in svc.history)
    steady = [h["flush_s"] for h in svc.history[1:]] or \
        [svc.history[-1]["flush_s"]]
    print(f"[serve] {svc.round_idx} rounds, {served} updates in "
          f"{dt:.2f}s; steady flush {min(steady)*1e3:.2f} ms; "
          f"traces {svc.trace_counts}")
    if args.eps > 0:
        print(svc.accountant.summary())
    return svc


if __name__ == "__main__":
    main()
