"""Serving launcher: batched prefill + decode with a KV/state cache.

CPU-scale demo on reduced configs (full configs lower via dryrun):

  python -m repro.launch.serve --arch glm4-9b --batch 4 --prompt-len 32 \
      --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.keys import stream_key
from repro.models.model import Model


def prefill_into_cache(model: Model, params, tokens, cache):
    """Feed a prompt token-by-token (functional reference prefill; the
    chunked flash prefill produces the same logits — tested)."""
    step = jax.jit(model.decode_step)
    B, S = tokens.shape[:2]
    logits = None
    for t in range(S):
        tok = tokens[:, t:t + 1]
        if model.cfg.family == "audio":
            tok = tokens[:, t:t + 1, :]
        logits, cache = step(params, cache, {"tokens": tok})
    return logits, cache


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="root seed; init/prompt/sampling keys are derived "
                    "as independent fold_in streams (repro.core.keys)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    model = Model(cfg)
    params = model.init(stream_key(args.seed, "params"))
    B = args.batch
    max_len = args.prompt_len + args.gen
    cache = model.init_cache(B, max_len)

    # the prompt and the decode sampling loop are separate streams: the
    # historical single key was consumed by randint AND re-split in the
    # decode loop, correlating prompts with sampling noise
    prompt_key = stream_key(args.seed, "serve", index=0)
    if cfg.family == "audio":
        prompt = jax.random.randint(prompt_key, (B, args.prompt_len,
                                                 cfg.n_codebooks),
                                    0, cfg.vocab)
    else:
        prompt = jax.random.randint(prompt_key, (B, args.prompt_len),
                                    0, cfg.vocab)
    key = stream_key(args.seed, "serve", index=1)

    t0 = time.time()
    logits, cache = prefill_into_cache(model, params, prompt, cache)
    t_prefill = time.time() - t0
    print(f"[serve] {cfg.name}: prefill {args.prompt_len} tokens x{B} "
          f"in {t_prefill:.2f}s")

    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], axis=-1)
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        key, sub = jax.random.split(key)
        t = tok[:, None]
        if cfg.family == "audio":
            t = jnp.tile(t[..., None], (1, 1, cfg.n_codebooks))
        logits, cache = step(params, cache, {"tokens": t})
        if args.temperature > 0:
            tok = jax.random.categorical(sub,
                                         logits[:, -1] / args.temperature)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)
        generated.append(tok)
    dt = time.time() - t0
    toks = jnp.stack(generated, axis=1)
    print(f"[serve] generated {args.gen} tokens x{B} in {dt:.2f}s "
          f"({B*args.gen/max(dt,1e-9):.1f} tok/s); "
          f"sample row 0: {toks[0][:16].tolist()}")
    return toks


if __name__ == "__main__":
    main()
