"""Synthetic LM data: a deterministic Markov token stream so training has
learnable structure (loss drops measurably within tens of steps).

Each vocab id v prefers successor (a*v + c) mod V with probability q and
otherwise uniform — a next-token distribution a small model can learn,
making the robust-training examples' loss curves meaningful.
"""
from __future__ import annotations

from typing import Dict, Iterator

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def markov_tokens(key: jax.Array, batch: int, seq: int, vocab: int,
                  q: float = 0.8) -> jnp.ndarray:
    a, c = 31, 17
    k1, k2, k3 = jax.random.split(key, 3)
    first = jax.random.randint(k1, (batch, 1), 0, vocab)
    flips = jax.random.bernoulli(k2, q, (batch, seq - 1))
    rand = jax.random.randint(k3, (batch, seq - 1), 0, vocab)

    def step(prev, inp):
        flip, r = inp
        nxt = jnp.where(flip, (a * prev + c) % vocab, r)
        return nxt, nxt

    _, rest = jax.lax.scan(step, first[:, 0],
                           (flips.T, rand.T))
    return jnp.concatenate([first, rest.T], axis=1)


def make_batch(key: jax.Array, cfg: ModelConfig, batch: int,
               seq: int) -> Dict[str, jnp.ndarray]:
    toks = markov_tokens(key, batch, seq + 1, cfg.vocab)
    inputs, labels = toks[:, :-1], toks[:, 1:]
    if cfg.family == "audio":
        inputs = jnp.tile(inputs[..., None], (1, 1, cfg.n_codebooks))
        return {"tokens": inputs, "labels": labels}
    if cfg.family == "vlm":
        patches = 0.1 * jax.random.normal(
            jax.random.fold_in(key, 1), (batch, cfg.n_patches, 1024),
            jnp.float32)
        return {"tokens": inputs, "labels": labels,
                "patch_embeds": patches}
    return {"tokens": inputs, "labels": labels}


def synthetic_lm_batches(key: jax.Array, cfg: ModelConfig, steps: int,
                         batch: int, seq: int) -> Iterator[Dict]:
    for i in range(steps):
        yield make_batch(jax.random.fold_in(key, i), cfg, batch, seq)
