"""Synthetic data generation (paper §5.1) and the token pipeline for the
architecture smoke tests / LLM training examples.

Regression designs follow the paper exactly:
  * X ~ N(0, Sigma_T), Sigma_T Toeplitz with entry rho^{|i-j|}, rho = 0.6;
  * theta* = p^{-1/2} (1/2, ..., 1/2);
  * logistic: Y ~ Bernoulli(sigmoid(X theta*));
  * Poisson:  X resampled until |X theta*| <= 1, Y ~ Poisson(exp(X theta*)).

``make_shards`` lays data out as (m+1, n, ...) with machine 0 the center.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def toeplitz_cov(p: int, rho: float = 0.6) -> jnp.ndarray:
    idx = jnp.arange(p)
    return rho ** jnp.abs(idx[:, None] - idx[None, :])


def target_theta(p: int) -> jnp.ndarray:
    return jnp.full((p,), 0.5) / jnp.sqrt(p)


def sample_x(key: jax.Array, n: int, p: int, rho: float = 0.6) -> jnp.ndarray:
    cov = toeplitz_cov(p, rho)
    chol = jnp.linalg.cholesky(cov)
    z = jax.random.normal(key, (n, p))
    return z @ chol.T


def logistic_data(key: jax.Array, n: int, p: int,
                  rho: float = 0.6) -> Tuple[jnp.ndarray, jnp.ndarray]:
    kx, ky = jax.random.split(key)
    X = sample_x(kx, n, p, rho)
    theta = target_theta(p)
    prob = jax.nn.sigmoid(X @ theta)
    y = jax.random.bernoulli(ky, prob).astype(jnp.float32)
    return X, y


def poisson_data(key: jax.Array, n: int, p: int,
                 rho: float = 0.6) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Truncated design: resample rows until |x.theta*| <= 1 (paper Exp 2).
    Implemented by oversampling 3x and taking the first n valid rows (>90%
    of draws are valid per the paper, so 3x is far more than enough)."""
    kx, ky = jax.random.split(key)
    theta = target_theta(p)
    X_big = sample_x(kx, 3 * n, p, rho)
    valid = jnp.abs(X_big @ theta) <= 1.0
    order = jnp.argsort(~valid)          # valid rows first, stable
    X = X_big[order][:n]
    lam = jnp.exp(X @ theta)
    y = jax.random.poisson(ky, lam).astype(jnp.float32)
    return X, y


def linear_data(key: jax.Array, n: int, p: int, rho: float = 0.6,
                noise: float = 1.0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    kx, ke = jax.random.split(key)
    X = sample_x(kx, n, p, rho)
    y = X @ target_theta(p) + noise * jax.random.normal(ke, (n,))
    return X, y


_GENERATORS = {"logistic": logistic_data, "poisson": poisson_data,
               "linear": linear_data}


def make_shards(key: jax.Array, model: str, m: int, n: int, p: int,
                rho: float = 0.6) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(m+1, n, p) X and (m+1, n) y; machine 0 is the central processor."""
    gen = _GENERATORS[model]
    keys = jax.random.split(key, m + 1)
    X, y = jax.vmap(lambda k: gen(k, n, p, rho))(keys)
    return X, y


# ------------------------------------------------------------- LM pipeline

def token_batches(seed: int, vocab: int, batch: int, seq: int,
                  n_batches: int):
    """Deterministic synthetic token stream with a learnable structure:
    next token = (3*tok + 7) % vocab with 10% uniform noise, so a model can
    visibly reduce loss within a few hundred steps."""
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        start = rng.integers(0, vocab, size=(batch, 1))
        toks = [start]
        for _ in range(seq):
            nxt = (3 * toks[-1] + 7) % vocab
            noise = rng.integers(0, vocab, size=nxt.shape)
            mask = rng.random(nxt.shape) < 0.1
            toks.append(np.where(mask, noise, nxt))
        arr = np.concatenate(toks, axis=1)
        yield jnp.asarray(arr[:, :seq]), jnp.asarray(arr[:, 1:seq + 1])


def digits_like_dataset(seed: int, n: int, n_features: int = 50,
                        pair: Tuple[int, int] = (8, 9)):
    """Deterministic stand-in for the MNIST pairs experiment (§5.2): two
    Gaussian classes whose means differ on a sparse subset of features, with
    heavier overlap for 'hard' pairs — no network access in this container,
    so the real MNIST cannot be fetched (DESIGN.md §2)."""
    rng = np.random.default_rng(seed + 100 * pair[0] + pair[1])
    hard = {(8, 9): 1.6, (6, 8): 1.2, (6, 9): 1.0}.get(tuple(sorted(pair)), 1.2)
    mean_gap = 1.0 / hard
    k_informative = 8
    mu = np.zeros(n_features)
    informative = rng.choice(n_features, size=k_informative, replace=False)
    mu[informative] = mean_gap * rng.choice([-1.0, 1.0], size=k_informative)
    y = rng.integers(0, 2, size=n)
    X = rng.normal(size=(n, n_features)) + np.outer(2 * y - 1, mu)
    return jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32), informative
