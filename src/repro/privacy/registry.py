"""Privacy-accountant registry: one entry per composition/calibration rule.

The paper's noise calibration (Thms 4.4/4.5) splits the total (eps, delta)
evenly over the protocol's transmissions — basic composition, Remark 4.5.
That split is the only knob every sigma in the codebase hangs off, so a
sharper accountant is worth real noise reduction at fixed total budget.
This registry is the single place accounting rules live, mirroring
``repro.agg``/``repro.attacks``: an :class:`Accountant` bundles the three
directions an accounting rule is used in —

  * ``per_round``   — invert the composition: the per-transmission
    (eps_r, delta_r) this rule certifies for a k-fold run at total
    (eps, delta). This is what the spend ledger records.
  * ``multiplier``  — calibrate the noise: the per-round noise multiplier
    (the paper's Delta factor) the rule buys at that budget. Sigma scaling
    everywhere routes through the RATIO of this to the basic entry
    (:func:`multiplier_ratio`), so ``basic`` stays byte-identical by
    construction — the ratio is the exact float ``1.0`` and the basic
    sigma tuple is never touched.
  * ``compose``     — the audit direction: total (eps, delta) certified
    for k rounds at a given per-round budget (monotonicity tests compare
    accountants this way).

Registering a new accountant makes it immediately sweepable
(``Scenario.accountant`` validates against this registry), servable
(``ServeConfig.accountant``) and launchable (``--accountant``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Accountant:
    """One privacy-composition rule.

    ``per_round(eps, delta, k)`` -> (eps_r, delta_r);
    ``multiplier(eps, delta, k)`` -> per-round noise multiplier (float);
    ``compose(eps_r, delta_r, k)`` -> (eps_total, delta_total).
    All three take Python floats — the non-basic entries invert their
    composition by bisection, which cannot run on traced values; the
    sweep executor calibrates host-side per scenario, exactly where the
    basic sigmas are already computed.
    """
    name: str
    per_round: Callable[[float, float, int], Tuple[float, float]]
    multiplier: Callable[[float, float, int], float]
    compose: Callable[[float, float, int], Tuple[float, float]]
    #: True when per-round sigma is identical to basic by construction:
    #: :func:`multiplier_ratio` returns the exact float 1.0 without any
    #: arithmetic, so calibration skips scaling and stays byte-identical.
    exact_basic: bool = False
    #: True for high-probability mechanisms: mechanism-level DP holds only
    #: on the tail-bound sensitivity event, whose failure probability must
    #: be recorded in the ledger.
    high_prob: bool = False
    #: ``failure_prob(p, n, gamma)`` -> per-transmission sensitivity
    #: failure probability (Lemma 4.4), or None when the rule makes no
    #: high-probability claim of its own.
    failure_prob: Optional[Callable[[int, int, float], float]] = None
    doc: str = ""


_REGISTRY: Dict[str, Accountant] = {}


def register(acct: Accountant) -> Accountant:
    """Register (or replace) an accountant under ``acct.name``."""
    _REGISTRY[acct.name] = acct
    return acct


def get_accountant(name: str) -> Accountant:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown accountant {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def registered() -> Tuple[str, ...]:
    """Registered accountant names, sorted."""
    return tuple(sorted(_REGISTRY))


def resolve(name: Optional[str]) -> str:
    """Validate ``name`` against the registry (None -> the default
    ``"basic"``), returning the canonical name."""
    if name is None:
        return "basic"
    return get_accountant(name).name


def multiplier_ratio(name: str, eps, delta, k: int) -> float:
    """Per-round noise-multiplier ratio of accountant ``name`` vs basic
    composition at total budget (eps, delta) over ``k`` transmissions.

    Every sigma path scales the BASIC calibration by this ratio, so the
    byte-parity contract is structural: ``exact_basic`` accountants return
    the literal ``1.0`` (no float math, traced eps/delta fine) and callers
    skip the multiply entirely. Non-basic accountants bisect host-side and
    therefore require Python-float budgets.
    """
    acct = get_accountant(name)
    if acct.exact_basic:
        return 1.0
    if not (isinstance(eps, (int, float)) and isinstance(delta, (int, float))):
        raise TypeError(
            f"accountant {acct.name!r} calibrates by host-side bisection; "
            "eps/delta must be Python floats here, not traced values — "
            "compute sigma_base per scenario host-side (the sweep executor "
            "already does) and batch the scaled sigmas along the vmap axis")
    basic = get_accountant("basic")
    return acct.multiplier(eps, delta, k) / basic.multiplier(eps, delta, k)
