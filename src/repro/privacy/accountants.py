"""The four registered accountants: basic, advanced, rdp, subexp.

All composition/inversion math lives in ``repro.core.dp`` (it is DP
theory, unit-tested there); this module only binds it into registry
entries. Numbers at the paper's §5 operating point — total budget
(eps=5, delta=1e-5) over the six untrusted-center transmissions:

  ============  =================  ==========================
  accountant    per-round sigma    note
  ============  =================  ==========================
  basic         1.00x (reference)  eps/k split, Remark 4.5
  advanced      1.00x at k=6       Cor 4.1's sqrt-k regime needs
                (< 1 for k >~ 25)  k >~ 2 ln(1/delta); best-of
                                   with basic, never worse
  rdp           ~0.38x             Gaussian Renyi curves, tight
                                   conversion — the real win
  subexp        1.00x              basic sigmas + the paper's
                                   high-prob failure ledger
  ============  =================  ==========================

(rdp's measured ratio at that point is 0.377 — a 2.65x noise reduction;
advanced reaches 0.62x at k=60 and 0.34x at k=200.)

``basic`` and ``subexp`` are ``exact_basic``: their multiplier ratio is
the literal float 1.0 and the calibrated sigma tuple is byte-identical
to the pre-registry code path (tests/test_protocol_pytree.py golden).
"""
from __future__ import annotations

from repro.core import dp
from repro.privacy.registry import Accountant, register


def _basic_per_round(eps: float, delta: float, k: int):
    return eps / k, delta / k


def _basic_multiplier(eps: float, delta: float, k: int) -> float:
    return dp.noise_multiplier(eps / k, delta / k)


def _basic_compose(eps_r: float, delta_r: float, k: int):
    return k * eps_r, k * delta_r


BASIC = register(Accountant(
    name="basic",
    per_round=_basic_per_round,
    multiplier=_basic_multiplier,
    compose=_basic_compose,
    exact_basic=True,
    doc="Dwork et al. sum composition: the historical eps/5 (eps/6 "
        "untrusted) split. The byte-identical default.",
))


def _advanced_per_round(eps: float, delta: float, k: int):
    return dp.invert_advanced(eps, delta, k)


def _advanced_multiplier(eps: float, delta: float, k: int) -> float:
    return dp.noise_multiplier(*dp.invert_advanced(eps, delta, k))


def _advanced_compose(eps_r: float, delta_r: float, k: int):
    # Audit direction: the better of basic and Cor 4.1 at slack = one
    # basic delta-budget (the standard "report at ~2x delta" convention).
    basic = (k * eps_r, k * delta_r)
    adv = dp.compose_advanced(eps_r, delta_r, k, slack=k * delta_r)
    return adv if adv[0] < basic[0] else basic


ADVANCED = register(Accountant(
    name="advanced",
    per_round=_advanced_per_round,
    multiplier=_advanced_multiplier,
    compose=_advanced_compose,
    doc="Kairouz-Oh-Viswanath Cor 4.1 INVERTED over a slack grid to "
        "calibrate per-round sigma, best-of with basic so it is never "
        "worse. Cor 4.1's sqrt(k) regime only beats the linear bound "
        "once k >~ 2 ln(1/delta) (~23 at delta=1e-5), so at the paper's "
        "k in {5, 6} it ties basic exactly and the gain appears at "
        "many-round training scale.",
))


def _rdp_per_round(eps: float, delta: float, k: int):
    # The standalone (eps_r, delta_r) one Gaussian release at the
    # calibrated multiplier satisfies (single-release tight conversion at
    # delta/k). Composing k of these under RDP certifies the total by
    # construction of the multiplier.
    mu = dp.calibrate_rdp_multiplier(eps, delta, k)
    delta_r = delta / k
    return dp.rdp_total_epsilon(mu, 1, delta_r), delta_r


def _rdp_multiplier(eps: float, delta: float, k: int) -> float:
    return dp.calibrate_rdp_multiplier(eps, delta, k)


def _rdp_compose(eps_r: float, delta_r: float, k: int):
    mu = dp.calibrate_rdp_multiplier(eps_r, delta_r, 1)
    return dp.rdp_total_epsilon(mu, k, k * delta_r), k * delta_r


RDP = register(Accountant(
    name="rdp",
    per_round=_rdp_per_round,
    multiplier=_rdp_multiplier,
    compose=_rdp_compose,
    doc="Gaussian-mechanism Renyi curves composed per order, converted "
        "with the tight RDP->(eps,delta) bound and optimized over the "
        "alpha grid. ~2.65x smaller per-round sigma than basic at the "
        "paper's (eps=5, delta=1e-5, k=6).",
))


def _subexp_failure_prob(p: int, n: int, gamma: float) -> float:
    return dp.mean_dp_failure_prob_subexp(p, n, gamma, 1.0, 1.0)


SUBEXP = register(Accountant(
    name="subexp",
    per_round=_basic_per_round,
    multiplier=_basic_multiplier,
    compose=_basic_compose,
    exact_basic=True,
    high_prob=True,
    failure_prob=_subexp_failure_prob,
    doc="The paper's sub-exponential high-probability mechanism (Lemma "
        "4.4): identical sigmas to basic, but the data-driven tail bound "
        "replaces any bounded-gradient clip, so mechanism-level DP holds "
        "only on the sensitivity event — EVERY transmission's failure "
        "probability is recorded in the ledger and union-bounded.",
))
