"""repro.privacy: the pluggable privacy-accountant registry.

>>> from repro import privacy
>>> privacy.registered()
('advanced', 'basic', 'rdp', 'subexp')
>>> privacy.multiplier_ratio("rdp", 5.0, 1e-5, 6)   # sigma vs basic
0.377...

See ``repro.privacy.registry`` for the Accountant contract and
``repro.privacy.accountants`` for the four entries.
"""
from repro.privacy.registry import (Accountant, get_accountant,
                                    multiplier_ratio, register, registered,
                                    resolve)
from repro.privacy import accountants as _accountants  # noqa: F401  (registers)

__all__ = ["Accountant", "get_accountant", "multiplier_ratio", "register",
           "registered", "resolve"]
