"""Partitioning rules: param/batch/cache PartitionSpecs for any mesh.

Scheme (Megatron-style, adapted per family):
  * "model" axis shards: fused attention head dims (w_q/w_k/w_v out,
    w_o in), MLP d_ff (w_gate/w_up out, w_down in), vocab (embed rows,
    lm_head cols), MoE expert axis (expert parallelism), Mamba d_inner.
  * "data" (x "pod") shards the batch / machine axis of activations,
    gradients and KV caches.
  * Norms, biases, router, small SSM scalars are replicated.

Every rule is divisibility-checked against the actual mesh: if a dim does
not divide, the rule falls back (next candidate dim or replication), so
every (arch x shape x mesh) combination lowers. Fallbacks that fire on the
production meshes are reported by ``explain_specs`` and recorded in
EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# key-name -> (dim candidates from the END of the shape, axis name)
# dim index is negative (so rules are stack-agnostic: a leading layer axis
# shifts positive indices but not negative ones).
_LAST = object()   # marker: shard last dim
_ROW = object()    # marker: shard dim -2 (input/row dim)

_RULES: Dict[str, int] = {
    # shard last dim on "model"
    "w_q": -1, "w_k": -1, "w_v": -1, "w_gate": -1, "w_up": -1,
    "w_in": -1, "w_x": -1, "w_if": -1, "lm_head": -1, "projector": -1,
    "w_router": -1,
    # shard row (input) dim on "model"
    "w_o": -2, "w_down": -2, "w_out": -2,
    # embedding: shard vocab rows
    "embed": -2,
}

_REPLICATED = {"norm1", "norm2", "norm", "norm_f", "conv_w", "conv_b",
               "a_log", "dt_bias", "d_skip", "b_if", "b", "r_h"}


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


def _fits(shape: Tuple[int, ...], dim: int, mesh: Mesh, axis) -> bool:
    try:
        return shape[dim] % _axis_size(mesh, axis) == 0
    except (IndexError, KeyError):
        return False


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               mesh: Mesh, cfg: Optional[ModelConfig] = None,
               fsdp: bool = False) -> P:
    """PartitionSpec for one parameter leaf given its dict path.

    ``fsdp=True`` additionally shards the largest remaining dim over the
    "data" axis (ZeRO-3 style weight sharding; GSPMD inserts the per-layer
    all-gathers). Only valid when the data axis is NOT being used as the
    robust-aggregation machine axis.
    """
    name = path[-1]
    ndim = len(shape)
    spec = [None] * ndim
    if name in _REPLICATED or ndim == 0:
        return P(*spec)
    # MoE expert tensors: (L?, E, d, f) — shard expert axis (dim -3)
    if "moe" in path and name in ("w_gate", "w_up", "w_down"):
        if _fits(shape, ndim - 3, mesh, "model"):
            spec[ndim - 3] = "model"
    elif name in _RULES:
        dim = _RULES[name] % ndim
        # audio stacked embed (nc, V, d): vocab is dim -2 still. OK.
        if _fits(shape, dim, mesh, "model"):
            spec[dim] = "model"
    if fsdp and "data" in mesh.shape:
        # largest unsharded dim divisible by the data axis
        for dim in sorted(range(ndim), key=lambda i: -shape[i]):
            if spec[dim] is None and _fits(shape, dim, mesh, "data"):
                spec[dim] = "data"
                break
    return P(*spec)


def param_shardings(params: Any, mesh: Mesh,
                    cfg: Optional[ModelConfig] = None,
                    fsdp: bool = False) -> Any:
    """Tree of NamedShardings matching ``params`` (works on shapes or
    ShapeDtypeStructs too)."""
    def leaf_spec(kp, leaf):
        path = tuple(getattr(k, "key", getattr(k, "idx", None))
                     for k in kp)
        path = tuple(str(x) for x in path)
        return NamedSharding(mesh, param_spec(path, tuple(leaf.shape), mesh,
                                              cfg, fsdp=fsdp))
    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def batch_axes(mesh: Mesh):
    """The (possibly compound) batch axis: ('pod','data') when a pod axis
    exists, else 'data'."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else "data"


def data_spec(shape: Tuple[int, ...], mesh: Mesh,
              batch_dim: int = 0) -> P:
    """Shard the batch dim over pod x data when divisible (else replicate)."""
    ax = batch_axes(mesh)
    spec = [None] * len(shape)
    if _fits(shape, batch_dim, mesh, ax):
        spec[batch_dim] = ax
    elif not isinstance(ax, str) and _fits(shape, batch_dim, mesh, "data"):
        spec[batch_dim] = "data"
    return P(*spec)


def batch_shardings(batch: Any, mesh: Mesh, batch_dim: int = 0) -> Any:
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(
            mesh, data_spec(tuple(leaf.shape), mesh, batch_dim)), batch)


def cache_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               mesh: Mesh, kv_mode: str = "auto") -> P:
    """KV/state caches: (L, B, ...) — batch on data, heads (or head_dim)
    on model when divisible.

    ``kv_mode`` (perf-iteration knob, EXPERIMENTS.md §Perf):
      auto — heads if divisible else head_dim (baseline)
      seq  — shard the cache SEQUENCE axis over model: attention scores
             are computed on local cache slices and only the (B,H,S)
             score row / softmax stats cross the mesh, instead of
             all-gathering the cache itself.
      replicate — no model-axis sharding (ablation)
    """
    ndim = len(shape)
    if ndim == 0:
        return P()
    name = path[-1]
    spec = [None] * ndim
    ax = batch_axes(mesh)
    # find the batch dim: stacked caches are (L, B, ...); xlstm caches are
    # per-layer lists with batch leading; pos is scalar
    if any("xlstm" in str(s) for s in path):
        if _fits(shape, 0, mesh, ax):
            return P(*((ax,) + (None,) * (ndim - 1)))
        return P(*spec)
    bdim = 1 if ndim >= 2 else 0
    if _fits(shape, bdim, mesh, ax):
        spec[bdim] = ax
    elif not isinstance(ax, str) and _fits(shape, bdim, mesh, "data"):
        spec[bdim] = "data"
    if name in ("k", "v") and ndim >= 4:
        # (L, B, S, Hkv, dh)
        if kv_mode == "seq":
            if _fits(shape, ndim - 3, mesh, "model"):
                spec[ndim - 3] = "model"
        elif kv_mode == "auto":
            # prefer head sharding, fall back to head_dim
            if _fits(shape, ndim - 2, mesh, "model"):
                spec[ndim - 2] = "model"
            elif _fits(shape, ndim - 1, mesh, "model"):
                spec[ndim - 1] = "model"
    elif name in ("state", "conv", "C", "n") and ndim >= 3:
        # ssm state (L,B,H,N,dh) / conv (L,B,t,C) / mlstm C: shard dim 2
        if _fits(shape, 2, mesh, "model"):
            spec[2] = "model"
    return P(*spec)


def cache_shardings(cache: Any, mesh: Mesh, kv_mode: str = "auto") -> Any:
    def leaf_spec(kp, leaf):
        path = tuple(str(getattr(k, "key", getattr(k, "idx", ""))) for k in kp)
        return NamedSharding(mesh, cache_spec(path, tuple(leaf.shape), mesh,
                                              kv_mode=kv_mode))
    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def explain_specs(params: Any, mesh: Mesh) -> Dict[str, str]:
    """Human-readable map path -> spec (for DESIGN/EXPERIMENTS tables)."""
    out = {}

    def walk(kp, leaf):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", "")))
                        for k in kp)
        out[path] = str(param_spec(
            tuple(str(getattr(k, "key", getattr(k, "idx", ""))) for k in kp),
            tuple(leaf.shape), mesh))
        return leaf
    jax.tree_util.tree_map_with_path(walk, params)
    return out
