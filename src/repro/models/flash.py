"""Chunked (flash-style) attention in pure JAX.

Online-softmax attention with a double ``lax.scan`` over query and KV
chunks so the (S x S) score matrix is never materialised — required for the
32k-prefill and 4k-train shapes to lower with bounded live memory on every
mesh. Supports GQA (kv heads broadcast over query-head groups), causal
masking and sliding windows. A Pallas TPU kernel for the decode hot-spot
lives in kernels/gqa_decode.py; this module is the jnp reference the model
uses on CPU and the oracle the kernel is tested against.

Shapes: q (B, S, Hq, Dh); k, v (B, T, Hkv, Dh). Output (B, S, Hq, Dh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk(x, size, axis):
    n = x.shape[axis]
    nchunks = n // size
    shape = x.shape[:axis] + (nchunks, size) + x.shape[axis + 1:]
    return x.reshape(shape)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, q_chunk: int = 512,
                    kv_chunk: int = 512, scale: float | None = None
                    ) -> jnp.ndarray:
    """Online-softmax attention, O(q_chunk * kv_chunk) live scores.

    Args:
      q: (B, S, Hq, Dh); k/v: (B, T, Hkv, Dh) with Hq % Hkv == 0.
      causal: apply causal mask (query position = q_offset + index).
      window: if > 0, sliding-window attention — query i attends to
        keys in (i - window, i].
      q_offset: absolute position of q[0] relative to k[0] (prefill: 0;
        decode-with-cache: cache length).
      q_chunk/kv_chunk: scan tile sizes (auto-clamped to S/T).
    """
    from repro.models import modes
    B, S, Hq, Dh = q.shape
    _, T, Hkv, _ = k.shape
    groups = Hq // Hkv
    if scale is None:
        scale = 1.0 / (Dh ** 0.5)
    q_chunk = min(modes.chunk_override(q_chunk, S), S)
    kv_chunk = min(modes.chunk_override(kv_chunk, T), T)
    # pad to multiples (masked out below)
    s_pad = (-S) % q_chunk
    t_pad = (-T) % kv_chunk
    if s_pad:
        q = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    if t_pad:
        k = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    Sp, Tp = q.shape[1], k.shape[1]
    nq, nk = Sp // q_chunk, Tp // kv_chunk

    # (nq, B, q_chunk, Hkv, groups, Dh)
    qc = jnp.moveaxis(_chunk(q, q_chunk, 1), 1, 0)
    qc = qc.reshape(nq, B, q_chunk, Hkv, groups, Dh)
    kc = jnp.moveaxis(_chunk(k, kv_chunk, 1), 1, 0)   # (nk, B, c, Hkv, Dh)
    vc = jnp.moveaxis(_chunk(v, kv_chunk, 1), 1, 0)

    q_pos = q_offset + jnp.arange(Sp)
    k_pos = jnp.arange(Tp)
    kv_valid = k_pos < T

    def q_step(_, qi):
        q_i, qpos_i = qi          # (B, qc, Hkv, g, Dh), (qc,)

        def kv_step(carry, ki):
            acc, m, lse = carry
            k_j, v_j, kpos_j, valid_j = ki
            # scores: (B, qc, Hkv, g, kc)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            mask = valid_j[None, :]
            if causal:
                mask = mask & (kpos_j[None, :] <= qpos_i[:, None])
            if window > 0:
                mask = mask & (kpos_j[None, :] > qpos_i[:, None] - window)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            lse_new = lse * corr + p.sum(axis=-1)
            pv = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v_j.dtype), v_j,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, lse_new), None

        acc0 = jnp.zeros((B, q_chunk, Hkv, groups, Dh), jnp.float32)
        m0 = jnp.full((B, q_chunk, Hkv, groups), NEG_INF, jnp.float32)
        lse0 = jnp.zeros((B, q_chunk, Hkv, groups), jnp.float32)
        (acc, m, lse), _ = jax.lax.scan(
            kv_step, (acc0, m0, lse0),
            (kc, vc, _chunk(k_pos, kv_chunk, 0), _chunk(kv_valid, kv_chunk, 0)))
        out = acc / jnp.maximum(lse[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, out = jax.lax.scan(q_step, None,
                          (qc, _chunk(q_pos, q_chunk, 0)))
    # (nq, B, qc, Hkv, g, Dh) -> (B, S, Hq, Dh)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sp, Hq, Dh)
    return out[:, :S]


def attention_reference(q, k, v, *, causal=True, window=0, q_offset=0,
                        scale=None):
    """Naive O(S*T) attention — oracle for tests (small shapes only)."""
    B, S, Hq, Dh = q.shape
    _, T, Hkv, _ = k.shape
    groups = Hq // Hkv
    if scale is None:
        scale = 1.0 / (Dh ** 0.5)
    kk = jnp.repeat(k, groups, axis=2)
    vv = jnp.repeat(v, groups, axis=2)
    s = jnp.einsum("bshd,bthd->bhst", q, kk).astype(jnp.float32) * scale
    q_pos = q_offset + jnp.arange(S)
    k_pos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window > 0:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p.astype(vv.dtype), vv)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cache_len: jnp.ndarray,
                     *, window: int = 0, scale: float | None = None
                     ) -> jnp.ndarray:
    """One-token decode: q (B, 1, Hq, Dh) vs cache (B, Smax, Hkv, Dh).

    ``cache_len`` is the number of valid entries. For ring-buffer
    (sliding-window) caches all Smax slots are valid once wrapped; the
    caller passes cache_len = min(pos+1, Smax) and positions are implicit
    (softmax is permutation-invariant so ring order is irrelevant).
    """
    B, _, Hq, Dh = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    groups = Hq // Hkv
    if scale is None:
        scale = 1.0 / (Dh ** 0.5)
    qg = q.reshape(B, Hkv, groups, Dh)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(Smax)[None] < cache_len[:, None]      # (B, Smax)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, Dh).astype(q.dtype)
