"""Top-level model: embedding/frontends -> block stack -> LM head.

One ``Model`` class covers all six architecture families via the config:

  dense / vlm / audio : scanned dense blocks (vlm prepends patch embeds,
                        audio sums codebook embeddings)
  moe                 : scanned moe blocks (aux loss accumulated in scan)
  hybrid (zamba2)     : scanned mamba blocks + ONE shared-weight attention
                        block applied after every ``attn_every`` layers
  ssm (xlstm)         : Python loop over heterogeneous mLSTM/sLSTM blocks

API:
  init(key) -> params
  forward(params, batch) -> logits            (train / prefill path)
  loss(params, batch) -> (scalar, aux dict)
  init_cache(batch_size, max_len) -> cache
  decode_step(params, cache, tokens) -> (logits, cache)   serve path
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, blocks, modes, ssm, xlstm
from repro.models.layers import (cross_entropy, embed_init, rms_norm,
                                 stack_layer_params, _init)

Params = Dict[str, jnp.ndarray]

VISION_DIM = 1024     # stub vision-tower output dim (projector maps to d)


def _np_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


class Model:
    def __init__(self, cfg: ModelConfig, remat: bool = False):
        self.cfg = cfg
        self.remat = remat            # rematerialise each block in backward
        self.dtype = _np_dtype(cfg)
        if cfg.family == "hybrid" and cfg.attn_every > 0:
            self.n_shared = cfg.n_layers // cfg.attn_every
        else:
            self.n_shared = 0

    # ------------------------------------------------------------- init
    def init(self, key: jax.Array) -> Params:
        cfg, dt = self.cfg, self.dtype
        keys = jax.random.split(key, cfg.n_layers + 8)
        p: Params = {"norm_f": jnp.ones((cfg.d_model,), dt)}

        if cfg.family == "audio":
            p["embed"] = jnp.stack([
                embed_init(keys[-i - 1], cfg.vocab, cfg.d_model, dt)
                for i in range(cfg.n_codebooks)])        # (nc, V, d)
        else:
            p["embed"] = embed_init(keys[-1], cfg.vocab, cfg.d_model, dt)
        if not cfg.tie_embeddings:
            p["lm_head"] = _init(keys[-2], (cfg.d_model, cfg.vocab),
                                 scale=0.02, dtype=dt)
        if cfg.family == "vlm":
            p["projector"] = _init(keys[-3], (VISION_DIM, cfg.d_model),
                                   dtype=dt)

        lk = keys[:cfg.n_layers]
        if cfg.family in ("dense", "vlm", "audio"):
            p["layers"] = stack_layer_params(
                lk, lambda k: blocks.dense_block_init(k, cfg, dt))
        elif cfg.family == "moe":
            p["layers"] = stack_layer_params(
                lk, lambda k: blocks.moe_block_init(k, cfg, dt))
        elif cfg.family == "hybrid":
            p["layers"] = stack_layer_params(
                lk, lambda k: blocks.mamba_block_init(k, cfg, dt))
            p["shared_attn"] = blocks.shared_attn_block_init(keys[-4], cfg, dt)
        elif cfg.family == "ssm":     # xlstm
            p["xlstm_layers"] = [
                blocks.xlstm_block_init(lk[i], cfg, i, dt)
                for i in range(cfg.n_layers)]
        else:
            raise ValueError(cfg.family)
        return p

    # ------------------------------------------------------------ embed
    def _embed(self, p: Params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        cfg = self.cfg
        tokens = batch["tokens"]
        if cfg.family == "audio":
            # tokens (B, S, n_codebooks): sum codebook embeddings
            h = sum(p["embed"][c][tokens[..., c]]
                    for c in range(cfg.n_codebooks))
        else:
            h = p["embed"][tokens]                        # (B, S, d)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            patches = jnp.einsum("bpv,vd->bpd",
                                 batch["patch_embeds"].astype(h.dtype),
                                 p["projector"])
            h = jnp.concatenate([patches, h], axis=1)
        return h

    # ---------------------------------------------------------- forward
    def forward(self, p: Params, batch: Dict[str, jnp.ndarray],
                window: Optional[int] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (logits (B, S, V), aux_loss scalar)."""
        cfg = self.cfg
        win = cfg.sliding_window if window is None else window
        h = self._embed(p, batch)
        aux = jnp.zeros((), jnp.float32)
        ckpt = jax.checkpoint if self.remat else (lambda f: f)

        if cfg.family in ("dense", "vlm", "audio"):
            @ckpt
            def body(carry, lp):
                return blocks.dense_block(lp, carry, cfg, window=win), None
            h, _ = jax.lax.scan(body, h, p["layers"],
                                unroll=modes.layer_unroll(cfg.n_layers))
        elif cfg.family == "moe":
            @ckpt
            def body(carry, lp):
                h, aux = carry
                h, a = blocks.moe_block(lp, h, cfg, window=win)
                return (h, aux + a), None
            (h, aux), _ = jax.lax.scan(
                body, (h, aux), p["layers"],
                unroll=modes.layer_unroll(cfg.n_layers))
        elif cfg.family == "hybrid":
            shared = p.get("shared_attn")
            every = cfg.attn_every

            @ckpt
            def body(carry, inp):
                i, lp = inp
                h = blocks.mamba_block(lp, carry, cfg)
                if every > 0:      # attn_every=0: pure-mamba ablation/probe
                    h = jax.lax.cond(
                        (i % every) == every - 1,
                        lambda hh: blocks.shared_attn_block(shared, hh, cfg,
                                                            window=win),
                        lambda hh: hh, h)
                return h, None
            idx = jnp.arange(cfg.n_layers)
            h, _ = jax.lax.scan(body, h, (idx, p["layers"]),
                                unroll=modes.layer_unroll(cfg.n_layers))
        elif cfg.family == "ssm":
            for i, lp in enumerate(p["xlstm_layers"]):
                def one(hh, lp=lp, i=i):
                    x = rms_norm(hh, lp["norm"], cfg.norm_eps)
                    if i in cfg.slstm_at:
                        return hh + xlstm.slstm_forward(lp["mixer"], x, cfg)
                    return hh + xlstm.mlstm_forward(lp["mixer"], x, cfg)
                h = ckpt(one)(h)
        else:
            raise ValueError(cfg.family)

        h = rms_norm(h, p["norm_f"], cfg.norm_eps)
        head = p["embed"].T if self.cfg.tie_embeddings else p["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", h, head)
        return logits, aux

    def loss(self, p: Params, batch: Dict[str, jnp.ndarray]
             ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits, aux = self.forward(p, batch)
        labels = batch["labels"]
        if self.cfg.family == "vlm" and "patch_embeds" in batch:
            # patches carry no next-token target: score only text positions
            n_patch = batch["patch_embeds"].shape[1]
            logits = logits[:, n_patch:]
        ce = cross_entropy(logits, labels, batch.get("mask"))
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------ cache
    def init_cache(self, batch: int, max_len: int,
                   dtype=None) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        dt = dtype or self.dtype
        win = cfg.sliding_window
        attn_len = min(max_len, win) if win > 0 else max_len
        cache: Dict[str, jnp.ndarray] = {"pos": jnp.zeros((), jnp.int32)}
        L = cfg.n_layers

        def stack(make, n):
            one = make()
            return jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), one)

        if cfg.family in ("dense", "vlm", "audio", "moe"):
            cache["attn"] = stack(
                lambda: attention.attn_cache_init(cfg, batch, attn_len, dt), L)
        elif cfg.family == "hybrid":
            cache["ssm"] = stack(
                lambda: ssm.ssm_cache_init(cfg, batch, jnp.float32), L)
            if self.n_shared:
                cache["attn"] = stack(
                    lambda: attention.attn_cache_init(cfg, batch, attn_len,
                                                      dt), self.n_shared)
        elif cfg.family == "ssm":
            cache["xlstm"] = [
                (xlstm.slstm_cache_init(cfg, batch) if i in cfg.slstm_at
                 else xlstm.mlstm_cache_init(cfg, batch))
                for i in range(L)]
        return cache

    # ------------------------------------------------------- decode step
    def decode_step(self, p: Params, cache: Dict[str, jnp.ndarray],
                    batch: Dict[str, jnp.ndarray]
                    ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        """One-token step. batch["tokens"]: (B, 1) (audio: (B, 1, nc)).
        Returns (logits (B, 1, V), updated cache)."""
        cfg = self.cfg
        win = cfg.sliding_window
        pos = cache["pos"]
        h = self._embed(p, {k: v for k, v in batch.items()
                            if k != "patch_embeds"})
        new_cache = dict(cache)

        if cfg.family in ("dense", "vlm", "audio", "moe"):
            dec = (blocks.moe_block_decode if cfg.family == "moe"
                   else blocks.dense_block_decode)

            def body(carry, inp):
                lp, lc = inp
                h2, lc2 = dec(lp, carry, lc, pos, cfg, window=win)
                return h2, lc2
            h, new_cache["attn"] = jax.lax.scan(
                body, h, (p["layers"], cache["attn"]),
                unroll=modes.layer_unroll(cfg.n_layers))
        elif cfg.family == "hybrid":
            shared = p.get("shared_attn")
            every = cfg.attn_every
            has_attn = self.n_shared > 0

            def body(carry, inp):
                h, attn_cache = carry
                i, lp, lc = inp
                h, lc2 = blocks.mamba_block_decode(lp, h, lc, cfg)

                def with_attn(operand):
                    h, ac = operand
                    j = i // every
                    one = jax.tree_util.tree_map(lambda a: a[j], ac)
                    h2, one2 = blocks.shared_attn_block_decode(
                        shared, h, one, pos, cfg, window=win)
                    ac2 = jax.tree_util.tree_map(
                        lambda a, b: jax.lax.dynamic_update_index_in_dim(
                            a, b.astype(a.dtype), j, 0), ac, one2)
                    return h2, ac2

                if has_attn:
                    h, attn_cache = jax.lax.cond(
                        (i % every) == every - 1, with_attn,
                        lambda op: op, (h, attn_cache))
                return (h, attn_cache), lc2
            idx = jnp.arange(cfg.n_layers)
            attn0 = cache["attn"] if has_attn else jnp.zeros(())
            (h, attn1), new_cache["ssm"] = jax.lax.scan(
                body, (h, attn0), (idx, p["layers"], cache["ssm"]),
                unroll=modes.layer_unroll(cfg.n_layers))
            if has_attn:
                new_cache["attn"] = attn1
        elif cfg.family == "ssm":
            caches = []
            for i, (lp, lc) in enumerate(zip(p["xlstm_layers"],
                                             cache["xlstm"])):
                x = rms_norm(h, lp["norm"], cfg.norm_eps)
                if i in cfg.slstm_at:
                    y, lc2 = xlstm.slstm_decode(lp["mixer"], x, lc, cfg)
                else:
                    y, lc2 = xlstm.mlstm_decode(lp["mixer"], x, lc, cfg)
                h = h + y
                caches.append(lc2)
            new_cache["xlstm"] = caches
        else:
            raise ValueError(cfg.family)

        h = rms_norm(h, p["norm_f"], cfg.norm_eps)
        head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", h, head)
        new_cache["pos"] = pos + 1
        return logits, new_cache
