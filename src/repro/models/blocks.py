"""Block wiring for every architecture family.

Homogeneous stacks (dense / moe / ssm-mamba / hybrid backbone) carry a
leading layer axis and are scanned (small HLO — essential for the 80-config
dry-run). xLSTM's heterogeneous 12-layer stack is a Python loop.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, moe, ssm, xlstm
from repro.models.layers import mlp_init, rms_norm, swiglu

Params = Dict[str, jnp.ndarray]


# ------------------------------------------------------------ init helpers

def dense_block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "attn": attention.attn_init(k1, cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def moe_block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "attn": attention.attn_init(k1, cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "moe": moe.moe_init(k2, cfg, dtype),
    }


def mamba_block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    return {
        "norm": jnp.ones((cfg.d_model,), dtype),
        "ssm": ssm.ssm_init(key, cfg, dtype),
    }


def shared_attn_block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    """zamba2's shared-weight attention+MLP block (one weight set)."""
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "attn": attention.attn_init(k1, cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def xlstm_block_init(key, cfg: ModelConfig, layer: int,
                     dtype=jnp.float32) -> Params:
    kind = "slstm" if layer in cfg.slstm_at else "mlstm"
    init = xlstm.slstm_init if kind == "slstm" else xlstm.mlstm_init
    return {"norm": jnp.ones((cfg.d_model,), dtype),
            "mixer": init(key, cfg, dtype)}


# ------------------------------------------------------------ forward

def dense_block(p: Params, h: jnp.ndarray, cfg: ModelConfig,
                window: int = 0) -> jnp.ndarray:
    h = h + attention.attn_forward(p["attn"],
                                   rms_norm(h, p["norm1"], cfg.norm_eps),
                                   cfg, window=window)
    x = rms_norm(h, p["norm2"], cfg.norm_eps)
    return h + swiglu(x, **p["mlp"])


def moe_block(p: Params, h: jnp.ndarray, cfg: ModelConfig,
              window: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = h + attention.attn_forward(p["attn"],
                                   rms_norm(h, p["norm1"], cfg.norm_eps),
                                   cfg, window=window)
    y, stats = moe.moe_ffn(p["moe"], rms_norm(h, p["norm2"], cfg.norm_eps),
                           cfg)
    return h + y, stats["aux_loss"]


def mamba_block(p: Params, h: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return h + ssm.ssm_forward(p["ssm"], rms_norm(h, p["norm"], cfg.norm_eps),
                               cfg)


def shared_attn_block(p: Params, h: jnp.ndarray, cfg: ModelConfig,
                      window: int = 0) -> jnp.ndarray:
    h = h + attention.attn_forward(p["attn"],
                                   rms_norm(h, p["norm1"], cfg.norm_eps),
                                   cfg, window=window)
    x = rms_norm(h, p["norm2"], cfg.norm_eps)
    return h + swiglu(x, **p["mlp"])


# ------------------------------------------------------------ decode

def dense_block_decode(p: Params, h: jnp.ndarray, cache: Params,
                       pos: jnp.ndarray, cfg: ModelConfig,
                       window: int = 0) -> Tuple[jnp.ndarray, Params]:
    a, cache = attention.attn_decode(p["attn"],
                                     rms_norm(h, p["norm1"], cfg.norm_eps),
                                     cache, pos, cfg, window=window)
    h = h + a
    x = rms_norm(h, p["norm2"], cfg.norm_eps)
    return h + swiglu(x, **p["mlp"]), cache


def moe_block_decode(p: Params, h: jnp.ndarray, cache: Params,
                     pos: jnp.ndarray, cfg: ModelConfig,
                     window: int = 0) -> Tuple[jnp.ndarray, Params]:
    a, cache = attention.attn_decode(p["attn"],
                                     rms_norm(h, p["norm1"], cfg.norm_eps),
                                     cache, pos, cfg, window=window)
    h = h + a
    y, _ = moe.moe_ffn(p["moe"], rms_norm(h, p["norm2"], cfg.norm_eps), cfg)
    return h + y, cache


def mamba_block_decode(p: Params, h: jnp.ndarray, cache: Params,
                       cfg: ModelConfig) -> Tuple[jnp.ndarray, Params]:
    y, cache = ssm.ssm_decode(p["ssm"], rms_norm(h, p["norm"], cfg.norm_eps),
                              cache, cfg)
    return h + y, cache


def shared_attn_block_decode(p: Params, h: jnp.ndarray, cache: Params,
                             pos: jnp.ndarray, cfg: ModelConfig,
                             window: int = 0) -> Tuple[jnp.ndarray, Params]:
    a, cache = attention.attn_decode(p["attn"],
                                     rms_norm(h, p["norm1"], cfg.norm_eps),
                                     cache, pos, cfg, window=window)
    h = h + a
    x = rms_norm(h, p["norm2"], cfg.norm_eps)
    return h + swiglu(x, **p["mlp"]), cache
