"""xLSTM blocks: mLSTM (matrix memory, parallel/chunked) + sLSTM (scalar
memory, recurrent scan). [arXiv:2405.04517]

mLSTM uses the stabilised parallel form. Because the decay is separable —
D~[i,j] = F_i + (itilde_j - F_j) with F the cumulative log-forget — the
whole thing streams like flash attention: we scan KV chunks with a running
max and rescale, so no (S x S) matrix is live (needed for 4k train /
32k prefill). Decode is the O(1) matrix-memory recurrence with the
(C, n, m) stabiliser state.

sLSTM keeps per-head scalar memories with recurrent mixing; train runs a
lax.scan over time (inherently sequential, as in the paper).

Simplifications recorded in DESIGN.md §7: mLSTM block uses a pre
up-projection (factor 2) with a SiLU gate branch; sLSTM block is
norm -> mixer -> down-projection without a separate FFN (d_ff = 0).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _init

Params = Dict[str, jnp.ndarray]

NEG_INF = -1e30


# ================================================================== mLSTM

def mlstm_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    d_inner = 2 * d
    ks = jax.random.split(key, 7)
    return {
        "w_up": _init(ks[0], (d, d_inner), dtype=dtype),      # main branch
        "w_gate": _init(ks[1], (d, d_inner), dtype=dtype),    # SiLU gate
        "w_q": _init(ks[2], (d_inner, d_inner), dtype=dtype),
        "w_k": _init(ks[3], (d_inner, d_inner), dtype=dtype),
        "w_v": _init(ks[4], (d_inner, d_inner), dtype=dtype),
        "w_if": _init(ks[5], (d_inner, 2 * H), scale=0.02, dtype=jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,)),
                                 jnp.linspace(3.0, 6.0, H)]).astype(jnp.float32),
        "w_down": _init(ks[6], (d_inner, d), dtype=dtype),
    }


def _mlstm_qkvif(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    H = cfg.n_heads
    B, S, _ = x.shape
    d_inner = p["w_up"].shape[1]
    dh = d_inner // H
    u = jnp.einsum("bsd,de->bse", x, p["w_up"])
    gate = jax.nn.silu(jnp.einsum("bsd,de->bse", x, p["w_gate"]))
    q = jnp.einsum("bse,ef->bsf", u, p["w_q"]).reshape(B, S, H, dh)
    k = jnp.einsum("bse,ef->bsf", u, p["w_k"]).reshape(B, S, H, dh)
    v = jnp.einsum("bse,ef->bsf", u, p["w_v"]).reshape(B, S, H, dh)
    gates = (jnp.einsum("bse,eg->bsg", u.astype(jnp.float32), p["w_if"])
             + p["b_if"])
    itilde, ftilde = gates[..., :H], gates[..., H:]           # (B,S,H)
    return q, k, v, itilde, ftilde, gate


def mlstm_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                  chunk: int = 512) -> jnp.ndarray:
    """Chunked-parallel stabilised mLSTM. x: (B, S, d_model)."""
    from repro.models import modes
    B, S, _ = x.shape
    H = cfg.n_heads
    d_inner = p["w_up"].shape[1]
    dh = d_inner // H
    q, k, v, itilde, ftilde, gate = _mlstm_qkvif(p, x, cfg)
    logf = jax.nn.log_sigmoid(ftilde)                         # (B,S,H)
    F = jnp.cumsum(logf, axis=1)                              # cumulative
    a = F                                                     # query weight
    b = itilde - F                                            # key weight

    Q = min(modes.chunk_override(chunk, S), S)
    pad = (-S) % Q
    if pad:
        def padf(t):
            return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        q, k, v = padf(q), padf(k), padf(v)
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=0.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)), constant_values=NEG_INF)
    Sp = q.shape[1]
    nc = Sp // Q

    def c(t):
        return jnp.moveaxis(t.reshape((B, nc, Q) + t.shape[2:]), 1, 0)

    qc, kc, vc, ac, bc = c(q), c(k), c(v), c(a), c(b)
    pos = jnp.moveaxis(jnp.arange(Sp).reshape(nc, Q), 0, 0)

    scale = 1.0 / (dh ** 0.5)

    def q_step(_, qi):
        q_i, a_i, pos_i = qi                                  # (B,Q,H,dh) ...

        def kv_step(carry, ki):
            num, den, m = carry
            k_j, v_j, b_j, pos_j = ki
            # decay matrix exponent: (B,Q,Q,H)
            dmat = a_i[:, :, None, :] + b_j[:, None, :, :]
            causal = pos_j[None, :] <= pos_i[:, None]         # (Q,Q)
            dmat = jnp.where(causal[None, :, :, None], dmat, NEG_INF)
            m_new = jnp.maximum(m, dmat.max(axis=2))          # (B,Q,H)
            w = jnp.exp(dmat - m_new[:, :, None, :])
            qk = jnp.einsum("bqhd,bkhd->bqkh", q_i, k_j).astype(jnp.float32) \
                * scale
            s = qk * w
            corr = jnp.exp(m - m_new)
            num_new = num * corr[..., None] + jnp.einsum(
                "bqkh,bkhd->bqhd", s, v_j.astype(jnp.float32))
            den_new = den * corr + s.sum(axis=2)
            return (num_new, den_new, m_new), None

        num0 = jnp.zeros((B, Q, H, dh), jnp.float32)
        den0 = jnp.zeros((B, Q, H), jnp.float32)
        m0 = jnp.full((B, Q, H), NEG_INF, jnp.float32)
        (num, den, m), _ = jax.lax.scan(kv_step, (num0, den0, m0),
                                        (kc, vc, bc, pos))
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
        return None, h

    _, h = jax.lax.scan(q_step, None, (qc, ac, pos))
    h = jnp.moveaxis(h, 0, 1).reshape(B, Sp, d_inner)[:, :S]
    out = h.astype(x.dtype) * gate
    return jnp.einsum("bse,ed->bsd", out, p["w_down"])


def mlstm_cache_init(cfg: ModelConfig, batch: int,
                     dtype=jnp.float32) -> Params:
    H = cfg.n_heads
    dh = 2 * cfg.d_model // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), dtype),
        "n": jnp.zeros((batch, H, dh), dtype),
        "m": jnp.full((batch, H), NEG_INF, dtype),
        "f_acc": jnp.zeros((batch, H), dtype),   # running F (cum log forget)
    }


def mlstm_decode(p: Params, x: jnp.ndarray, cache: Params,
                 cfg: ModelConfig) -> Tuple[jnp.ndarray, Params]:
    """One-token recurrent mLSTM. x: (B, 1, d_model)."""
    B = x.shape[0]
    H = cfg.n_heads
    d_inner = p["w_up"].shape[1]
    dh = d_inner // H
    q, k, v, itilde, ftilde, gate = _mlstm_qkvif(p, x, cfg)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                       # (B,H,dh)
    itilde, ftilde = itilde[:, 0], ftilde[:, 0]               # (B,H)
    logf = jax.nn.log_sigmoid(ftilde)
    m_prev, C_prev, n_prev = cache["m"], cache["C"], cache["n"]
    m_new = jnp.maximum(logf + m_prev, itilde)
    fw = jnp.exp(logf + m_prev - m_new)
    iw = jnp.exp(itilde - m_new)
    C = fw[..., None, None] * C_prev + iw[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k.astype(jnp.float32),
                   v.astype(jnp.float32))
    n = fw[..., None] * n_prev + iw[..., None] * k.astype(jnp.float32)
    scale = 1.0 / (dh ** 0.5)
    qf = q.astype(jnp.float32) * scale
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, d_inner)
    out = h.astype(x.dtype) * gate
    return (jnp.einsum("bse,ed->bsd", out, p["w_down"]),
            {"C": C, "n": n, "m": m_new,
             "f_acc": cache["f_acc"] + logf})


# ================================================================== sLSTM

def slstm_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 3)
    return {
        "w_x": _init(ks[0], (d, 4 * d), dtype=dtype),         # z i f o
        # recurrent weights, block-diagonal per head: (H, dh, 4*dh)
        "r_h": _init(ks[1], (H, dh, 4 * dh), scale=0.1, dtype=jnp.float32),
        "b": jnp.concatenate([jnp.zeros((2 * d,)),
                              jnp.ones((d,)), jnp.zeros((d,))]
                             ).astype(jnp.float32),
        "w_down": _init(ks[2], (d, d), dtype=dtype),
    }


def slstm_cache_init(cfg: ModelConfig, batch: int,
                     dtype=jnp.float32) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    return {
        "c": jnp.zeros((batch, H, dh), dtype),
        "n": jnp.ones((batch, H, dh), dtype),
        "h": jnp.zeros((batch, H, dh), dtype),
        "m": jnp.zeros((batch, H, dh), dtype),
    }


def _slstm_cell(p: Params, xt: jnp.ndarray, state: Params, cfg: ModelConfig):
    """xt: (B, d) pre-projected input for one step."""
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    B = xt.shape[0]
    wx = jnp.einsum("bd,de->be", xt, p["w_x"]).astype(jnp.float32) + p["b"]
    rh = jnp.einsum("bhd,hde->bhe", state["h"], p["r_h"])     # (B,H,4dh)
    pre = wx.reshape(B, H, 4, dh) + rh.reshape(B, H, 4, dh)
    ztil, itil, ftil, otil = (pre[:, :, 0], pre[:, :, 1],
                              pre[:, :, 2], pre[:, :, 3])
    z = jnp.tanh(ztil)
    o = jax.nn.sigmoid(otil)
    logf = jax.nn.log_sigmoid(ftil)
    m_new = jnp.maximum(logf + state["m"], itil)
    iw = jnp.exp(itil - m_new)
    fw = jnp.exp(logf + state["m"] - m_new)
    c = fw * state["c"] + iw * z
    n = fw * state["n"] + iw
    h = o * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Sequential sLSTM over S (lax.scan). x: (B, S, d_model)."""
    B, S, d = x.shape
    state = slstm_cache_init(cfg, B)

    def step(st, xt):
        st2 = _slstm_cell(p, xt, st, cfg)
        return st2, st2["h"]

    _, hs = jax.lax.scan(step, state, jnp.moveaxis(x, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d)
    return jnp.einsum("bse,ed->bsd", h.astype(x.dtype), p["w_down"])


def slstm_decode(p: Params, x: jnp.ndarray, cache: Params,
                 cfg: ModelConfig) -> Tuple[jnp.ndarray, Params]:
    st = _slstm_cell(p, x[:, 0], cache, cfg)
    B = x.shape[0]
    h = st["h"].reshape(B, 1, cfg.d_model)
    return jnp.einsum("bse,ed->bsd", h.astype(x.dtype), p["w_down"]), st
