"""Mamba2 (SSD) layer — zamba2's backbone mixer. [arXiv:2405.21060 form]

Chunked "state-space dual" formulation: intra-chunk attention-like matmuls
(MXU-friendly) + an inter-chunk recurrence scanned over chunks. Decode is
the O(1) recurrent update. Grouped B/C (n_groups) as in Mamba2; D skip and
depthwise conv front as in the reference implementation.

Train path shapes: x (B, S, d_model); d_inner = expand * d_model;
H = d_inner / headdim heads; state size N = d_state.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _init

Params = Dict[str, jnp.ndarray]


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.headdim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, H, conv_dim


def ssm_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    s = cfg.ssm
    d_inner, H, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        # fused input projection: [z | x+B+C (conv'd) | dt]
        "w_in": _init(ks[0], (cfg.d_model, d_inner + conv_dim + H),
                      dtype=dtype),
        "conv_w": _init(ks[1], (s.d_conv, conv_dim), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "w_out": _init(ks[2], (d_inner, cfg.d_model), dtype=dtype),
    }


def _split_proj(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    d_inner, H, conv_dim = _dims(cfg)
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + conv_dim]
    dt = proj[..., d_inner + conv_dim:]
    return z, xbc, dt


def _split_xbc(xbc: jnp.ndarray, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    xs = xbc[..., :d_inner]
    Bmat = xbc[..., d_inner:d_inner + gn]
    Cmat = xbc[..., d_inner + gn:]
    return xs, Bmat, Cmat


def _conv_train(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                d_conv: int) -> jnp.ndarray:
    """Causal depthwise conv over S. xbc: (B, S, C)."""
    pads = jnp.pad(xbc, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(pads[:, i:i + xbc.shape[1]] * w[i] for i in range(d_conv))
    return jax.nn.silu(out + b)


def ssd_chunked(x, dt, a_log, Bmat, Cmat, cfg: ModelConfig):
    """SSD scan. x: (B,S,H,dh); dt: (B,S,H); Bmat/Cmat: (B,S,G,N)."""
    s = cfg.ssm
    Bsz, S, H, dh = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    Q = min(s.chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // Q
    rep = H // G                                   # heads per group

    A = -jnp.exp(a_log)                            # (H,), negative
    dta = dt * A                                   # (B,Sp,H) log-decay
    xdt = x * dt[..., None]                        # dt-weighted input

    def c(t, extra=()):                            # chunk a time axis
        return t.reshape((Bsz, nc, Q) + t.shape[2:])

    xc, dtac = c(xdt), c(dta)
    Bc = jnp.repeat(c(Bmat), rep, axis=3)          # (B,nc,Q,H,N) via group rep
    Cc = jnp.repeat(c(Cmat), rep, axis=3)
    la = jnp.cumsum(dtac, axis=2)                  # (B,nc,Q,H) cum log decay

    # intra-chunk (attention-like): L[i,j] = exp(la_i - la_j) for j <= i.
    # mask BEFORE exp: masked entries have la_i - la_j > 0 (la decreasing),
    # and exp(big) = inf would poison the backward (inf * 0 -> NaN in vjp).
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]      # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    L = jnp.exp(seg)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", Cc, Bc) * L
    y_intra = jnp.einsum("bcqkh,bckhd->bcqhd", scores, xc)

    # chunk-final states: sum_j exp(la_Q - la_j) B_j (x_j dt_j)^T
    decay_to_end = jnp.exp(la[:, :, -1:, :] - la)          # (B,nc,Q,H)
    states = jnp.einsum("bcqh,bcqhn,bcqhd->bchnd",
                        decay_to_end, Bc, xc)              # (B,nc,H,N,dh)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(la[:, :, -1, :])                 # (B,nc,H)

    def step(prev, inp):
        st, dec = inp                                      # (B,H,N,dh), (B,H)
        new = prev * dec[..., None, None] + st
        return new, prev                                   # emit state BEFORE chunk

    init = jnp.zeros((Bsz, H, N, dh), x.dtype)
    _, prev_states = jax.lax.scan(
        step, init, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # (B,nc,H,N,dh)

    y_inter = jnp.einsum("bcqh,bcqhn,bchnd->bcqhd",
                         jnp.exp(la), Cc, prev_states)
    y = (y_intra + y_inter).reshape(Bsz, Sp, H, dh)
    return y[:, :S]


def ssm_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Full-sequence Mamba2 mixer. x: (B, S, d_model)."""
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    B_, S, _ = x.shape
    z, xbc, dt = _split_proj(p, x, cfg)
    xbc = _conv_train(xbc, p["conv_w"], p["conv_b"], s.d_conv)
    xs, Bmat, Cmat = _split_xbc(xbc, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xh = xs.reshape(B_, S, H, s.headdim)
    Bm = Bmat.reshape(B_, S, s.n_groups, s.d_state)
    Cm = Cmat.reshape(B_, S, s.n_groups, s.d_state)
    y = ssd_chunked(xh.astype(jnp.float32), dt, p["a_log"], Bm.astype(jnp.float32),
                    Cm.astype(jnp.float32), cfg)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = (y.reshape(B_, S, d_inner) * jax.nn.silu(z.astype(jnp.float32)))
    return jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["w_out"])


# ---------------------------------------------------------------- decode

def ssm_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    s = cfg.ssm
    d_inner, H, conv_dim = _dims(cfg)
    return {
        "state": jnp.zeros((batch, H, s.d_state, s.headdim), dtype),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
    }


def ssm_decode(p: Params, x: jnp.ndarray, cache: Params,
               cfg: ModelConfig) -> Tuple[jnp.ndarray, Params]:
    """One-token recurrent update. x: (B, 1, d_model)."""
    s = cfg.ssm
    d_inner, H, conv_dim = _dims(cfg)
    B_ = x.shape[0]
    z, xbc, dt = _split_proj(p, x, cfg)                     # (B,1,*)
    # depthwise conv via cache of the last d_conv-1 inputs
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)    # (B,d_conv,C)
    conv_out = jax.nn.silu(
        jnp.einsum("btc,tc->bc", hist, p["conv_w"]) + p["conv_b"])[:, None]
    new_conv = hist[:, 1:]
    xs, Bmat, Cmat = _split_xbc(conv_out, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    xh = xs.reshape(B_, H, s.headdim).astype(jnp.float32)
    rep = H // s.n_groups
    Bm = jnp.repeat(Bmat.reshape(B_, s.n_groups, s.d_state), rep, 1)  # (B,H,N)
    Cm = jnp.repeat(Cmat.reshape(B_, s.n_groups, s.d_state), rep, 1)
    A = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * A)                                 # (B,H)
    upd = jnp.einsum("bh,bhn,bhd->bhnd", dt, Bm, xh)
    state = cache["state"] * decay[..., None, None] + upd
    y = jnp.einsum("bhn,bhnd->bhd", Cm, state)
    y = y + xh * p["d_skip"][None, :, None]
    y = (y.reshape(B_, 1, d_inner)
         * jax.nn.silu(z.astype(jnp.float32)))
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["w_out"])
    return out, {"state": state, "conv": new_conv}


def ssm_reference(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Sequential-scan oracle for ssd_chunked (tests only)."""
    B_, S, _ = x.shape
    cache = ssm_cache_init(cfg, B_)
    outs = []
    for t in range(S):
        o, cache = ssm_decode(p, x[:, t:t + 1], cache, cfg)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
