"""Mixture-of-Experts FFN with top-k routing (qwen3-moe, phi3.5-moe).

Sort-based dispatch (grouped-GEMM layout): token assignments are sorted by
expert id, ranked within each expert via segment offsets, capacity-clipped
and scattered into an (E, C, d) buffer so the expert matmuls are plain
einsums with the expert axis sharded over the mesh "model" axis
(expert-parallelism). This avoids the O(T*E*C) dispatch mask of the naive
one-hot formulation — the buffer is the largest live tensor and shards by
expert. Router stats (load fraction, aux loss) are returned for the
load-balance regulariser.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _init

Params = Dict[str, jnp.ndarray]


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    moe = cfg.moe
    d, f, e = cfg.d_model, moe.d_ff_expert, moe.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "w_router": _init(k1, (d, e), scale=0.02, dtype=jnp.float32),
        "w_gate": _init(k2, (e, d, f), dtype=dtype),
        "w_up": _init(k3, (e, d, f), dtype=dtype),
        "w_down": _init(k4, (e, f, d), dtype=dtype),
    }


def moe_capacity(cfg: ModelConfig, tokens: int) -> int:
    moe = cfg.moe
    c = int(moe.top_k * tokens * moe.capacity_factor / moe.n_experts) + 1
    return min(max(c, 4), tokens)


def moe_ffn(p: Params, x: jnp.ndarray, cfg: ModelConfig
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, S, d) -> (B, S, d), router stats.

    Dropped tokens (over capacity) contribute zero from the dropped
    expert; their other top-k routes still apply (standard capacity
    semantics). With ``dispatch_shards=N`` the sort/scatter runs
    independently on N token shards (local capacity) — semantics match
    per-shard-capacity MoE and the scatters stay shard-local (§Perf).
    """
    moe = cfg.moe
    B, S, d = x.shape
    T = B * S
    D = max(1, moe.dispatch_shards)
    if D > 1 and T % D == 0:
        xs = x.reshape(D, T // D, 1, d)
        y, stats = jax.vmap(lambda xx: _moe_dispatch(p, xx, cfg))(xs)
        y = y.reshape(B, S, d)
        stats = jax.tree_util.tree_map(lambda s: s.mean(axis=0), stats)
        return y, stats
    return _moe_dispatch(p, x, cfg)


def _moe_dispatch(p: Params, x: jnp.ndarray, cfg: ModelConfig
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    moe = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = moe.n_experts, moe.top_k
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["w_router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, K)                     # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_ids = ids.reshape(-1)                               # (T*K,)
    order = jnp.argsort(flat_ids)                            # stable
    sorted_ids = flat_ids[order]
    counts = jax.ops.segment_sum(jnp.ones_like(flat_ids), flat_ids,
                                 num_segments=E)             # (E,)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * K) - starts[sorted_ids]
    C = moe_capacity(cfg, T)
    keep = rank < C
    slot = jnp.where(keep, sorted_ids * C + rank, E * C)     # overflow -> dropped

    src_token = order // K                                   # token of each slot
    buf = jnp.zeros((E * C + 1, d), xt.dtype).at[slot].set(xt[src_token])
    buf = buf[:-1].reshape(E, C, d)
    if moe.shard_buffers:
        # expert-parallel layout for the dispatch buffer and expert
        # activations: tokens cross the mesh once (all-to-all-ish)
        # instead of the token stream being gathered onto every shard.
        from jax.sharding import PartitionSpec as P
        wsc = jax.lax.with_sharding_constraint
        buf = wsc(buf, P("model", None, None))

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    if moe.shard_buffers:
        g = wsc(g, P("model", None, None))
        u = wsc(u, P("model", None, None))
    yb = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])      # (E, C, d)

    y_sorted = yb.reshape(E * C, d)
    gathered = jnp.where(keep[:, None], y_sorted[jnp.minimum(slot, E * C - 1)],
                         0.0)
    y_flat = jnp.zeros((T * K, d), xt.dtype).at[order].set(gathered)
    y = (y_flat.reshape(T, K, d)
         * gates.astype(xt.dtype)[..., None]).sum(axis=1)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    frac = counts.astype(jnp.float32) / jnp.maximum(counts.sum(), 1.0)
    mean_prob = probs.mean(axis=0)
    aux = E * jnp.sum(frac * mean_prob)
    stats = {"aux_loss": aux,
             "dropped_frac": 1.0 - keep.mean(),
             "load_frac": frac}
    return y.reshape(B, S, d), stats
