"""Model zoo: one functional Model class covering all six families."""
from repro.models.model import Model
from repro.models import attention, blocks, flash, layers, moe, sharding, ssm, xlstm

__all__ = ["Model", "attention", "blocks", "flash", "layers", "moe",
           "sharding", "ssm", "xlstm"]
