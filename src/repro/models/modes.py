"""Trace-time cost-probe modes for the dry-run roofline analysis.

XLA's HloCostAnalysis visits each while-loop body ONCE, so a model that
``lax.scan``s its layers (and flash-attention chunks) under-reports FLOPs
and bytes. The dry-run therefore compiles small L=1/L=2 probe models with:

  UNROLL_LAYERS — the layer scan is unrolled (bodies appear L times in
    HLO): per-layer byte/collective increments become measurable.
  EXACT_CHUNKS — flash attention / mLSTM process the sequence as ONE
    chunk (algebraically the same FLOP count as the chunked schedule,
    which computes every q x kv block pair): FLOP increments become exact.
    (SSD needs no flag: its intra-chunk einsums are batched over chunks,
    not scanned, so they are already fully counted.)

Flags are trace-time globals set by context managers around
``jit(...).lower()`` in launch/dryrun.py; production paths never set them.
"""
from __future__ import annotations

import contextlib

UNROLL_LAYERS = False
EXACT_CHUNKS = False


@contextlib.contextmanager
def probe_mode(unroll_layers: bool = True, exact_chunks: bool = False):
    global UNROLL_LAYERS, EXACT_CHUNKS
    old = (UNROLL_LAYERS, EXACT_CHUNKS)
    UNROLL_LAYERS, EXACT_CHUNKS = unroll_layers, exact_chunks
    try:
        yield
    finally:
        UNROLL_LAYERS, EXACT_CHUNKS = old


def layer_unroll(n_layers: int) -> int:
    return n_layers if UNROLL_LAYERS else 1


def chunk_override(size: int, full: int) -> int:
    return full if EXACT_CHUNKS else size
