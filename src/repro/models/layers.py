"""Shared neural building blocks (pure JAX, functional params-as-pytrees).

Parameters live in nested dicts; homogeneous layer stacks carry a leading
layer axis so the forward pass can ``lax.scan`` over layers (keeps the HLO
small — essential for the 80-config dry-run on one CPU core, and standard
practice at scale).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]


def _init(key, shape, scale=None, dtype=jnp.float32):
    if scale is None:
        scale = 1.0 / jnp.sqrt(shape[0])
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU MLP: down( silu(x @ gate) * (x @ up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _init(k1, (d_model, d_ff), dtype=dtype),
        "w_up": _init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": _init(k3, (d_ff, d_model), dtype=dtype),
    }


def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32) -> jnp.ndarray:
    return _init(key, (vocab, d_model), scale=0.02, dtype=dtype)


def rope_frequencies(d_head: int, theta: float = 1e4) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """x: (..., seq, heads, d_head); positions: (..., seq)."""
    d_head = x.shape[-1]
    freqs = rope_frequencies(d_head, theta)                   # (d_head/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    angles = angles[..., None, :]                             # (..., S, 1, dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean CE over (batch, seq[, heads]) with optional validity mask."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def stack_layer_params(keys, init_fn) -> Params:
    """Initialise L copies of a layer and stack each leaf on axis 0."""
    per_layer = [init_fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_layer)
