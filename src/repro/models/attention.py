"""GQA attention layer: init, forward (flash), decode (KV cache).

Weights keep the fused (d_model, n_heads*d_head) layout so the model axis
can shard the fused dim (always divisible by the mesh's model size for the
assigned architectures; see models/sharding.py).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import flash
from repro.models.layers import _init, apply_rope

Params = Dict[str, jnp.ndarray]


def attn_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d, dh = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "w_q": _init(k1, (d, hq * dh), dtype=dtype),
        "w_k": _init(k2, (d, hkv * dh), dtype=dtype),
        "w_v": _init(k3, (d, hkv * dh), dtype=dtype),
        "w_o": _init(k4, (hq * dh, d), dtype=dtype),
    }


def _project_qkv(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                 cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, S, _ = x.shape
    dh = cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["w_q"]).reshape(B, S, cfg.n_heads, dh)
    k = jnp.einsum("bsd,de->bse", x, p["w_k"]).reshape(B, S, cfg.n_kv_heads, dh)
    v = jnp.einsum("bsd,de->bse", x, p["w_v"]).reshape(B, S, cfg.n_kv_heads, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                 window: int = 0) -> jnp.ndarray:
    """Full-sequence causal attention (train / prefill). x: (B, S, d)."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(p, x, positions, cfg)
    out = flash.flash_attention(q, k, v, causal=True, window=window)
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bse,ed->bsd", out, p["w_o"])


def attn_cache_init(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16) -> Params:
    """KV cache for one layer. Sliding-window archs pass max_len=window
    (ring buffer); full attention passes the sequence length."""
    dh = cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), dtype),
    }


def attn_decode(p: Params, x: jnp.ndarray, cache: Params,
                pos: jnp.ndarray, cfg: ModelConfig,
                window: int = 0) -> Tuple[jnp.ndarray, Params]:
    """One-token decode. x: (B, 1, d); pos: scalar int32 absolute position.

    Returns (output (B, 1, d), updated cache). Ring-buffer indexing when
    the cache is shorter than the absolute position (sliding window).
    """
    B = x.shape[0]
    smax = cache["k"].shape[1]
    positions = jnp.broadcast_to(pos[None], (B, 1))
    q, k, v = _project_qkv(p, x, positions, cfg)
    slot = jnp.mod(pos, smax)
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    cache_len = jnp.minimum(pos + 1, smax)
    out = flash.decode_attention(
        q, k_cache, v_cache, jnp.broadcast_to(cache_len, (B,)),
        window=0)  # ring buffer already bounds the window
    out = out.reshape(B, 1, cfg.n_heads * cfg.head_dim)
    return (jnp.einsum("bse,ed->bsd", out, p["w_o"]),
            {"k": k_cache, "v": v_cache})
