"""Cross-version jax compatibility shims for the mesh/sharding API.

The repo targets the modern mesh API (``jax.make_mesh(..., axis_types=...)``,
``jax.sharding.AxisType``, ``jax.set_mesh`` / ``jax.sharding.use_mesh``,
``jax.shard_map``), but must also run on jax 0.4.x where none of those
exist yet. This module provides the missing pieces:

  * ``AxisType`` — re-export, or a stand-in enum on old jax;
  * ``make_mesh`` — accepts (and, on old jax, swallows) ``axis_types``;
  * ``set_mesh`` / ``use_mesh`` — context managers that fall back to the
    classic ``with mesh:`` physical-mesh context;
  * ``shard_map`` — ``jax.shard_map`` or the 0.4.x experimental location.

``install()`` (called from ``repro.__init__``) additionally fills the gaps
in the ``jax`` namespace itself — never overriding anything that exists —
so scripts and tests written against the modern spelling
(``jax.make_mesh(..., axis_types=...)``, ``jax.set_mesh``) run unchanged
on the pinned 0.4.x toolchain.
"""
from __future__ import annotations

import contextlib
import enum
import functools
import inspect

import jax
import jax.sharding


# ------------------------------------------------------------- AxisType

try:
    from jax.sharding import AxisType            # jax >= 0.5
except ImportError:                              # pragma: no cover - new jax
    class AxisType(enum.Enum):
        """Stand-in for jax.sharding.AxisType on jax 0.4.x, where every
        mesh axis is implicitly Auto (GSPMD-propagated)."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


# ------------------------------------------------------------- make_mesh

_native_make_mesh = jax.make_mesh
_HAS_AXIS_TYPES = "axis_types" in inspect.signature(_native_make_mesh).parameters


@functools.wraps(_native_make_mesh)
def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` accepting ``axis_types`` on every jax version.

    On jax 0.4.x only ``AxisType.Auto`` is emulated (every axis there is
    implicitly Auto/GSPMD); requesting Explicit or Manual axes raises
    rather than silently changing sharding semantics.
    """
    if _HAS_AXIS_TYPES:
        return _native_make_mesh(axis_shapes, axis_names,
                                 axis_types=axis_types, devices=devices)
    if axis_types is not None and any(t is not None and t != AxisType.Auto
                                      for t in axis_types):
        raise NotImplementedError(
            f"jax {jax.__version__} only supports Auto mesh axes; "
            f"got axis_types={axis_types}")
    return _native_make_mesh(axis_shapes, axis_names, devices=devices)


# ------------------------------------------------------- mesh contexts

if hasattr(jax.sharding, "use_mesh"):
    use_mesh = jax.sharding.use_mesh
else:
    @contextlib.contextmanager
    def use_mesh(mesh: jax.sharding.Mesh):
        """Fallback: the classic physical-mesh context (``with mesh:``)."""
        with mesh:
            yield mesh


if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
else:
    def set_mesh(mesh: jax.sharding.Mesh):
        """Fallback for ``jax.set_mesh``: usable as ``with set_mesh(m):``."""
        return use_mesh(mesh)


# ------------------------------------------------------------ shard_map

if hasattr(jax, "shard_map"):
    _native_shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _native_shard_map
_SM_PARAMS = inspect.signature(_native_shard_map).parameters


@functools.wraps(_native_shard_map)
def shard_map(f, *, mesh, in_specs, out_specs, check_rep=None, **kwargs):
    """``shard_map`` with the replication-check kwarg normalised: newer
    jax renamed ``check_rep`` to ``check_vma``; pass whichever exists."""
    if check_rep is not None:
        if "check_rep" in _SM_PARAMS:
            kwargs["check_rep"] = check_rep
        elif "check_vma" in _SM_PARAMS:
            kwargs["check_vma"] = check_rep
    return _native_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)


# -------------------------------------------------------------- install

_installed = False


def install() -> None:
    """Fill missing mesh-API attributes on the jax namespace (idempotent).

    Only ever adds what is absent; on a modern jax this is a no-op.
    """
    global _installed
    if _installed:
        return
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = AxisType
    if not _HAS_AXIS_TYPES:
        jax.make_mesh = make_mesh
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = set_mesh
    if not hasattr(jax.sharding, "use_mesh"):
        jax.sharding.use_mesh = use_mesh
    if not hasattr(jax, "shard_map"):
        jax.shard_map = shard_map
    _installed = True
