"""Generalized batched Pallas order-statistics kernel (TPU VPU bisection).

One kernel serves every coordinate-wise aggregator in the registry: k-th
order statistic, median, trimmed mean, scale-supplied DCQ, MAD-scaled DCQ,
and a fused median+MAD+DCQ single pass — all built from the same bisection
rank-counting core. The GPU-natural formulation (per-coordinate sort) maps
poorly onto the TPU's vector unit — there is no fast per-lane sort.
Instead order statistics are found by binary-searching the value range per
coordinate, counting ranks with full-width VPU comparisons and reductions
over the machine axis; ``N_BISECT`` halvings pin the k-th order statistic
to below fp32 resolution. The whole tile lives in VMEM:

  values tile (m, TP)  ->  order stats / trimmed sums / CQ sums  ->  (TP,)

Grid: ``(batch, coordinate blocks)`` — LEADING BATCH AXES ARE MAPPED ONTO
THE PALLAS GRID, so the sweep engine's (scenarios, replicates, machines,
coords) stacks aggregate in one fused kernel launch instead of
per-scenario sorted fallbacks. The machine axis is small (m <= a few
thousand) and stays resident. All comparisons are masked-sum reductions —
no data-dependent control flow, MXU not needed (a pure VPU kernel, which
is why the paper's center-side aggregation is cheap on TPU).

Large-p regime: each grid program owns a block of ``tile * inner``
coordinates and walks it in an in-kernel coordinate-tile loop (``inner``
statically-unrolled subtiles of width ``tile``), so p in the
thousands–millions amortizes per-program grid overhead while
:func:`clamp_block` keeps the resident block under the VMEM budget —
the delivered block never exceeds ``VMEM_BUDGET_BYTES`` no matter how
large p grows (the grid covers the rest). ``tile``, ``inner`` and the
bisection trip count ``n_bisect`` are jit-static knobs tuned per
(op, shape-bucket, platform) by :mod:`repro.agg.autotune`; ``N_BISECT``
is only the untuned default (60 halvings pin fp32 exactly; measured
buckets typically need far fewer).

The trimmed mean needs no sort either: with the two bracketing order
statistics ``t_lo = v_(g)`` and ``t_hi = v_(m-1-g)`` in hand, the trimmed
sum is recovered exactly from masked sums with a tie correction:

  kept = [S(v<=t_hi) - (N(v<=t_hi) - (m-g)) t_hi]
       - [S(v<=t_lo) - (N(v<=t_lo) - g) t_lo]

Validated against repro.agg.reference (the pure-jnp oracle) over a
shape/dtype/m-parity sweep, including the batched grid path, in
tests/test_agg.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.agg.reference import MAD_EPS, MAD_SIGMA

#: default bisection trip count — enough halvings to pin any fp32 value;
#: the autotuner replaces this per bucket (32 already reaches fp32
#: resolution on unit-scale data).
N_BISECT = 60

#: per-program VMEM budget for the resident values block (bytes). A TPU
#: core has ~16 MB of VMEM; half of it leaves room for Pallas's
#: double-buffered pipelining of the next block plus outputs/scale.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

#: operations the generalized kernel computes from the shared bisection core
OPS = ("mean", "median", "kth", "trimmed", "dcq", "dcq_mad",
       "median_mad_dcq")


def clamp_block(m: int, p: int, tile: int, inner: int,
                budget: int = VMEM_BUDGET_BYTES):
    """Clamp a (tile, inner) candidate so one program's resident f32
    values block ``m x (tile * inner)`` fits the VMEM budget and carries
    no all-padding subtiles. Returns the adjusted (tile, inner)."""
    tile = max(128, min(tile, p)) if p >= 128 else max(1, min(tile, p))
    max_cols = max(budget // (4 * max(m, 1)), tile)
    inner = max(1, min(inner, max_cols // tile))
    # never a block wider than the (padded) coordinate count
    inner = min(inner, -(-p // tile))
    return tile, inner


def cq_constants(K: int):
    """Host-side composite-quantile constants: the K standard-normal knots
    ``Delta_k = Psi^{-1}(k/(K+1))`` and ``sum_k psi(Delta_k)`` — Python
    floats baked into the kernel as compile-time scalars."""
    from statistics import NormalDist
    nd = NormalDist()
    knots = tuple(nd.inv_cdf((k + 1.0) / (K + 1.0)) for k in range(K))
    psi_sum = sum(math.exp(-0.5 * d * d) for d in knots) \
        / math.sqrt(2.0 * math.pi)
    return knots, psi_sum


# ------------------------------------------------------ bisection core

def _kth_smallest(vals: jnp.ndarray, k, lo: jnp.ndarray,
                  hi: jnp.ndarray, n_bisect: int = N_BISECT) -> jnp.ndarray:
    """Bisection k-th order statistic (0-indexed) per column.

    vals: (m, tp) f32; k: scalar; lo/hi: (tp,) bracketing values.
    Returns (tp,) the k-th smallest per column (exact as a value present
    in the column up to the fixed ``n_bisect``-halving resolution — an
    early-exit-free trip count, tuned per shape bucket by the autotuner).
    """
    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        # rank of mid: how many values are <= mid
        cnt = jnp.sum((vals <= mid[None, :]).astype(jnp.float32), axis=0)
        go_right = cnt <= jnp.float32(k)          # need larger values
        lo = jnp.where(go_right, mid, lo)
        hi = jnp.where(go_right, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, n_bisect, body, (lo, hi))
    return hi     # converged upper bracket = smallest value with rank > k


def _kth_cols(vals: jnp.ndarray, k: int,
              n_bisect: int = N_BISECT) -> jnp.ndarray:
    lo = jnp.min(vals, axis=0)
    hi = jnp.max(vals, axis=0)
    return _kth_smallest(vals, k, lo, hi, n_bisect)


def _median_cols(vals: jnp.ndarray,
                 n_bisect: int = N_BISECT) -> jnp.ndarray:
    """Columnwise median via one or two bisection searches. vals: (m, tp)."""
    m = vals.shape[0]
    if m % 2 == 1:
        return _kth_cols(vals, (m - 1) // 2, n_bisect)
    return 0.5 * (_kth_cols(vals, m // 2 - 1, n_bisect)
                  + _kth_cols(vals, m // 2, n_bisect))


def _trimmed_cols(vals: jnp.ndarray, g: int,
                  n_bisect: int = N_BISECT) -> jnp.ndarray:
    """Columnwise beta-trimmed mean (g dropped per side) without sorting:
    bracket with two order statistics, recover the kept sum from masked
    sums with an exact tie correction."""
    m = vals.shape[0]
    if g == 0:
        return jnp.mean(vals, axis=0)
    t_lo = _kth_cols(vals, g, n_bisect)
    t_hi = _kth_cols(vals, m - 1 - g, n_bisect)
    le_hi = (vals <= t_hi[None, :]).astype(jnp.float32)
    le_lo = (vals <= t_lo[None, :]).astype(jnp.float32)
    top = (vals * le_hi).sum(axis=0) - (le_hi.sum(axis=0) - (m - g)) * t_hi
    bot = (vals * le_lo).sum(axis=0) - (le_lo.sum(axis=0) - g) * t_lo
    return (top - bot) / (m - 2 * g)


def _cq_correct(vals: jnp.ndarray, med: jnp.ndarray, scale: jnp.ndarray,
                knots, psi_sum: float) -> jnp.ndarray:
    """Composite-quantile correction: med - scale*S/(m*psi_sum) with
    S = sum_k sum_j [I(v_j <= med + scale*Delta_k) - kappa_k]."""
    m = vals.shape[0]
    K = len(knots)
    s = jnp.zeros_like(med)
    for j, delta in enumerate(knots):           # K static (10): unrolled
        thr = med + scale * delta
        kappa = (j + 1.0) / (K + 1.0)
        ind = (vals <= thr[None, :]).astype(jnp.float32)
        s = s + ind.sum(axis=0) - m * kappa
    return med - scale * s / (m * psi_sum)


# ---------------------------------------------------------- kernel body

def _ostat_kernel(*refs, op: str, knots, psi_sum: float, g: int, kth: int,
                  has_scale: bool, tile: int, inner: int, n_bisect: int):
    values_ref = refs[0]
    scale_ref = refs[1] if has_scale else None
    outs = refs[1 + int(has_scale):]

    # coordinate-tile double loop: the program's (m, tile*inner) block is
    # walked one statically-unrolled (m, tile) subtile at a time, so the
    # per-bisection working set stays one subtile wide while each grid
    # step amortizes over ``inner`` tiles.
    for j in range(inner):
        sl = slice(j * tile, (j + 1) * tile)
        vals = values_ref[0, :, sl].astype(jnp.float32)   # (m, tile)

        if op == "mean":
            res = (jnp.mean(vals, axis=0),)
        elif op == "kth":
            res = (_kth_cols(vals, kth, n_bisect),)
        elif op == "median":
            res = (_median_cols(vals, n_bisect),)
        elif op == "trimmed":
            res = (_trimmed_cols(vals, g, n_bisect),)
        elif op == "dcq":
            med = _median_cols(vals, n_bisect)
            scale = scale_ref[0, sl].astype(jnp.float32)  # (tile,)
            res = (_cq_correct(vals, med, scale, knots, psi_sum),)
        elif op == "dcq_mad":
            med = _median_cols(vals, n_bisect)
            mad = _median_cols(jnp.abs(vals - med[None, :]), n_bisect)
            scale = MAD_SIGMA * mad + MAD_EPS
            res = (_cq_correct(vals, med, scale, knots, psi_sum),)
        elif op == "median_mad_dcq":
            # fused single pass: one resident subtile, three statistics out
            med = _median_cols(vals, n_bisect)
            mad = _median_cols(jnp.abs(vals - med[None, :]), n_bisect)
            scale = MAD_SIGMA * mad + MAD_EPS
            res = (med, mad, _cq_correct(vals, med, scale, knots, psi_sum))
        else:
            raise ValueError(f"unknown order-statistics op {op!r}")
        for out_ref, r in zip(outs, res):
            out_ref[0, sl] = r.astype(out_ref.dtype)


# --------------------------------------------------------- public entry

@functools.partial(jax.jit, static_argnames=("op", "K", "trim_beta", "kth",
                                             "tile", "inner", "n_bisect",
                                             "interpret"))
def ostat_pallas(values: jnp.ndarray, op: str, scale=None, *, K: int = 10,
                 trim_beta: float = 0.2, kth: int = 0, tile: int = 512,
                 inner: int = 1, n_bisect: int = N_BISECT,
                 interpret=None):
    """Batched order-statistics aggregation ``(*B, m, p) -> (*B, p)``.

    The machine axis is second-to-last; any leading axes are batch and map
    onto the Pallas grid (one program per (batch row, coordinate block of
    ``tile * inner`` columns) — the block is walked in an in-kernel
    coordinate-tile loop and is clamped to the VMEM budget, so arbitrary
    p is safe). ``op="median_mad_dcq"`` returns the fused
    ``(median, mad, dcq)`` triple; every other op returns a single array.
    ``scale`` (``(*B, p)``) is required for ``op="dcq"``. ``tile``,
    ``inner`` and the bisection trip count ``n_bisect`` are the
    autotuner's knobs (repro.agg.autotune; dispatch feeds the measured
    values per shape bucket). ``interpret=None`` auto-selects interpret
    mode off-TPU (this container); on TPU the compiled kernel runs
    natively.
    """
    if op not in OPS:
        raise ValueError(f"unknown order-statistics op {op!r}; one of {OPS}")
    if values.ndim < 2:
        raise ValueError(f"need (*batch, m, p), got shape {values.shape}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    batch = values.shape[:-2]
    m, p = values.shape[-2:]
    bn = 1
    for d in batch:
        bn *= d
    vals = values.reshape((bn, m, p))

    g = max(int(trim_beta * m), 0)
    if op == "trimmed" and 2 * g >= m:
        raise ValueError(f"trim fraction {trim_beta} too large for m={m}")
    knots, psi_sum = cq_constants(K)

    tile, inner = clamp_block(m, p, tile, inner)
    block = tile * inner
    pad = (-p) % block
    if pad:
        vals = jnp.pad(vals, ((0, 0), (0, 0), (0, pad)))
    pp = p + pad

    has_scale = op == "dcq"
    operands = [vals]
    in_specs = [pl.BlockSpec((1, m, block), lambda b, i: (b, 0, i))]
    if has_scale:
        if scale is None:
            raise ValueError("op='dcq' needs a per-coordinate scale")
        sc = jnp.broadcast_to(scale, batch + (p,)).reshape((bn, p))
        if pad:
            sc = jnp.pad(sc, ((0, 0), (0, pad)), constant_values=1.0)
        operands.append(sc)
        in_specs.append(pl.BlockSpec((1, block), lambda b, i: (b, i)))

    n_out = 3 if op == "median_mad_dcq" else 1
    out_spec = pl.BlockSpec((1, block), lambda b, i: (b, i))
    out_shape = [jax.ShapeDtypeStruct((bn, pp), values.dtype)
                 for _ in range(n_out)]
    outs = pl.pallas_call(
        functools.partial(_ostat_kernel, op=op, knots=knots,
                          psi_sum=psi_sum, g=g, kth=kth,
                          has_scale=has_scale, tile=tile, inner=inner,
                          n_bisect=n_bisect),
        grid=(bn, pp // block),
        in_specs=in_specs,
        out_specs=[out_spec] * n_out,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    outs = tuple(o[:, :p].reshape(batch + (p,)) for o in outs)
    return outs if n_out > 1 else outs[0]


@functools.partial(jax.jit, static_argnames=("K", "tile", "interpret"))
def dcq_pallas(values: jnp.ndarray, K: int = 10, tile: int = 512,
               interpret=None) -> jnp.ndarray:
    """DCQ-with-MAD aggregation of (m, p) -> (p,) via the Pallas kernel.

    Back-compat entry (formerly kernels/dcq.py): ``interpret=None``
    auto-selects like ``ostat_pallas`` — interpret mode off-TPU, native
    on TPU (the old hardcoded ``interpret=True`` default silently ran a
    TPU caller in interpret mode).
    """
    return ostat_pallas(values, "dcq_mad", K=K, tile=tile,
                        interpret=interpret)
