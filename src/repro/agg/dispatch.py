"""Measured backend-dispatch table for ``repro.agg``.

``backend=None`` ("auto") used to mean a platform heuristic: Pallas on
TPU, jnp reference elsewhere. BENCH_agg showed that heuristic picking the
7x-slower path at the sweep regime — which backend is fastest depends on
the *shape* (the sorted reference wins at small p, rank-count bisection
at large p), not just the platform. This module replaces the heuristic
with measurement: the autotuner (:mod:`repro.agg.autotune`) times every
backend of every registered aggregator over a grid of ``(B, m, p)``
problems and records the winner — plus the winning kernel tuning
parameters (``tile``, ``inner``, ``n_bisect``) — into a versioned
on-disk JSON table, one file per platform.

Lookup is shape-bucketed: ``(B, m, p)`` maps to the key
``B<log2 B>:m<log2 m>:p<log2 p>`` (floor log2 per axis), so one measured
entry covers its whole power-of-two neighbourhood. Dispatch policy for
``backend=None``:

  * platform table present, bucket measured  -> the recorded best
    backend with its recorded kernel parameters;
  * platform table present, bucket UNmeasured -> the reference oracle
    (conservative: never ship an unmeasured kernel config);
  * no table for this platform at all        -> the historical platform
    heuristic (Pallas on TPU, reference elsewhere).

Masked (serving) rules dispatch through the same table under op keys
``masked:<rule>`` with backends ``sort`` (the contractual
:mod:`repro.agg.masked` forms) / ``bisect`` (the sort-free rank-count
forms); their unmeasured fallback is ``sort``.

A measured CPU default table is committed at ``tables/cpu.json``
(regenerate with ``repro-agg-tune``); ``REPRO_AGG_DISPATCH=<path>``
points dispatch at a re-tuned table without touching the package, and
:func:`set_table` injects one in-process (tests, notebooks).

All tuning parameters are **ints** end to end (``Decision.params`` is
validated on load): they flow into ``jax.jit`` static arguments, where a
float- or list-valued key would silently retrace per call — the exact
hazard ``repro.analyze``'s retrace-hazard rule exists to catch.
"""
from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path
from typing import Dict, Optional, Tuple

import jax

SCHEMA = "repro.agg.dispatch/v1"

#: committed per-platform default tables (``cpu.json`` ships in the sdist)
TABLE_DIR = Path(__file__).resolve().parent / "tables"

#: environment override: path to a re-tuned table for this platform
ENV_VAR = "REPRO_AGG_DISPATCH"

#: kernel tuning parameters a table entry may carry (all static ints)
PARAM_KEYS = ("tile", "inner", "n_bisect")


@dataclasses.dataclass(frozen=True)
class Decision:
    """One dispatch outcome: which backend to run and how it was chosen.

    ``params`` are the measured kernel tuning ints (empty for reference /
    masked-sort); ``measured`` is False when the decision came from a
    fallback rather than a table entry; ``source`` says which
    ("table", "fallback-unmeasured", "fallback-no-table").
    """
    backend: str
    params: Dict[str, int]
    measured: bool
    source: str


def bucket_of(B: int, m: int, p: int) -> str:
    """Shape-bucket key: floor-log2 per axis, e.g. (320, 8, 10) ->
    ``"B8:m3:p3"``. One measured entry serves its whole power-of-two
    neighbourhood."""
    def lg(x):
        return max(int(x), 1).bit_length() - 1
    return f"B{lg(B)}:m{lg(m)}:p{lg(p)}"


def _fallback_backend(op: str, platform: str) -> str:
    if op.startswith("masked:"):
        return "sort"
    return "pallas" if platform == "tpu" else "reference"


class DispatchTable:
    """In-memory form of one platform's measured dispatch table."""

    def __init__(self, platform: str, entries: Optional[dict] = None,
                 meta: Optional[dict] = None):
        self.platform = platform
        self.entries: dict = entries if entries is not None else {}
        self.meta: dict = meta if meta is not None else {}

    # ------------------------------------------------------------ record

    def record(self, op: str, B: int, m: int, p: int, backend: str,
               time_s: float, **params) -> None:
        """Record one measured backend timing for a shape bucket. Tuning
        params must be ints (they become jit static arguments); the
        bucket's ``best`` backend is recomputed on every record."""
        bad = {k: v for k, v in params.items() if not isinstance(v, int)}
        if bad:
            raise TypeError(
                f"non-int tuning params {bad!r} for {op}: table params "
                "feed jit static arguments and must be hashable ints")
        key = f"{op}|{bucket_of(B, m, p)}"
        entry = self.entries.setdefault(key, {"backends": {}, "best": None})
        rec = {"time_s": float(time_s)}
        if params:
            rec["params"] = dict(params)
        entry["backends"][backend] = rec
        entry["best"] = min(entry["backends"],
                            key=lambda b: entry["backends"][b]["time_s"])

    # ------------------------------------------------------------ lookup

    def best(self, op: str, B: int, m: int,
             p: int) -> Optional[Tuple[str, Dict[str, int]]]:
        """The measured-best (backend, params) for this shape bucket, or
        None when the bucket was never measured for this op."""
        entry = self.entries.get(f"{op}|{bucket_of(B, m, p)}")
        if not entry or not entry.get("best"):
            return None
        backend = entry["best"]
        params = entry["backends"][backend].get("params", {})
        return backend, {k: int(v) for k, v in params.items()
                         if k in PARAM_KEYS}

    # ------------------------------------------------------- (de)serialize

    def to_json(self) -> dict:
        return {"schema": SCHEMA, "platform": self.platform,
                "meta": dict(self.meta),
                "entries": {k: self.entries[k]
                            for k in sorted(self.entries)}}

    @classmethod
    def from_json(cls, payload: dict) -> "DispatchTable":
        if payload.get("schema") != SCHEMA:
            raise ValueError(
                f"dispatch table schema {payload.get('schema')!r} != "
                f"{SCHEMA}; re-tune with repro-agg-tune")
        table = cls(payload["platform"], meta=dict(payload.get("meta", {})))
        for key, entry in payload.get("entries", {}).items():
            for backend, rec in entry.get("backends", {}).items():
                params = rec.get("params", {})
                bad = {k: v for k, v in params.items()
                       if not isinstance(v, int)}
                if bad:
                    raise ValueError(
                        f"dispatch entry {key!r}/{backend} carries non-int "
                        f"params {bad!r}: would retrace per call as a jit "
                        "static argument")
            table.entries[key] = {
                "backends": {b: dict(r)
                             for b, r in entry["backends"].items()},
                "best": entry.get("best")}
        return table

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        return path

    @classmethod
    def load(cls, path) -> "DispatchTable":
        with open(path) as f:
            return cls.from_json(json.load(f))


# ------------------------------------------------------- module-level cache

#: platform -> DispatchTable | None (None = looked, no table on disk)
_CACHE: dict = {}
#: test/in-process injection: platform -> DispatchTable
_INJECTED: dict = {}


def clear_cache() -> None:
    """Drop loaded tables (picks up a changed ENV_VAR / table file)."""
    _CACHE.clear()


def set_table(table: Optional[DispatchTable],
              platform: Optional[str] = None) -> None:
    """Inject a table for ``platform`` (default: the table's own platform)
    ahead of any on-disk file; ``set_table(None, platform)`` removes that
    injection and ``set_table(None)`` removes all of them. Test hook and
    notebook re-tuning hook."""
    if table is None:
        if platform is None:
            _INJECTED.clear()
        else:
            _INJECTED.pop(platform, None)
    else:
        _INJECTED[platform if platform is not None
                  else table.platform] = table
    clear_cache()


def load_table(platform: Optional[str] = None) -> Optional[DispatchTable]:
    """The active table for ``platform`` (default: current jax backend):
    injected > $REPRO_AGG_DISPATCH > committed tables/<platform>.json."""
    if platform is None:
        platform = jax.default_backend()
    if platform in _INJECTED:
        return _INJECTED[platform]
    if platform not in _CACHE:
        table = None
        env = os.environ.get(ENV_VAR)
        path = Path(env) if env else TABLE_DIR / f"{platform}.json"
        if path.is_file():
            table = DispatchTable.load(path)
            if table.platform != platform:
                table = None        # a cpu table must not steer a tpu run
        _CACHE[platform] = table
    return _CACHE[platform]


def decide(op: str, B: int, m: int, p: int,
           platform: Optional[str] = None) -> Decision:
    """Resolve ``backend=None`` for one aggregation problem (see module
    docstring for the policy)."""
    if platform is None:
        platform = jax.default_backend()
    table = load_table(platform)
    if table is None:
        return Decision(_fallback_backend(op, platform), {}, False,
                        "fallback-no-table")
    hit = table.best(op, B, m, p)
    if hit is None:
        backend = "sort" if op.startswith("masked:") else "reference"
        return Decision(backend, {}, False, "fallback-unmeasured")
    backend, params = hit
    return Decision(backend, params, True, "table")
