"""Tile/grid autotuner for the order-statistics kernels.

For every registered aggregator's Pallas form (plus the fused
``median_mad_dcq`` pass and the masked serving rules) this sweeps the
kernel's static knobs — coordinate tile width ``tile``, in-kernel
coordinate-loop depth ``inner`` and bisection trip count ``n_bisect`` —
over a grid of ``(B, m, p)`` problem shapes, times each candidate
against the jnp reference on the CURRENT platform, and records the
measured winners into a :class:`repro.agg.dispatch.DispatchTable`:

    repro-agg-tune --out src/repro/agg/tables/cpu.json

Candidates must pass a correctness gate (99.9th-percentile abs error vs
the reference oracle below ``tol``, see :func:`_gate_err` for why not
the max) before their timing counts — a fast-but-wrong ``n_bisect`` can
never enter the table. Every recorded tuning parameter
is an int: the knobs feed ``jax.jit`` static arguments, where float or
unhashable keys silently retrace per call (the repro.analyze
retrace-hazard rule polices exactly this).

Timings use an injectable ``timer`` (default ``time.perf_counter``) so
tests can pin a deterministic clock; with a fixed clock and fixed seeds
the emitted table is byte-stable.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.agg import (aggregate_masked, get_aggregator, has_pallas,
                       median_mad_dcq, ostat_pallas, registered)
from repro.agg.dispatch import SCHEMA, TABLE_DIR, DispatchTable
from repro.agg.kernel import N_BISECT, clamp_block

__all__ = ["DEFAULT_SHAPES", "FAST_SHAPES", "autotune", "main"]

#: (B, m, p) problem shapes tuned by default: the sweep engine's regime
#: (many tiny problems), protocol-scale single problems, and the mid-/
#: large-p gradient regimes the high-dimensional DP line needs.
DEFAULT_SHAPES = (
    (320, 8, 10),        # sweep hot loop: B scenarios x (m, p) tiles
    (1, 8, 10),          # one protocol round at paper scale
    (8, 8, 4096),        # mid-p: a small grid of gradient-sized problems
    (1, 8, 4096),
    (1, 8, 262144),      # large-p: one model-gradient-sized problem
)

#: reduced shapes for CI / nightly smoke runs
FAST_SHAPES = (
    (96, 8, 10),
    (4, 8, 1024),
    (1, 8, 16384),
)

#: masked (serving) capacities tuned per payload width p
MASKED_CAPACITY = 256

_TILES = (256, 512, 1024, 2048)
_INNERS = (1, 4)
_N_BISECTS = (32, 60)


def _pallas_candidates(op: str, m: int, p: int):
    """Deduplicated (tile, inner, n_bisect) candidates for one problem.
    Tiles/inners are pre-clamped to the VMEM budget and the coordinate
    count; ops that never bisect (mean) collapse the n_bisect axis."""
    seen, out = set(), []
    n_bisects = (N_BISECT,) if op == "mean" else _N_BISECTS
    for tile in _TILES:
        for inner in _INNERS:
            ct, ci = clamp_block(m, p, tile, inner)
            for nb in n_bisects:
                key = (ct, ci, nb)
                if key not in seen:
                    seen.add(key)
                    out.append(key)
    return out


def _steady(fn, reps: int, timer) -> float:
    """Steady-state seconds per call: one warmup (compile), then the mean
    of ``reps`` timed calls."""
    jax.block_until_ready(fn())
    t0 = timer()
    r = None
    for _ in range(reps):
        r = fn()
    jax.block_until_ready(r)
    return (timer() - t0) / reps


def _gate_err(a, b) -> float:
    """Correctness-gate error: the 99.9th-percentile abs deviation.

    The CQ estimators are sums of indicators I(v <= med + scale*Delta_k):
    when a value sits within f32 rounding of a knot threshold, last-ulp
    differences between backends flip one indicator and the estimate
    jumps by ~scale/(m*psi_sum) at that single coordinate — an inherent
    discontinuity, not a kernel bug, and at p~1e5+ some coordinate will
    always tie. A genuinely wrong candidate (under-resolved bisection,
    bad tiling) is off at EVERY coordinate, so gating the 99.9th
    percentile rejects it while tolerating isolated tie flips."""
    d = jnp.abs(jnp.asarray(a, jnp.float32) - jnp.asarray(b, jnp.float32))
    return float(jnp.quantile(d.reshape(-1), 0.999))


def _tune_op(table: DispatchTable, op: str, B: int, m: int, p: int, *,
             reps: int, timer, tol: float, log) -> None:
    """Measure reference vs every Pallas candidate for one (op, shape)."""
    is_fused = op == "median_mad_dcq"
    agg = None if is_fused else get_aggregator(op)
    key = jax.random.PRNGKey(0)
    v = jax.random.normal(key, (B, m, p), jnp.float32) * 2.0
    scale = None
    if agg is not None and agg.needs_scale:
        scale = jnp.abs(jax.random.normal(jax.random.PRNGKey(1),
                                          (B, p))) + 0.1

    if is_fused:
        def ref_call(vv=v):
            return median_mad_dcq(vv, backend="reference")
    else:
        ref = jax.jit(lambda vv, sc: agg.reference(
            vv, scale=sc, K=10, trim_beta=0.2, axis=-2))

        def ref_call(vv=v, sc=scale):
            return ref(vv, sc)

    oracle = ref_call()
    t_ref = _steady(ref_call, reps, timer)
    table.record(op, B, m, p, "reference", t_ref)

    best = None
    for tile, inner, nb in _pallas_candidates(op, m, p):
        def pal_call(tile=tile, inner=inner, nb=nb):
            return ostat_pallas(v, op, scale, K=10, trim_beta=0.2,
                                tile=tile, inner=inner, n_bisect=nb)
        out = pal_call()
        err = max(_gate_err(o, r) for o, r in zip(
            out if isinstance(out, tuple) else (out,),
            oracle if isinstance(oracle, tuple) else (oracle,)))
        if err > tol:
            log(f"    pallas tile={tile} inner={inner} n_bisect={nb}: "
                f"REJECTED err={err:.2e} > {tol:g}")
            continue
        t = _steady(pal_call, reps, timer)
        log(f"    pallas tile={tile} inner={inner} n_bisect={nb}: "
            f"{t * 1e3:.3f}ms (err {err:.2e})")
        if best is None or t < best[0]:
            best = (t, tile, inner, nb)
    if best is not None:
        t, tile, inner, nb = best
        table.record(op, B, m, p, "pallas", t,
                     tile=int(tile), inner=int(inner), n_bisect=int(nb))
    win = table.best(op, B, m, p)
    log(f"  {op} B={B} m={m} p={p}: reference={t_ref * 1e3:.3f}ms  "
        f"best={win[0] if win else '?'}")


def _tune_masked(table: DispatchTable, rule: str, C: int, p: int, *,
                 reps: int, timer, tol: float, log) -> None:
    """Measure the masked sort backend vs the sort-free bisect backend at
    one serving (capacity, p); recorded under op ``masked:<rule>``."""
    agg = get_aggregator(rule)
    v = jax.random.normal(jax.random.PRNGKey(2), (C, p), jnp.float32)
    scale = (jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (p,))) + 0.1
             if agg.needs_scale else None)
    fill = jnp.int32((3 * C) // 4)      # a partially-filled buffer

    def call(be):
        fn = jax.jit(lambda vv, ff: aggregate_masked(
            vv, ff, method=rule, scale=scale, backend=be))
        return lambda: fn(v, fill)

    sort_call = call("sort")
    oracle = sort_call()
    t_sort = _steady(sort_call, reps, timer)
    table.record(f"masked:{rule}", 1, C, p, "sort", t_sort)
    t_bis = None
    if agg.masked_bisect is not None:
        bis_call = call("bisect")
        err = _gate_err(bis_call(), oracle)
        if err <= tol:
            t_bis = _steady(bis_call, reps, timer)
            table.record(f"masked:{rule}", 1, C, p, "bisect", t_bis)
        else:
            log(f"    masked:{rule} bisect REJECTED err={err:.2e}")
    log(f"  masked:{rule} C={C} p={p}: sort={t_sort * 1e3:.3f}ms  "
        + (f"bisect={t_bis * 1e3:.3f}ms" if t_bis is not None
           else "bisect=n/a"))


def autotune(ops=None, shapes=DEFAULT_SHAPES, *, platform=None,
             reps: int = 3, timer=time.perf_counter, tol: float = 5e-4,
             include_masked: bool = True, masked_capacity=MASKED_CAPACITY,
             table: DispatchTable = None, verbose: bool = True
             ) -> DispatchTable:
    """Measure every backend over ``ops`` x ``shapes`` and return the
    populated dispatch table (extending ``table`` when given).

    Deterministic given a deterministic ``timer``: ops and shapes are
    visited in a fixed order with fixed PRNG seeds, so tests can pin a
    stub clock and assert byte-stable output.
    """
    log = print if verbose else (lambda *_a, **_k: None)
    if platform is None:
        platform = jax.default_backend()
    if ops is None:
        ops = [n for n in registered() if has_pallas(n)]
        ops.append("median_mad_dcq")
    if table is None:
        table = DispatchTable(platform, meta={
            "generated_by": "repro.agg.autotune", "jax": jax.__version__,
            "reps": reps})
    for op in ops:
        for B, m, p in shapes:
            _tune_op(table, op, B, m, p, reps=reps, timer=timer, tol=tol,
                     log=log)
    if include_masked:
        masked_rules = [n for n in registered()
                        if get_aggregator(n).masked is not None]
        for rule in masked_rules:
            for p in sorted({s[2] for s in shapes}):
                _tune_masked(table, rule, masked_capacity, p, reps=reps,
                             timer=timer, tol=tol, log=log)
    return table


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Autotune repro.agg kernels and write the measured "
                    "backend-dispatch table for this platform.")
    ap.add_argument("--out", default=None,
                    help="output table path (default: the committed "
                         "package table for this platform, "
                         f"{TABLE_DIR}/<platform>.json)")
    ap.add_argument("--fast", action="store_true",
                    help="reduced shape grid (CI / nightly smoke)")
    ap.add_argument("--ops", nargs="*", default=None,
                    help="subset of ops to tune (default: every "
                         "registered Pallas aggregator + the fused pass)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--no-masked", action="store_true",
                    help="skip the masked (serving) backends")
    args = ap.parse_args(argv)

    platform = jax.default_backend()
    shapes = FAST_SHAPES if args.fast else DEFAULT_SHAPES
    print(f"== repro-agg-tune: platform={platform} jax={jax.__version__} "
          f"schema={SCHEMA} ==")
    table = autotune(ops=args.ops, shapes=shapes, platform=platform,
                     reps=args.reps, include_masked=not args.no_masked)
    out = args.out if args.out else TABLE_DIR / f"{platform}.json"
    path = table.save(out)
    print(f"wrote {len(table.entries)} entries -> {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
