"""Masked partial-fill aggregation: the serving subsystem's numerics.

A streaming ring buffer (repro.serve) holds a fixed-capacity ``(C, p)``
stack whose first ``fill`` rows are valid machine updates and whose tail
is stale garbage. A continuously-batched compiled step must aggregate the
valid prefix under ONE trace — ``fill`` is a traced scalar, never a shape
— and a half-full buffer must aggregate to EXACTLY what the dense
unpadded ``(fill, p)`` batch would: stragglers may shrink the batch, they
must never perturb the estimate.

That exactness is engineered, not assumed. XLA lowers a row-sum to a
reduction tree whose shape depends on the row count (and on the SIMD lane
layout), so ``sum(pad_with_zeros(x))`` is NOT bit-equal to ``sum(x)`` in
float arithmetic. Two primitives restore bit-equality:

  * **block-sequential sums** — every machine-axis sum runs as a
    sequential ``lax.scan`` over fixed ``BLOCK``-row chunks (invalid rows
    zeroed, capacity zero-padded to a block multiple, never fewer than
    two blocks so XLA cannot inline a trip-count-1 loop into a
    differently-fused graph). Both the buffered and the dense batch
    reduce with byte-identical per-block HLO; the buffer's extra blocks
    are all-zero and add exactly 0.0f;
  * **parity-balanced median padding** — invalid slots are filled with a
    balanced split of -inf/+inf so the valid entries keep their central
    rank. ``jnp.median`` interpolates iff the row count is even, so the
    kernel evaluates a ``C``-row and a ``(C+1)``-row variant and selects
    the one matching the parity of ``fill`` — making the masked median
    bit-identical to ``jnp.median(values[:fill])`` itself, at every fill.

Contract (asserted per aggregator in tests/test_serve.py): for every
registered rule, ``masked(buffer, fill=k)`` == ``masked(buffer[:k],
fill=k)`` byte-for-byte; the ``median`` rule is additionally bit-equal to
the registry reference, and every rule matches the registry reference to
reduction-order rounding (~1e-6), exactly at full fill of a minimal
buffer. Sum-based rules differ from ``repro.agg.reference`` only in
summation ORDER (documented here, tested there).

These kernels take the machine axis at 0 and a 2-D ``(C, p)`` payload —
``repro.agg.aggregate_masked`` and the transport wire flatten pytree
leaves to that layout, exactly as the Pallas path does.

Two masked BACKENDS exist for the order-statistics rules (median / dcq /
dcq_mad):

  * **sort** — the forms above (``jnp.median`` with parity-balanced
    padding): bit-equal to the dense reference, O(C log C) per column;
  * **bisect** — the ``*_bisect`` forms: the Pallas kernel's rank-count
    bisection transplanted to the masked regime (invalid rows excluded
    from every count/min/max). Sort-free — O(n_bisect * C * p) full-width
    comparisons — which is the winning complexity at serving scale
    (large p, big capacity). Fill-invariance holds for the same reason it
    holds densely: indicator counts are small-integer float sums (exact
    in any reduction order), and min/max with ±inf padding are exact, so
    ``bisect(buffer, fill=k)`` is byte-identical to
    ``bisect(buffer[:k], fill=k)``. The bisect median agrees with
    ``jnp.median`` only to bisection resolution (~fp32 eps), NOT
    bit-exactly — which is why it is a separate dispatchable backend
    (repro.agg.dispatch, op key ``masked:<rule>``) and not a swap-in.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.stats import norm

from repro.agg.kernel import N_BISECT
from repro.agg.reference import (MAD_EPS, MAD_SIGMA, quantile_knots,
                                 quantile_levels)

__all__ = ["BLOCK", "blocked_sum", "masked_mean", "masked_median",
           "masked_trimmed", "masked_geomedian", "masked_dcq",
           "masked_dcq_mad", "masked_median_bisect", "masked_dcq_bisect",
           "masked_dcq_mad_bisect"]

#: rows per sequential sum chunk. Part of the numeric contract: both the
#: buffered and the dense side chunk identically, so the per-block reduce
#: trees coincide. 128 keeps the scan short (capacity 16384 -> 128 steps)
#: while each block sum stays a wide vectorized reduce.
BLOCK = 128


def _blocked(values, fill, row_axis: int = 0):
    """Sum over ``row_axis`` keeping rows ``< fill``: sequential scan over
    fixed-size blocks (see module docstring for why this shape)."""
    m = values.shape[row_axis]
    n_blocks = max(-(-m // BLOCK), 2)     # >= 2: no trip-count-1 while loop
    pad = n_blocks * BLOCK - m
    if pad:
        pad_shape = list(values.shape)
        pad_shape[row_axis] = pad
        values = jnp.concatenate(
            [values, jnp.zeros(pad_shape, values.dtype)], axis=row_axis)
    mask_shape = [1] * values.ndim
    mask_shape[row_axis] = n_blocks * BLOCK
    mask = (jnp.arange(n_blocks * BLOCK) < fill).reshape(mask_shape)
    v = jnp.moveaxis(jnp.where(mask, values, 0), row_axis, 0)
    blocks = v.reshape((n_blocks, BLOCK) + v.shape[1:])

    def body(acc, blk):
        return acc + jnp.sum(blk, axis=0), None

    acc, _ = jax.lax.scan(body, jnp.zeros(v.shape[1:], values.dtype), blocks)
    return acc


def blocked_sum(values, fill):
    """Masked machine-axis sum ``values[:fill].sum(0)`` with fill-invariant
    bytes (leading axis; ``fill`` may be traced)."""
    return _blocked(values, fill, row_axis=0)


def _fill_f(fill, dtype):
    return jnp.asarray(fill).astype(dtype)


def _padded_median(values, fill, rows: int):
    """Median over ``rows`` slots: valid prefix, then a balanced -inf/+inf
    split. Exact iff ``rows - fill`` is even (the valid entries stay
    centred and the interpolation weight matches the dense batch's)."""
    m, p = values.shape
    if rows > m:
        values = jnp.concatenate(
            [values, jnp.zeros((rows - m, p), values.dtype)])
    i = jnp.arange(rows)[:, None]
    lo = fill + (rows - fill) // 2
    padded = jnp.where(i < fill, values,
                       jnp.where(i < lo, -jnp.inf, jnp.inf))
    return jnp.median(padded, axis=0)


def masked_median(values, fill, *, scale=None, K=10, trim_beta=0.2):
    """Bit-identical to ``jnp.median(values[:fill], axis=0)`` at every
    fill: dual C/(C+1)-row padded medians, selected by fill parity."""
    m = values.shape[0]
    even = _padded_median(values, fill, m)
    odd = _padded_median(values, fill, m + 1)
    return jnp.where((m - fill) % 2 == 0, even, odd)


def masked_mean(values, fill, *, scale=None, K=10, trim_beta=0.2):
    return blocked_sum(values, fill) * (1.0 / _fill_f(fill, values.dtype))


def masked_trimmed(values, fill, *, scale=None, K=10, trim_beta=0.2):
    """beta-trimmed mean of the valid prefix: +inf fill sinks invalid rows
    to the tail of the sort (comparison-only, so the valid sorted prefix
    is bit-equal to sorting the dense batch), then a window sum.

    The trim count ``floor(beta * fill)`` is computed in the payload
    dtype (fill is traced); for beta where ``beta * m`` lands exactly on
    an integer this can differ by one row from the reference's host-side
    ``int(beta * m)`` — the registered default 0.2 never does for f32.
    Any ``beta < 0.5`` keeps the window non-empty at every fill >= 1.
    """
    if not trim_beta < 0.5:
        raise ValueError(f"trim fraction {trim_beta} too large: the "
                         "masked window must stay non-empty at fill 1")
    m = values.shape[0]
    i = jnp.arange(m)[:, None]
    srt = jnp.sort(jnp.where(i < fill, values, jnp.inf), axis=0)
    g = jnp.floor(trim_beta * _fill_f(fill, values.dtype)).astype(jnp.int32)
    window = (i >= g) & (i < fill - g)
    kept = jnp.where(window, srt, 0.0)
    total = blocked_sum(kept, jnp.int32(m))       # window already zeroed
    return total * (1.0 / (fill - 2 * g).astype(values.dtype))


def masked_geomedian(values, fill, *, scale=None, K=10, trim_beta=0.2,
                     iters: int = 50, eps: float = 1e-8):
    """Weiszfeld over the valid prefix: invalid rows are zeroed BEFORE the
    distance pass (0 * garbage would resurrect NaNs) and their weights
    forced to 0, so they never pull the iterate."""
    m = values.shape[0]
    valid = jnp.arange(m) < fill
    flat = jnp.where(valid[:, None], values.reshape(m, -1), 0.0)

    def step(z, _):
        d = jnp.linalg.norm(flat - z[None], axis=1)
        w = jnp.where(valid, 1.0 / jnp.maximum(d, eps), 0.0)
        num = blocked_sum(w[:, None] * flat, jnp.int32(m))
        return num / blocked_sum(w, jnp.int32(m)), None

    z0 = masked_median(flat, fill)
    z, _ = jax.lax.scan(step, z0, None, length=iters)
    return z.reshape(values.shape[1:])


def _cq_correct_masked(values, fill, med, scale, K):
    """Composite-quantile correction around a given median anchor over the
    valid prefix (block-sequential indicator sums; the machine count in
    the denominator is the traced fill)."""
    delta = quantile_knots(K).astype(values.dtype)
    kappa = quantile_levels(K).astype(values.dtype)
    thr = med[None] + scale[None] * delta.reshape((K,) + (1,) * med.ndim)
    ind = (values[None, :] <= thr[:, None]).astype(values.dtype)  # (K, C, p)
    contrib = ind - kappa.reshape((K, 1, 1))
    s = jnp.sum(_blocked(contrib, fill, row_axis=1), axis=0)
    denom = _fill_f(fill, values.dtype) \
        * norm.pdf(delta).sum().astype(values.dtype)
    return med - scale * s / denom


def masked_dcq(values, fill, *, scale=None, K=10, trim_beta=0.2):
    """DCQ with oracle scale over the valid prefix (reference.dcq with
    masked median anchor and block-sequential indicator sums)."""
    return _cq_correct_masked(values, fill, masked_median(values, fill),
                              scale, K)


def masked_dcq_mad(values, fill, *, scale=None, K=10, trim_beta=0.2):
    """MAD-self-calibrated DCQ (the gradient/serving wire carries no
    variance estimates); f32 like the reference and the Pallas kernel."""
    values = values.astype(jnp.float32)
    med = masked_median(values, fill)
    mad = masked_median(jnp.abs(values - med[None]), fill)
    mad_scale = MAD_SIGMA * mad + MAD_EPS
    return masked_dcq(values, fill, scale=mad_scale, K=K)


# ------------------------------------------------- sort-free bisect backend

def _masked_kth(values, fill, ks, n_bisect: int = N_BISECT):
    """Rank-count bisection k-th order statistics over the valid prefix.

    values: (C, p); fill: traced valid-row count; ks: (q,) traced
    0-indexed ranks (each < fill). Returns (q, p), each row the
    ks[i]-smallest per column among the first ``fill`` rows, to
    ``n_bisect``-halving resolution. Every operation is exact and
    independent of the stale tail (counts are small-integer float sums;
    min/max see ±inf in invalid slots), so the result is byte-identical
    to running the same bisection on the dense ``values[:fill]``.
    """
    C = values.shape[0]
    valid = (jnp.arange(C) < fill)[:, None]
    lo = jnp.min(jnp.where(valid, values, jnp.inf), axis=0)     # (p,)
    hi = jnp.max(jnp.where(valid, values, -jnp.inf), axis=0)
    q = ks.shape[0]
    lo = jnp.broadcast_to(lo, (q,) + lo.shape)
    hi = jnp.broadcast_to(hi, (q,) + hi.shape)
    # counts in f32 regardless of payload dtype: a bf16 count of a
    # 16384-slot buffer would round and return the wrong rank
    kf = ks.astype(jnp.float32)[:, None]

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)                                   # (q, p)
        le = (values[None] <= mid[:, None]) & valid[None]       # (q, C, p)
        cnt = jnp.sum(le.astype(jnp.float32), axis=1)
        go_right = cnt <= kf
        return jnp.where(go_right, mid, lo), jnp.where(go_right, hi, mid)

    lo, hi = jax.lax.fori_loop(0, n_bisect, body, (lo, hi))
    return hi


def masked_median_bisect(values, fill, *, scale=None, K=10, trim_beta=0.2,
                         n_bisect: int = N_BISECT):
    """Sort-free masked median: one dual-rank bisection pass instead of
    the dual parity-padded sorts. Matches ``masked_median`` to bisection
    resolution (NOT bit-exactly); fill-invariant byte-for-byte."""
    ks = jnp.stack([(fill - 1) // 2, fill // 2]).astype(jnp.int32)
    two = _masked_kth(values, fill, ks, n_bisect)
    return jnp.where(fill % 2 == 1, two[0], 0.5 * (two[0] + two[1]))


def masked_dcq_bisect(values, fill, *, scale=None, K=10, trim_beta=0.2,
                      n_bisect: int = N_BISECT):
    """DCQ with oracle scale, bisect median anchor: fully sort-free (the
    CQ correction was already rank-counting)."""
    med = masked_median_bisect(values, fill, n_bisect=n_bisect)
    return _cq_correct_masked(values, fill, med, scale, K)


def masked_dcq_mad_bisect(values, fill, *, scale=None, K=10, trim_beta=0.2,
                          n_bisect: int = N_BISECT):
    """MAD-self-calibrated DCQ, fully sort-free: both medians by
    rank-count bisection, then the indicator-sum correction."""
    values = values.astype(jnp.float32)
    med = masked_median_bisect(values, fill, n_bisect=n_bisect)
    mad = masked_median_bisect(jnp.abs(values - med[None]), fill,
                               n_bisect=n_bisect)
    mad_scale = MAD_SIGMA * mad + MAD_EPS
    return _cq_correct_masked(values, fill, med, mad_scale, K)
