"""``repro.agg`` — the unified robust-aggregation subsystem.

Every center-side aggregation in this repo routes through here: the
paper's Algorithm 1 rounds (core/protocol.py), the gradient aggregator
(dist/grad_agg.py), the SPMD collectives (dist/collectives.py), the
comparison baselines (core/baselines.py) and the sweep/benchmark layers.

Three pieces:

  * :mod:`repro.agg.registry`  — ``register("median"|"trimmed"|...)``;
    an :class:`Aggregator` bundles a jnp reference impl, a Pallas impl
    and a declared batching rule. Adding an aggregator is a one-file
    registry entry that is immediately dispatchable, sweepable and
    benchmarkable.
  * :mod:`repro.agg.reference` — the pure-jnp oracles (median, trimmed
    mean, geometric median, DCQ and its efficiency theory, MAD-scaled
    DCQ, the fused median+MAD+DCQ pass, the untrusted-center
    median-deviation variance).
  * :mod:`repro.agg.kernel`    — ONE generalized Pallas bisection
    order-statistics kernel computing k-th statistic / median / MAD /
    trimmed mean / DCQ from a shared rank-counting core, with leading
    batch axes mapped onto the grid.

Backend selection: ``backend=None`` ("auto") consults the MEASURED
dispatch table (:mod:`repro.agg.dispatch`): the autotuner
(:mod:`repro.agg.autotune`, ``repro-agg-tune``) times every backend per
(op, shape-bucket, platform) and records the winner plus its kernel
tuning parameters; auto dispatch looks the current shape's bucket up and
runs the recorded best. Unmeasured buckets fall back to the reference
oracle; platforms with no table at all fall back to the historical
heuristic (Pallas on TPU, reference elsewhere — off-TPU numbers stay
bit-identical to the historical sort-based path). ``backend="pallas"``
forces the kernel (interpret mode off-TPU); ``backend="reference"``
forces the oracle.

Migration note: ``core/robust_agg.py``, ``core/dcq.py``,
``kernels/dcq.py`` and ``kernels/dcq_ref.py`` are now thin shims over
this package; import from ``repro.agg`` directly in new code.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.agg import dispatch, kernel, masked, reference
from repro.agg.dispatch import DispatchTable
from repro.agg.kernel import OPS, cq_constants, dcq_pallas, ostat_pallas
from repro.agg.reference import (ARE_MEDIAN, are_dcq, d_k, dcq, dcq_jit,
                                 dcq_mad_reference, dcq_with_sigma,
                                 geometric_median_agg, mean_agg, median_agg,
                                 median_deviation_variance,
                                 median_mad_dcq_reference, quantile_knots,
                                 quantile_levels, trimmed_mean_agg)
from repro.agg.registry import (Aggregator, get_aggregator, has_masked,
                                has_pallas, register, registered)

__all__ = [
    "Aggregator", "register", "get_aggregator", "registered", "has_pallas",
    "has_masked", "dispatch", "DispatchTable",
    "aggregate", "aggregate_batched", "aggregate_masked", "median_mad_dcq",
    "median_deviation_variance",
    "ostat_pallas", "dcq_pallas", "OPS", "cq_constants",
    "dcq", "dcq_with_sigma", "dcq_jit", "dcq_mad_reference",
    "median_mad_dcq_reference", "quantile_levels", "quantile_knots",
    "d_k", "are_dcq", "ARE_MEDIAN",
    "mean_agg", "median_agg", "trimmed_mean_agg", "geometric_median_agg",
    "kernel", "masked", "reference",
]


# ----------------------------------------------------- built-in aggregators
#
# reference signature: (values, *, scale, K, trim_beta, axis) -> aggregate
# pallas signature:    (values, *, scale, K, trim_beta, tile, inner,
#                      n_bisect, interpret) with machine axis at -2,
#                      leading dims batch.

def _pallas_op(op):
    def run(values, *, scale=None, K=10, trim_beta=0.2, tile=512,
            inner=1, n_bisect=kernel.N_BISECT, interpret=None):
        return ostat_pallas(values, op, scale, K=K, trim_beta=trim_beta,
                            tile=tile, inner=inner, n_bisect=n_bisect,
                            interpret=interpret)
    return run


register(Aggregator(
    name="mean",
    reference=lambda values, *, scale=None, K=10, trim_beta=0.2, axis=0:
        reference.mean_agg(values, axis=axis),
    pallas=_pallas_op("mean"), masked=masked.masked_mean,
    doc="non-robust average (the efficiency yardstick)"))

register(Aggregator(
    name="median",
    reference=lambda values, *, scale=None, K=10, trim_beta=0.2, axis=0:
        reference.median_agg(values, axis=axis),
    pallas=_pallas_op("median"), masked=masked.masked_median,
    masked_bisect=masked.masked_median_bisect,
    doc="coordinate-wise median (Yin et al. 2018)"))

register(Aggregator(
    name="trimmed",
    reference=lambda values, *, scale=None, K=10, trim_beta=0.2, axis=0:
        reference.trimmed_mean_agg(values, beta=trim_beta, axis=axis),
    pallas=_pallas_op("trimmed"), masked=masked.masked_trimmed,
    doc="coordinate-wise beta-trimmed mean (Yin et al. 2018/19)"))

register(Aggregator(
    name="geomedian",
    reference=lambda values, *, scale=None, K=10, trim_beta=0.2, axis=0:
        reference.geometric_median_agg(values, axis=axis),
    pallas=None, batching="vmap", coordinatewise=False,
    masked=masked.masked_geomedian,
    doc="geometric median via Weiszfeld (Chen et al. 2017); couples "
        "coordinates, so no Pallas form and payload must stay replicated"))

register(Aggregator(
    name="dcq",
    reference=lambda values, *, scale=None, K=10, trim_beta=0.2, axis=0:
        reference.dcq(values, scale, K=K, axis=axis),
    pallas=_pallas_op("dcq"), needs_scale=True, masked=masked.masked_dcq,
    masked_bisect=masked.masked_dcq_bisect,
    doc="the paper's composite-quantile estimator with oracle scale "
        "(§3/§4.4)"))

register(Aggregator(
    name="dcq_mad",
    reference=lambda values, *, scale=None, K=10, trim_beta=0.2, axis=0:
        reference.dcq_mad_reference(values, K=K, axis=axis),
    pallas=_pallas_op("dcq_mad"), masked=masked.masked_dcq_mad,
    masked_bisect=masked.masked_dcq_mad_bisect,
    doc="MAD-self-calibrated DCQ (the gradient-aggregation path, no "
        "transmitted variance)"))


# ------------------------------------------------------------ dispatch API

def _pick_backend(agg: Aggregator, backend: Optional[str],
                  shape=None) -> "tuple[str, dict]":
    """Resolve the backend for one problem; returns (backend, params).

    ``backend=None`` with a known ``shape=(B, m, p)`` consults the
    measured dispatch table (repro.agg.dispatch); without a shape (or
    without a table for this platform) the historical platform heuristic
    applies. ``params`` are the table's tuned kernel knobs (tile / inner
    / n_bisect), empty for reference or forced backends.
    """
    params: dict = {}
    if backend is None:
        if agg.pallas is not None and shape is not None:
            dec = dispatch.decide(agg.name, *shape)
            backend, params = dec.backend, dict(dec.params)
        else:
            backend = "pallas" if jax.default_backend() == "tpu" \
                else "reference"
    if backend == "pallas" and agg.pallas is None:
        backend = "reference"       # e.g. geomedian: no kernel form
        params = {}
    if backend not in ("pallas", "reference"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend, params


def aggregate(values, method: str = "dcq", scale=None, K: int = 10,
              trim_beta: float = 0.2, axis: int = 0,
              backend: Optional[str] = None, interpret=None):
    """Aggregate ``values`` over its machine axis with a registered rule.

    The dispatch entry used by the protocol, the gradient aggregator and
    the baselines. ``backend=None`` consults the measured dispatch table
    for this (shape bucket, platform) — see :mod:`repro.agg.dispatch` —
    running the recorded best backend with its tuned kernel parameters;
    unmeasured shapes fall back to the reference oracle. Returns
    ``values.shape`` without ``axis``.
    """
    agg = get_aggregator(method)
    if agg.needs_scale and scale is None:
        raise ValueError(f"{method!r} needs a per-coordinate scale")
    vals = jnp.moveaxis(values, axis, 0)          # (m, *payload)
    payload = vals.shape[1:]
    p = 1
    for d in payload:
        p *= d
    be, params = _pick_backend(agg, backend, shape=(1, vals.shape[0], p))
    if be == "reference":
        return agg.reference(values, scale=scale, K=K, trim_beta=trim_beta,
                             axis=axis)
    flat = vals.reshape(vals.shape[0], -1) if payload else vals[:, None]
    sc = None
    if scale is not None:
        sc = jnp.broadcast_to(scale, payload).reshape(-1) if payload \
            else jnp.asarray(scale).reshape(1)
    out = agg.pallas(flat, scale=sc, K=K, trim_beta=trim_beta,
                     interpret=interpret, **params)
    return out.reshape(payload).astype(values.dtype)


def aggregate_masked(values, fill, method: str = "dcq", scale=None,
                     K: int = 10, trim_beta: float = 0.2, axis: int = 0,
                     backend: Optional[str] = None):
    """Partial-fill aggregation over a fixed-capacity buffer: reduce the
    first ``fill`` rows of the machine axis, ignoring the stale tail.

    ``fill`` is a (traceable) scalar, never a shape — the serving step
    compiles ONCE per buffer capacity and every fill level reuses the
    executable. The result is byte-identical to calling this same entry
    on the dense unpadded ``values[:fill]`` batch (the fill-invariance
    contract, see :mod:`repro.agg.masked`).

    ``backend`` selects between the masked backends: ``"sort"`` (the
    contractual forms — the ``median`` rule is additionally bit-equal to
    the registry reference at every fill, sum-based rules match it up to
    float summation order) and ``"bisect"`` (sort-free rank counting,
    bisection resolution, the large-p serving path). ``backend=None``
    consults the measured dispatch table under op ``masked:<method>`` at
    trace time — the streaming service inherits the fastest measured
    backend per (capacity, p) bucket — and falls back to ``"sort"``.
    """
    agg = get_aggregator(method)
    if agg.masked is None:
        raise ValueError(f"{method!r} has no masked partial-fill form; "
                         f"servable rules: "
                         f"{[n for n in registered() if has_masked(n)]}")
    if agg.needs_scale and scale is None:
        raise ValueError(f"{method!r} needs a per-coordinate scale")
    vals = jnp.moveaxis(values, axis, 0)           # (capacity, *payload)
    payload = vals.shape[1:]
    p = 1
    for d in payload:
        p *= d
    if backend is None:
        dec = dispatch.decide(f"masked:{method}", 1, vals.shape[0], p)
        backend = dec.backend
        if backend == "bisect" and agg.masked_bisect is None:
            backend = "sort"
    if backend == "bisect":
        if agg.masked_bisect is None:
            servable = [n for n in registered()
                        if get_aggregator(n).masked_bisect is not None]
            raise ValueError(f"{method!r} has no sort-free masked form; "
                             f"bisect rules: {servable}")
        fn = agg.masked_bisect
    elif backend == "sort":
        fn = agg.masked
    else:
        raise ValueError(f"unknown masked backend {backend!r} "
                         "(one of 'sort', 'bisect')")
    flat = vals.reshape(vals.shape[0], -1) if payload else vals[:, None]
    sc = None
    if scale is not None:
        sc = jnp.broadcast_to(jnp.asarray(scale, vals.dtype),
                              payload).reshape(-1) if payload \
            else jnp.asarray(scale, vals.dtype).reshape(1)
    out = fn(flat, fill, scale=sc, K=K, trim_beta=trim_beta)
    return out.reshape(payload).astype(values.dtype)


def aggregate_batched(values, method: str = "dcq", scale=None, K: int = 10,
                      trim_beta: float = 0.2,
                      backend: Optional[str] = None, interpret=None):
    """Batched aggregation ``(*B, m, p) -> (*B, p)`` (machine axis at -2).

    This is each aggregator's declared batching rule made explicit: grid
    aggregators push the whole batch through ONE fused Pallas launch
    (leading axes on the grid); ``"vmap"`` aggregators (geomedian) batch
    via an outer vmap of the reference. On the reference backend the
    coordinate-wise rules batch natively via ``axis=-2`` reductions.
    """
    agg = get_aggregator(method)
    if agg.needs_scale and scale is None:
        raise ValueError(f"{method!r} needs a per-coordinate scale")
    if values.ndim < 2:
        raise ValueError(f"need (*batch, m, p), got {values.shape}")
    bn = 1
    for d in values.shape[:-2]:
        bn *= d
    be, params = _pick_backend(agg, backend,
                               shape=(bn,) + values.shape[-2:])
    if be == "pallas" and agg.batching == "grid":
        out = agg.pallas(values, scale=scale, K=K, trim_beta=trim_beta,
                         interpret=interpret, **params)
        return out.astype(values.dtype)
    if agg.batching == "vmap" and values.ndim > 2:
        inner = functools.partial(aggregate_batched, method=method,
                                  scale=scale, K=K, trim_beta=trim_beta,
                                  backend=backend, interpret=interpret)
        return jax.vmap(inner)(values)
    return agg.reference(values, scale=scale, K=K, trim_beta=trim_beta,
                         axis=-2)


def median_mad_dcq(values, K: int = 10, backend: Optional[str] = None,
                   interpret=None):
    """Fused single-pass ``(median, raw MAD, MAD-scaled DCQ)`` over the
    machine axis at -2 (leading dims batch). The MAD-scaled gradient path
    uses all three: anchor, scale (robust variance = (1.4826*mad)^2) and
    the sharpened estimate — one resident tile instead of three passes.
    ``backend=None`` consults the dispatch table (op "median_mad_dcq")."""
    params: dict = {}
    if backend is None:
        if values.ndim >= 2:
            bn = 1
            for d in values.shape[:-2]:
                bn *= d
            dec = dispatch.decide("median_mad_dcq", bn,
                                  *values.shape[-2:])
            backend, params = dec.backend, dict(dec.params)
        else:
            backend = "pallas" if jax.default_backend() == "tpu" \
                else "reference"
    if backend not in ("pallas", "reference"):
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "pallas":
        return ostat_pallas(values, "median_mad_dcq", K=K,
                            interpret=interpret, **params)
    return reference.median_mad_dcq_reference(values, K=K, axis=-2)
