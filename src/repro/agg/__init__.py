"""``repro.agg`` — the unified robust-aggregation subsystem.

Every center-side aggregation in this repo routes through here: the
paper's Algorithm 1 rounds (core/protocol.py), the gradient aggregator
(dist/grad_agg.py), the SPMD collectives (dist/collectives.py), the
comparison baselines (core/baselines.py) and the sweep/benchmark layers.

Three pieces:

  * :mod:`repro.agg.registry`  — ``register("median"|"trimmed"|...)``;
    an :class:`Aggregator` bundles a jnp reference impl, a Pallas impl
    and a declared batching rule. Adding an aggregator is a one-file
    registry entry that is immediately dispatchable, sweepable and
    benchmarkable.
  * :mod:`repro.agg.reference` — the pure-jnp oracles (median, trimmed
    mean, geometric median, DCQ and its efficiency theory, MAD-scaled
    DCQ, the fused median+MAD+DCQ pass, the untrusted-center
    median-deviation variance).
  * :mod:`repro.agg.kernel`    — ONE generalized Pallas bisection
    order-statistics kernel computing k-th statistic / median / MAD /
    trimmed mean / DCQ from a shared rank-counting core, with leading
    batch axes mapped onto the grid.

Backend selection: ``backend=None`` ("auto") runs the Pallas kernel
natively on TPU and the jnp reference elsewhere — off-TPU numbers are
bit-identical to the historical sort-based path. ``backend="pallas"``
forces the kernel (interpret mode off-TPU); ``backend="reference"``
forces the oracle.

Migration note: ``core/robust_agg.py``, ``core/dcq.py``,
``kernels/dcq.py`` and ``kernels/dcq_ref.py`` are now thin shims over
this package; import from ``repro.agg`` directly in new code.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.agg import kernel, masked, reference
from repro.agg.kernel import OPS, cq_constants, dcq_pallas, ostat_pallas
from repro.agg.reference import (ARE_MEDIAN, are_dcq, d_k, dcq, dcq_jit,
                                 dcq_mad_reference, dcq_with_sigma,
                                 geometric_median_agg, mean_agg, median_agg,
                                 median_deviation_variance,
                                 median_mad_dcq_reference, quantile_knots,
                                 quantile_levels, trimmed_mean_agg)
from repro.agg.registry import (Aggregator, get_aggregator, has_masked,
                                has_pallas, register, registered)

__all__ = [
    "Aggregator", "register", "get_aggregator", "registered", "has_pallas",
    "has_masked",
    "aggregate", "aggregate_batched", "aggregate_masked", "median_mad_dcq",
    "median_deviation_variance",
    "ostat_pallas", "dcq_pallas", "OPS", "cq_constants",
    "dcq", "dcq_with_sigma", "dcq_jit", "dcq_mad_reference",
    "median_mad_dcq_reference", "quantile_levels", "quantile_knots",
    "d_k", "are_dcq", "ARE_MEDIAN",
    "mean_agg", "median_agg", "trimmed_mean_agg", "geometric_median_agg",
    "kernel", "masked", "reference",
]


# ----------------------------------------------------- built-in aggregators
#
# reference signature: (values, *, scale, K, trim_beta, axis) -> aggregate
# pallas signature:    (values, *, scale, K, trim_beta, tile, interpret)
#                      with machine axis at -2, leading dims batch.

def _pallas_op(op):
    def run(values, *, scale=None, K=10, trim_beta=0.2, tile=512,
            interpret=None):
        return ostat_pallas(values, op, scale, K=K, trim_beta=trim_beta,
                            tile=tile, interpret=interpret)
    return run


register(Aggregator(
    name="mean",
    reference=lambda values, *, scale=None, K=10, trim_beta=0.2, axis=0:
        reference.mean_agg(values, axis=axis),
    pallas=_pallas_op("mean"), masked=masked.masked_mean,
    doc="non-robust average (the efficiency yardstick)"))

register(Aggregator(
    name="median",
    reference=lambda values, *, scale=None, K=10, trim_beta=0.2, axis=0:
        reference.median_agg(values, axis=axis),
    pallas=_pallas_op("median"), masked=masked.masked_median,
    doc="coordinate-wise median (Yin et al. 2018)"))

register(Aggregator(
    name="trimmed",
    reference=lambda values, *, scale=None, K=10, trim_beta=0.2, axis=0:
        reference.trimmed_mean_agg(values, beta=trim_beta, axis=axis),
    pallas=_pallas_op("trimmed"), masked=masked.masked_trimmed,
    doc="coordinate-wise beta-trimmed mean (Yin et al. 2018/19)"))

register(Aggregator(
    name="geomedian",
    reference=lambda values, *, scale=None, K=10, trim_beta=0.2, axis=0:
        reference.geometric_median_agg(values, axis=axis),
    pallas=None, batching="vmap", coordinatewise=False,
    masked=masked.masked_geomedian,
    doc="geometric median via Weiszfeld (Chen et al. 2017); couples "
        "coordinates, so no Pallas form and payload must stay replicated"))

register(Aggregator(
    name="dcq",
    reference=lambda values, *, scale=None, K=10, trim_beta=0.2, axis=0:
        reference.dcq(values, scale, K=K, axis=axis),
    pallas=_pallas_op("dcq"), needs_scale=True, masked=masked.masked_dcq,
    doc="the paper's composite-quantile estimator with oracle scale "
        "(§3/§4.4)"))

register(Aggregator(
    name="dcq_mad",
    reference=lambda values, *, scale=None, K=10, trim_beta=0.2, axis=0:
        reference.dcq_mad_reference(values, K=K, axis=axis),
    pallas=_pallas_op("dcq_mad"), masked=masked.masked_dcq_mad,
    doc="MAD-self-calibrated DCQ (the gradient-aggregation path, no "
        "transmitted variance)"))


# ------------------------------------------------------------ dispatch API

def _pick_backend(agg: Aggregator, backend: Optional[str]) -> str:
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "reference"
    if backend == "pallas" and agg.pallas is None:
        backend = "reference"       # e.g. geomedian: no kernel form
    if backend not in ("pallas", "reference"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


def aggregate(values, method: str = "dcq", scale=None, K: int = 10,
              trim_beta: float = 0.2, axis: int = 0,
              backend: Optional[str] = None, interpret=None):
    """Aggregate ``values`` over its machine axis with a registered rule.

    The dispatch table used by the protocol, the gradient aggregator and
    the baselines. ``backend=None`` auto-selects (Pallas on TPU, jnp
    reference elsewhere). Returns ``values.shape`` without ``axis``.
    """
    agg = get_aggregator(method)
    if agg.needs_scale and scale is None:
        raise ValueError(f"{method!r} needs a per-coordinate scale")
    be = _pick_backend(agg, backend)
    if be == "reference":
        return agg.reference(values, scale=scale, K=K, trim_beta=trim_beta,
                             axis=axis)
    vals = jnp.moveaxis(values, axis, 0)          # (m, *payload)
    payload = vals.shape[1:]
    flat = vals.reshape(vals.shape[0], -1) if payload else vals[:, None]
    sc = None
    if scale is not None:
        sc = jnp.broadcast_to(scale, payload).reshape(-1) if payload \
            else jnp.asarray(scale).reshape(1)
    out = agg.pallas(flat, scale=sc, K=K, trim_beta=trim_beta,
                     interpret=interpret)
    return out.reshape(payload).astype(values.dtype)


def aggregate_masked(values, fill, method: str = "dcq", scale=None,
                     K: int = 10, trim_beta: float = 0.2, axis: int = 0):
    """Partial-fill aggregation over a fixed-capacity buffer: reduce the
    first ``fill`` rows of the machine axis, ignoring the stale tail.

    ``fill`` is a (traceable) scalar, never a shape — the serving step
    compiles ONCE per buffer capacity and every fill level reuses the
    executable. The result is byte-identical to calling this same entry
    on the dense unpadded ``values[:fill]`` batch (the fill-invariance
    contract, see :mod:`repro.agg.masked`); the ``median`` rule is
    additionally bit-equal to the registry reference at every fill, and
    the sum-based rules match it up to float summation order.
    """
    agg = get_aggregator(method)
    if agg.masked is None:
        raise ValueError(f"{method!r} has no masked partial-fill form; "
                         f"servable rules: "
                         f"{[n for n in registered() if has_masked(n)]}")
    if agg.needs_scale and scale is None:
        raise ValueError(f"{method!r} needs a per-coordinate scale")
    vals = jnp.moveaxis(values, axis, 0)           # (capacity, *payload)
    payload = vals.shape[1:]
    flat = vals.reshape(vals.shape[0], -1) if payload else vals[:, None]
    sc = None
    if scale is not None:
        sc = jnp.broadcast_to(jnp.asarray(scale, vals.dtype),
                              payload).reshape(-1) if payload \
            else jnp.asarray(scale, vals.dtype).reshape(1)
    out = agg.masked(flat, fill, scale=sc, K=K, trim_beta=trim_beta)
    return out.reshape(payload).astype(values.dtype)


def aggregate_batched(values, method: str = "dcq", scale=None, K: int = 10,
                      trim_beta: float = 0.2,
                      backend: Optional[str] = None, interpret=None):
    """Batched aggregation ``(*B, m, p) -> (*B, p)`` (machine axis at -2).

    This is each aggregator's declared batching rule made explicit: grid
    aggregators push the whole batch through ONE fused Pallas launch
    (leading axes on the grid); ``"vmap"`` aggregators (geomedian) batch
    via an outer vmap of the reference. On the reference backend the
    coordinate-wise rules batch natively via ``axis=-2`` reductions.
    """
    agg = get_aggregator(method)
    if agg.needs_scale and scale is None:
        raise ValueError(f"{method!r} needs a per-coordinate scale")
    if values.ndim < 2:
        raise ValueError(f"need (*batch, m, p), got {values.shape}")
    be = _pick_backend(agg, backend)
    if be == "pallas" and agg.batching == "grid":
        out = agg.pallas(values, scale=scale, K=K, trim_beta=trim_beta,
                         interpret=interpret)
        return out.astype(values.dtype)
    if agg.batching == "vmap" and values.ndim > 2:
        inner = functools.partial(aggregate_batched, method=method,
                                  scale=scale, K=K, trim_beta=trim_beta,
                                  backend=backend, interpret=interpret)
        return jax.vmap(inner)(values)
    return agg.reference(values, scale=scale, K=K, trim_beta=trim_beta,
                         axis=-2)


def median_mad_dcq(values, K: int = 10, backend: Optional[str] = None,
                   interpret=None):
    """Fused single-pass ``(median, raw MAD, MAD-scaled DCQ)`` over the
    machine axis at -2 (leading dims batch). The MAD-scaled gradient path
    uses all three: anchor, scale (robust variance = (1.4826*mad)^2) and
    the sharpened estimate — one resident tile instead of three passes."""
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" \
            else "reference"
    if backend not in ("pallas", "reference"):
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "pallas":
        return ostat_pallas(values, "median_mad_dcq", K=K,
                            interpret=interpret)
    return reference.median_mad_dcq_reference(values, K=K, axis=-2)
