"""Aggregator registry: one entry per robust center-side aggregation rule.

Every step of the paper's Algorithm 1 — and of the Yin-style distributed
Newton / Byzantine-robust one-step baselines it compares against — is a
coordinate-wise aggregation over a leading machine axis. This registry is
the single place those rules live. An :class:`Aggregator` bundles

  * ``reference`` — the pure-jnp implementation (the numerical oracle and
    the default backend off-TPU; machine axis is an ``axis`` argument, so
    arbitrary leading/trailing dims batch natively under vmap);
  * ``pallas``    — the Pallas order-statistics kernel entry
    (``repro.agg.kernel.ostat_pallas`` partial), or ``None`` when the rule
    has no kernel form (geomedian couples coordinates via Weiszfeld);
  * ``batching``  — the declared batching rule: ``"grid"`` means extra
    leading axes map onto the Pallas grid (coordinate-wise rules),
    ``"vmap"`` means batch via an outer vmap of the reference.

Registering a new aggregator makes it immediately dispatchable from
``repro.agg.aggregate``, sweepable (``Scenario.aggregator`` validates
against this registry) and benchmarkable (``benchmarks/kernel_bench.py``
iterates the registry).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Aggregator:
    """One robust aggregation rule over the machine axis.

    ``reference(values, *, scale, K, trim_beta, axis)`` -> aggregate with
    the machine axis removed; ``pallas(values, *, scale, K, trim_beta,
    tile, interpret)`` expects the machine axis at ``-2`` (payload last,
    any leading dims are batch) and returns ``values.shape`` without the
    machine axis.
    """
    name: str
    reference: Callable
    pallas: Optional[Callable] = None
    #: "grid"  — coordinate-wise; leading batch axes ride the Pallas grid.
    #: "vmap"  — not coordinate-wise; batch via outer vmap of reference.
    batching: str = "grid"
    #: ``masked(values, fill, *, scale, K, trim_beta)`` — partial-fill form
    #: over a fixed-capacity ``(C, p)`` buffer whose first ``fill`` (traced)
    #: rows are valid; byte-identical to itself on the dense unpadded batch
    #: (repro.agg.masked). ``None`` = rule not servable from a ring buffer.
    masked: Optional[Callable] = None
    #: sort-free masked form (rank-count bisection, repro.agg.masked
    #: ``*_bisect``): same signature and fill-invariance contract as
    #: ``masked`` but O(n_bisect * C * p) comparisons instead of a
    #: per-column sort — the large-p serving backend. The dispatch table
    #: (repro.agg.dispatch, op key ``masked:<name>``) picks between the
    #: two per measured shape bucket. ``None`` = no bisect form.
    masked_bisect: Optional[Callable] = None
    #: True when the rule consumes a per-coordinate scale (protocol DCQ).
    needs_scale: bool = False
    #: coordinate-wise rules commute with payload sharding (collectives.py)
    coordinatewise: bool = True
    doc: str = ""


_REGISTRY: Dict[str, Aggregator] = {}


def register(agg: Aggregator) -> Aggregator:
    """Register (or replace) an aggregator under ``agg.name``."""
    if agg.batching not in ("grid", "vmap"):
        raise ValueError(f"unknown batching rule {agg.batching!r}")
    _REGISTRY[agg.name] = agg
    return agg


def get_aggregator(name: str) -> Aggregator:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown aggregator {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def registered() -> Tuple[str, ...]:
    """Names of all registered aggregators, sorted."""
    return tuple(sorted(_REGISTRY))


def has_pallas(name: str) -> bool:
    return get_aggregator(name).pallas is not None


def has_masked(name: str) -> bool:
    return get_aggregator(name).masked is not None
