"""Pure-jnp reference implementations of every registered aggregator.

This module is the numerical oracle for the Pallas kernel
(``repro.agg.kernel``) and the default backend off-TPU. It consolidates
what previously lived in ``core/robust_agg.py`` (mean / median / trimmed
mean / geometric median), ``core/dcq.py`` (the paper's DCQ estimator and
its efficiency theory) and ``kernels/dcq_ref.py`` (the MAD-scaled DCQ
oracle of the gradient-aggregation path).

All coordinate-wise rules take the machine axis as an ``axis`` argument
and operate with plain jnp reductions, so arbitrary leading/trailing dims
batch natively under (nested) vmap — that is their declared batching rule.

DCQ math (paper §3, eq. (3.1)/(4.4)): given m machine statistics
``Y_1..Y_m`` with sampling distribution ``mu + scale * Z``, ``Z ~ G``
(standard normal here),

    med  = med{Y_j}
    S    = sum_k sum_j [ I(Y_j <= med + scale*Delta_k) - kappa_k ]
    DCQ  = med - scale * S / (m * sum_k g(Delta_k))

with ``kappa_k = k/(K+1)`` and ``Delta_k = G^{-1}(kappa_k)``.

Asymptotics (Thm 3.1): sqrt(m)(DCQ - mu)/sigma_cq -> N(0,1) with
``sigma_cq^2 = D_K * scale^2``. NOTE: the paper's printed D_K omits the
``- kappa_{k1} kappa_{k2}`` centring term; the centred form (used in
Thm 4.3's V_{g,vr} and required to reproduce ARE 3/pi ~= 0.955) is

    D_K = sum_{k1,k2} [min(k1,k2)/(K+1) - k1*k2/(K+1)^2] / {sum_k psi(Delta_k)}^2.

We implement the centred form (see DESIGN.md §1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtri  # Psi^{-1}
from jax.scipy.stats import norm

#: MAD -> sd consistency factor for the normal reference distribution.
MAD_SIGMA = 1.4826
#: floor added to MAD scales so all-identical columns stay finite.
MAD_EPS = 1e-12


# ------------------------------------------------------- DCQ quantile theory

def quantile_levels(K: int) -> jnp.ndarray:
    """kappa_k = k/(K+1), k = 1..K."""
    return jnp.arange(1, K + 1, dtype=jnp.float64 if jax.config.jax_enable_x64
                      else jnp.float32) / (K + 1)


def quantile_knots(K: int) -> jnp.ndarray:
    """Delta_k = Psi^{-1}(kappa_k) for the standard-normal reference G."""
    return ndtri(quantile_levels(K))


def d_k(K: int) -> float:
    """Variance inflation D_K of the DCQ estimator vs the mean (centred form).

    ARE(DCQ vs mean) = 1/D_K ; K -> inf gives D_K -> pi/3 (ARE 3/pi ~ 0.955).
    """
    kappa = quantile_levels(K)
    delta = quantile_knots(K)
    num = (jnp.minimum(kappa[:, None], kappa[None, :])
           - kappa[:, None] * kappa[None, :]).sum()
    den = norm.pdf(delta).sum() ** 2
    return float(num / den)


def are_dcq(K: int) -> float:
    """Asymptotic relative efficiency of DCQ vs the sample mean."""
    return 1.0 / d_k(K)


ARE_MEDIAN = 2.0 / jnp.pi  # ~0.637, quoted in the paper §1


# ----------------------------------------------------- simple aggregators

def mean_agg(values, axis: int = 0):
    return jnp.mean(values, axis=axis)


def median_agg(values, axis: int = 0):
    return jnp.median(values, axis=axis)


def trimmed_mean_agg(values, beta: float = 0.2, axis: int = 0):
    """Coordinate-wise beta-trimmed mean (Yin et al. 2018 convention): drop
    the floor(beta*m) smallest AND the floor(beta*m) largest entries per
    coordinate, keeping the central (1-2*beta) fraction. Robust to an
    alpha-fraction of Byzantine machines whenever beta >= alpha; on clean
    normal data ARE = 1 - 2*beta relative to the mean (so beta must be
    < 1/2)."""
    values = jnp.moveaxis(values, axis, 0)
    m = values.shape[0]
    g = max(int(beta * m), 0)
    srt = jnp.sort(values, axis=0)
    if 2 * g >= m:
        raise ValueError(f"trim fraction {beta} too large for m={m}")
    kept = srt[g:m - g]
    return kept.mean(axis=0)


def geometric_median_agg(values, axis: int = 0, iters: int = 50,
                         eps: float = 1e-8):
    """Weiszfeld iteration for the geometric median of m vectors. NOT
    coordinate-wise (the weights couple all coordinates), so its batching
    rule is an outer vmap, not the Pallas grid."""
    values = jnp.moveaxis(values, axis, 0)          # (m, ...)
    m = values.shape[0]
    flat = values.reshape(m, -1)

    def step(z, _):
        d = jnp.linalg.norm(flat - z[None], axis=1)
        w = 1.0 / jnp.maximum(d, eps)
        z_new = (w[:, None] * flat).sum(0) / w.sum()
        return z_new, None

    z0 = jnp.median(flat, axis=0)
    z, _ = jax.lax.scan(step, z0, None, length=iters)
    return z.reshape(values.shape[1:])


# --------------------------------------------------------------- DCQ rules

def dcq(values: jnp.ndarray, scale: jnp.ndarray, K: int = 10,
        axis: int = 0) -> jnp.ndarray:
    """Coordinate-wise DCQ estimate over the machine axis.

    Args:
      values: array with the machine axis at ``axis`` (e.g. (m, p)).
      scale: per-coordinate standard deviation of one machine's statistic
        (shape = values.shape without ``axis``). In the protocol this is
        ``sigma_hat_b / sqrt(n)`` etc. — the caller supplies the final scale.
      K: number of composite quantile levels.
      axis: machine axis.

    Returns: DCQ estimate, shape = values.shape without ``axis``.
    """
    values = jnp.moveaxis(values, axis, 0)
    m = values.shape[0]
    med = jnp.median(values, axis=0)
    delta = quantile_knots(K).astype(values.dtype)          # (K,)
    kappa = quantile_levels(K).astype(values.dtype)         # (K,)
    # thresholds: med + scale * Delta_k  -> (K, ...)
    thr = med[None] + scale[None] * delta.reshape((K,) + (1,) * med.ndim)
    ind = (values[None, :] <= thr[:, None]).astype(values.dtype)  # (K, m, ...)
    s = (ind - kappa.reshape((K,) + (1,) * values.ndim)).sum(axis=(0, 1))
    denom = m * norm.pdf(delta).sum().astype(values.dtype)
    return med - scale * s / denom


def dcq_with_sigma(values: jnp.ndarray, scale: jnp.ndarray, K: int = 10,
                   axis: int = 0):
    """DCQ estimate plus its asymptotic s.d. sigma_cq/sqrt(m) (Thm 3.1)."""
    est = dcq(values, scale, K=K, axis=axis)
    m = values.shape[axis]
    sd = jnp.sqrt(jnp.asarray(d_k(K), values.dtype)) * scale / jnp.sqrt(m)
    return est, sd


@functools.partial(jax.jit, static_argnames=("K", "axis"))
def dcq_jit(values, scale, K: int = 10, axis: int = 0):
    return dcq(values, scale, K=K, axis=axis)


def dcq_mad_reference(values: jnp.ndarray, K: int = 10,
                      axis: int = 0) -> jnp.ndarray:
    """MAD-scaled DCQ: median anchor, 1.4826*MAD scale, CQ correction.

    The gradient-aggregation variant (repro.dist.grad_agg): unlike the
    convex protocol there is no transmitted variance estimate, so the
    scale is calibrated from the data itself. Always computes in f32
    (matching the Pallas kernel) and returns f32.
    """
    values = jnp.moveaxis(values, axis, 0).astype(jnp.float32)
    med = jnp.median(values, axis=0)
    mad = jnp.median(jnp.abs(values - med[None]), axis=0)
    scale = MAD_SIGMA * mad + MAD_EPS
    return dcq(values, scale, K=K, axis=0)


def median_mad_dcq_reference(values: jnp.ndarray, K: int = 10,
                             axis: int = 0):
    """Fused single-pass statistics for the MAD-scaled gradient path:
    returns ``(median, raw MAD, MAD-scaled DCQ)`` in one call (the Pallas
    kernel computes all three from one resident tile)."""
    values = jnp.moveaxis(values, axis, 0).astype(jnp.float32)
    med = jnp.median(values, axis=0)
    mad = jnp.median(jnp.abs(values - med[None]), axis=0)
    scale = MAD_SIGMA * mad + MAD_EPS
    return med, mad, dcq(values, scale, K=K, axis=0)


def median_deviation_variance(values: jnp.ndarray, n, axis: int = 0,
                              floor: float = 1e-12) -> jnp.ndarray:
    """The untrusted-center variance estimate of Algorithm 1 (§4.3):
    ``max(median((v - median(v))^2) * n, floor)`` per coordinate — the
    robust plug-in the center uses when it cannot trust its own shard.
    One named implementation instead of the six ad-hoc ``jnp.median``
    spellings previously inlined in core/protocol.py."""
    values = jnp.moveaxis(values, axis, 0)
    med = jnp.median(values, axis=0)
    return jnp.maximum(jnp.median((values - med) ** 2, axis=0) * n, floor)
