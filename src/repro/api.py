"""``repro.api`` — the stable public surface of this repository.

One import gives the four things a user of the reproduction actually
does, decoupled from the internal package layout (which this facade is
free to keep stable across refactors — tests/test_api.py snapshots the
surface and CI fails on any break):

  * :func:`run_protocol`     — one replicate of the paper's Algorithm 1
    (DP quasi-Newton robust estimation) over pre-sharded data;
  * :func:`run_monte_carlo`  — the batched replicate driver (one compiled
    vmap over PRNG keys);
  * :func:`run_sweep`        — the scenario-sweep engine over the paper's
    experiment grid, by preset name or explicit scenario list;
  * :func:`serve`            — the streaming aggregation service
    (continuous batching over a fixed-capacity ring buffer).

plus the registry views (:func:`registered_aggregators`,
:func:`registered_attacks`) and the config/result types those entry
points consume. Internal modules (``repro.core.*``, ``repro.agg.*``,
``repro.sweep.*``) remain importable but are NOT covered by the
stability promise; the deprecated PR1-era shims (``core/robust_agg``,
``core/dcq``, ``core/byzantine``, ``kernels/dcq*``) warn and will be
removed.
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from repro import agg as _agg
from repro import attacks as _attacks
from repro.configs.base import ProtocolConfig
from repro.core.losses import MEstimationProblem, get_problem
from repro.core.protocol import DPQNProtocol, ProtocolResult
from repro.serve import AggregationService, FlushPolicy, RingBuffer, \
    ServeConfig

__all__ = [
    "run_protocol", "run_monte_carlo", "run_sweep", "serve",
    "registered_aggregators", "registered_attacks",
    "ProtocolConfig", "ProtocolResult", "DPQNProtocol",
    "MEstimationProblem", "get_problem",
    "AggregationService", "ServeConfig", "FlushPolicy", "RingBuffer",
]


def run_protocol(X, y, problem: Any = "logistic",
                 cfg: Optional[ProtocolConfig] = None,
                 key: Optional[jax.Array] = None, seed: int = 0,
                 **kwargs) -> ProtocolResult:
    """One replicate of Algorithm 1 over pre-sharded data.

    ``X``: (m+1, n, p), ``y``: (m+1, n) — machine 0 is the central
    processor. ``problem`` is a registered loss name or an
    :class:`MEstimationProblem`; ``cfg`` defaults to the paper's
    :class:`ProtocolConfig`. Extra keyword arguments (``byz_mask``,
    ``attack``, ``attack_factor``, ``theta0``, ...) forward to
    :meth:`DPQNProtocol.run`.
    """
    prob = get_problem(problem) if isinstance(problem, str) else problem
    proto = DPQNProtocol(prob, cfg if cfg is not None else ProtocolConfig())
    if key is None:
        key = jax.random.PRNGKey(seed)
    return proto.run(key, X, y, **kwargs)


def run_monte_carlo(X, y, reps: int = 100, problem: Any = "logistic",
                    cfg: Optional[ProtocolConfig] = None,
                    keys: Optional[jax.Array] = None, seed: int = 0,
                    **kwargs):
    """Batched Monte-Carlo replicates of Algorithm 1: one compiled vmap
    over the replicate keys. Returns a ``ProtocolArrays`` whose every
    field has a leading replicate axis (``theta_qn``: (reps, p))."""
    prob = get_problem(problem) if isinstance(problem, str) else problem
    proto = DPQNProtocol(prob, cfg if cfg is not None else ProtocolConfig())
    if keys is None:
        keys = jax.random.split(jax.random.PRNGKey(seed), reps)
    return proto.run_monte_carlo(keys, X, y, **kwargs)


def run_sweep(scenarios: Any = "smoke", fast: bool = False,
              artifact_path: Optional[str] = None, **kwargs) -> dict:
    """Run a scenario sweep and return its artifact dict.

    ``scenarios`` is a preset name (see ``repro.sweep.PRESETS``) or an
    iterable of ``Scenario`` objects; ``fast=True`` runs the reduced-
    replicate CI variant of a preset. Extra keyword arguments (``mesh``,
    ``resume``, ``chunk_size``, ...) forward to
    ``repro.sweep.run_scenarios``.
    """
    from repro import sweep as _sweep   # heavy import kept lazy
    if isinstance(scenarios, str):
        scens = _sweep.build_preset(scenarios)
    else:
        scens = list(scenarios)
    if fast:
        scens = _sweep.fast_variant(scens)
    return _sweep.run_scenarios(scens, artifact_path=artifact_path,
                                **kwargs)


def serve(theta: Any, cfg: Optional[ServeConfig] = None,
          policy: Optional[FlushPolicy] = None,
          sharding: Optional[Any] = None,
          **cfg_kwargs) -> AggregationService:
    """Stand up a streaming aggregation service around a model.

    ``theta`` is the served model (flat parameter vector or pytree).
    Pass a full :class:`ServeConfig`, or its fields directly as keyword
    arguments (``serve(theta, method="median", capacity=4096, eps=1.0)``).
    Returns a live :class:`AggregationService`; feed it with
    ``submit``/``submit_many``, tick ``poll`` for deadline flushes.
    """
    if cfg is not None and cfg_kwargs:
        raise ValueError("pass either cfg or ServeConfig fields, not both")
    if cfg is None:
        cfg = ServeConfig(**cfg_kwargs)
    return AggregationService(theta, cfg, policy=policy, sharding=sharding)


def registered_aggregators() -> tuple:
    """Names of every registered robust-aggregation rule."""
    return _agg.registered()


def registered_attacks() -> tuple:
    """Names of every registered Byzantine attack."""
    return _attacks.registered()
