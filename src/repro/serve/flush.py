"""Flush policy: when does the buffered fleet become a model update?

Pure host-side logic (the compiled step never branches on it): a flush
fires when the buffer reaches a capacity fraction, when the oldest
pending update has waited past a deadline, or when the caller asks
explicitly. ``min_fill`` floors every trigger — a robust aggregator
over two machines is not meaningfully robust — and ``backpressure``
names what ingest does with a full buffer that the policy refuses to
flush: reject the arrival or overwrite the oldest row (ring semantics).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

__all__ = ["FlushPolicy"]


@dataclasses.dataclass(frozen=True)
class FlushPolicy:
    #: flush when fill >= ceil(capacity_frac * capacity); None disables
    #: the capacity trigger (deadline/explicit flushes only).
    capacity_frac: Optional[float] = 1.0
    #: flush when the oldest buffered update is older than this (seconds);
    #: None disables the deadline trigger.
    max_delay_s: Optional[float] = None
    #: never flush fewer than this many updates (explicit flushes included).
    min_fill: int = 1
    #: full buffer + no flush: "reject" the arrival or "overwrite" oldest.
    backpressure: str = "reject"

    def __post_init__(self):
        if self.capacity_frac is not None \
                and not 0.0 < self.capacity_frac <= 1.0:
            raise ValueError(f"capacity_frac must be in (0, 1], got "
                             f"{self.capacity_frac}")
        if self.max_delay_s is not None and self.max_delay_s < 0:
            raise ValueError(f"max_delay_s must be >= 0, got "
                             f"{self.max_delay_s}")
        if self.min_fill < 1:
            raise ValueError(f"min_fill must be >= 1, got {self.min_fill}")
        if self.backpressure not in ("reject", "overwrite"):
            raise ValueError(f"backpressure must be 'reject' or "
                             f"'overwrite', got {self.backpressure!r}")

    def capacity_trigger(self, capacity: int) -> Optional[int]:
        """Fill level at which the capacity trigger fires, or None."""
        if self.capacity_frac is None:
            return None
        return max(self.min_fill,
                   math.ceil(self.capacity_frac * capacity))

    def should_flush(self, fill: int, capacity: int,
                     age_s: float = 0.0) -> bool:
        """Would a buffer at ``fill`` of ``capacity``, whose oldest update
        is ``age_s`` old, flush now?"""
        if fill < self.min_fill:
            return False
        trigger = self.capacity_trigger(capacity)
        if trigger is not None and fill >= trigger:
            return True
        return self.max_delay_s is not None and age_s >= self.max_delay_s
