"""Fixed-capacity device-resident ring buffers for streaming ingest.

One buffer slot per machine update: a pytree of ``(capacity, *leaf)``
device arrays plus a host-side cursor. Ingest is write-only and
compiled — a single-row writer and a fixed-size block writer, both
jitted ONCE with the buffer arrays donated, so every arrival is an
in-place device write with no host round-trip of the payload and no
retrace (the write position is a traced scalar).

Invariant consumed by the masked aggregation step: the valid rows are
always the contiguous prefix ``[0, fill)``. Below capacity the cursor
IS the fill; at capacity the cursor wraps (ring semantics — the oldest
row is overwritten) and every slot stays valid, so the prefix invariant
holds in both regimes.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["RingBuffer"]


class RingBuffer:
    """Device-resident ``(capacity, *leaf)`` stack with compiled writers.

    ``template`` is one machine update (a pytree of arrays or
    ``jax.ShapeDtypeStruct``s); ``block`` is the batch-ingest chunk size
    (one compiled write per ``block`` arrivals on the bulk path).
    ``sharding`` (optional) places the buffer arrays — e.g. a
    ``NamedSharding`` over the capacity axis for multi-device fleets.
    """

    def __init__(self, template: Any, capacity: int, block: int = 64,
                 sharding: Optional[Any] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.block = max(1, min(int(block), self.capacity))
        self.cursor = 0          # total writes since reset (never > needed)
        self.trace_counts = {"write": 0, "write_block": 0}

        def alloc(leaf):
            shape = (self.capacity,) + tuple(leaf.shape)
            arr = jnp.zeros(shape, leaf.dtype)
            return jax.device_put(arr, sharding) if sharding is not None \
                else arr
        self.arrays = jax.tree_util.tree_map(alloc, template)

        def write(arrays, row, idx):
            self.trace_counts["write"] += 1       # runs at trace time only
            return jax.tree_util.tree_map(
                lambda buf, x: buf.at[idx].set(x), arrays, row)

        def write_block(arrays, rows, start, idx):
            # rows: (n, *leaf) with n static; carve [start, start+block)
            # with a traced start so ONE executable serves every offset.
            self.trace_counts["write_block"] += 1
            def upd(buf, full):
                chunk = jax.lax.dynamic_slice_in_dim(full, start,
                                                     self.block, axis=0)
                return jax.lax.dynamic_update_slice_in_dim(buf, chunk,
                                                           idx, axis=0)
            return jax.tree_util.tree_map(upd, arrays, rows)

        # donate the buffer arrays: XLA aliases the output into the donated
        # input pages, so steady-state ingest mutates the buffer in place.
        self._write = jax.jit(write, donate_argnums=0)
        self._write_block = jax.jit(write_block, donate_argnums=0)

    @property
    def fill(self) -> int:
        """Number of valid rows (the contiguous prefix)."""
        return min(self.cursor, self.capacity)

    @property
    def full(self) -> bool:
        return self.cursor >= self.capacity

    def push(self, update: Any) -> int:
        """Write one machine update; at capacity the ring wraps onto the
        oldest slot (the caller's backpressure policy decides whether this
        is ever reached). Returns the slot index written."""
        idx = self.cursor % self.capacity
        self.arrays = self._write(self.arrays, update, jnp.int32(idx))
        self.cursor += 1
        return idx

    def push_block(self, rows: Any, start: int) -> None:
        """Write ``block`` rows taken from ``rows[start:start+block]`` at
        the cursor. Bulk-ingest fast path; the caller guarantees the
        buffer has ``block`` slots of room (no wrap mid-block)."""
        if self.fill + self.block > self.capacity:
            raise ValueError("push_block needs room for a full block; "
                             f"fill={self.fill} block={self.block} "
                             f"capacity={self.capacity}")
        self.arrays = self._write_block(self.arrays, rows,
                                        jnp.int32(start),
                                        jnp.int32(self.cursor))
        self.cursor += self.block

    def reset(self) -> None:
        """Start a new round: the stale rows stay in place — the masked
        aggregation step never reads past ``fill``."""
        self.cursor = 0
