"""``repro.serve`` — streaming aggregation service (continuous batching).

The serving counterpart of the training protocol: machine updates
stream in asynchronously, a fixed-capacity device-resident
:class:`RingBuffer` absorbs them with compiled donated writes, and one
compiled step — a single trace for the service lifetime, with the fill
level as a traced scalar — runs registry-backed masked robust
aggregation plus the DP spend ledger and the model update whenever the
:class:`FlushPolicy` fires (buffer full, deadline, or explicit flush).

Entry points:

  * :class:`AggregationService` — the service loop (submit / poll /
    flush over a model pytree or flat parameter vector);
  * :class:`ServeConfig`       — static step configuration (rule, DP
    budget, learning rate, ingest block);
  * :class:`FlushPolicy`       — when buffered updates become a round;
  * :class:`RingBuffer`        — the device-resident ingest buffer.

The masked partial-fill kernels live in :mod:`repro.agg.masked` and are
byte-identical to the dense unpadded path per registered aggregator.
"""
from __future__ import annotations

from repro.serve.buffers import RingBuffer
from repro.serve.flush import FlushPolicy
from repro.serve.service import AggregationService, ServeConfig

__all__ = ["AggregationService", "ServeConfig", "FlushPolicy",
           "RingBuffer"]
