"""The streaming aggregation service: continuous batching for huge fleets.

Machine updates (Algorithm-1 p-vectors or gradient pytrees) arrive
asynchronously via :meth:`AggregationService.submit` / ``submit_many``,
land in a fixed-capacity device-resident :class:`RingBuffer`, and a
continuously-batched compiled step — ONE trace for the whole service
lifetime — runs whenever the :class:`FlushPolicy` fires (buffer full,
deadline, or explicit ``flush()``):

    noise (central DP, per-leaf calibrated)  ->  masked robust
    aggregation over the valid prefix (repro.agg registry, byte-identical
    to the dense unpadded batch)  ->  theta <- theta - lr * aggregate

``fill`` enters the step as a traced scalar, so a half-full deadline
flush and a full capacity flush share the executable; ``theta`` is
donated (updated in place), and ingest writes are donated device writes
(buffers.py). Every served round appends to the DP spend ledger — one
composition entry on the :class:`PrivacyAccountant` and per-leaf
``{transmission, leaf, dim, sigma, eps, delta}`` records, mirroring the
training path's ``spend_record``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.dp import PrivacyAccountant, tree_mean_sigma
from repro.core.keys import stream_key
from repro.core.transport import (leaf_paths, tree_axpy, tree_leaf_dims,
                                  wire_aggregate, wire_noise)
from repro.serve.buffers import RingBuffer
from repro.serve.flush import FlushPolicy

__all__ = ["ServeConfig", "AggregationService"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static configuration of one service instance (anything here is
    baked into the single compiled step)."""
    #: registered repro.agg rule; must have a masked partial-fill form.
    method: str = "dcq_mad"
    #: ring-buffer slots (the continuous batch's maximum machine count).
    capacity: int = 1024
    #: per-coordinate scale pytree for needs_scale rules (protocol "dcq").
    scale: Any = None
    K: int = 10
    trim_beta: float = 0.2
    #: model update: theta <- theta - lr * aggregate.
    lr: float = 1.0
    #: central-DP budget per served round; > 0 adds per-leaf calibrated
    #: Gaussian noise to the buffered updates inside the compiled step.
    eps: float = 0.0
    delta: float = 1e-6
    #: samples per machine (the mean-mechanism sensitivity, Lemma 4.4).
    dp_n: int = 100
    dp_gamma: float = 2.0
    dp_tail: str = "subexp"
    #: bulk-ingest chunk: one compiled device write per this many rows.
    ingest_block: int = 64
    #: root seed for the per-round noise keys ("serve" stream).
    seed: int = 0
    #: repro.privacy registry accountant. The serving wire is ONE
    #: transmission per round (k=1), so only the accountant's
    #: single-release conversion matters — "rdp"'s tight conversion still
    #: buys a strictly smaller sigma than the paper's Lemma 2.1-style
    #: multiplier; "basic"/"subexp" are byte-identical to the historical
    #: calibration.
    accountant: str = "basic"
    #: masked aggregation form: "sort", "bisect", or None to consult the
    #: measured dispatch table (repro.agg.dispatch) for this platform.
    masked_backend: Optional[str] = None


class AggregationService:
    """Continuously-batched robust-DP aggregation over a streaming fleet.

    ``theta`` is the served model (array or pytree); arriving updates
    must match its structure. ``sharding`` optionally places the ring
    buffer (e.g. capacity axis over a device mesh).
    """

    def __init__(self, theta: Any, cfg: ServeConfig = ServeConfig(),
                 policy: Optional[FlushPolicy] = None,
                 sharding: Optional[Any] = None):
        self.cfg = cfg
        self.policy = policy if policy is not None else FlushPolicy()
        self.theta = theta
        template = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                           jnp.asarray(x).dtype), theta)
        self.buffer = RingBuffer(template, cfg.capacity,
                                 block=cfg.ingest_block, sharding=sharding)
        self.round_idx = 0
        self.accountant = PrivacyAccountant()
        self.ledger: list = []      # per-leaf spend records, every round
        self.history: list = []     # per-round {round, fill, latency_s, ..}
        self.rejected = 0
        self._oldest_ts: Optional[float] = None
        self._key = stream_key(cfg.seed, "serve")
        self._trace_counts = {"step": 0}

        # static per-leaf noise calibration: the serving wire is ONE
        # transmission per round, so each flush spends the whole
        # (eps, delta) on one mean-mechanism release per leaf.
        self._paths = leaf_paths(template)
        self._dims = [int(d) for d in jax.tree_util.tree_leaves(
            tree_leaf_dims(template))]
        from repro.privacy import get_accountant, multiplier_ratio
        self._acct = get_accountant(cfg.accountant)   # validates the name
        if cfg.eps > 0:
            self._sigma = tree_mean_sigma(tree_leaf_dims(template),
                                          cfg.dp_n, cfg.dp_gamma, cfg.eps,
                                          cfg.delta, cfg.dp_tail)
            if cfg.accountant != "basic":
                ratio = multiplier_ratio(cfg.accountant, cfg.eps,
                                         cfg.delta, 1)
                if ratio != 1.0:
                    self._sigma = jax.tree_util.tree_map(
                        lambda s: s * ratio, self._sigma)
        else:
            self._sigma = None

        def step(arrays, fill, theta, key):
            self._trace_counts["step"] += 1     # runs at trace time only
            vals = arrays
            if self._sigma is not None:
                # stale tail rows are noised too (same executable at every
                # fill); the masked aggregation never reads them.
                vals = wire_noise(key, vals, self._sigma)
            agg = wire_aggregate(vals, cfg.method, scale=cfg.scale,
                                 K=cfg.K, trim_beta=cfg.trim_beta,
                                 fill=fill, backend=cfg.masked_backend)
            return tree_axpy(-cfg.lr, agg, theta), agg

        self._step = jax.jit(step, donate_argnums=2)
        # compiled row extraction for bulk-ingest tails: a traced index,
        # so one executable serves every row of every round.
        self._take_row = jax.jit(lambda rows, i: jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_index_in_dim(x, i, 0,
                                                   keepdims=False), rows))

    # ------------------------------------------------------------- state

    @property
    def fill(self) -> int:
        return self.buffer.fill

    @property
    def trace_counts(self) -> dict:
        """Compile-once accounting: the service step plus the buffer's
        writers must each have traced exactly once, no matter how many
        rounds were served."""
        return {**self._trace_counts, **self.buffer.trace_counts}

    def _age_s(self, now: Optional[float] = None) -> float:
        if self._oldest_ts is None:
            return 0.0
        return (now if now is not None else time.perf_counter()) \
            - self._oldest_ts

    # ------------------------------------------------------------ ingest

    def submit(self, update: Any) -> bool:
        """One machine update. Returns False iff the buffer is full, the
        policy does not flush, and backpressure is "reject"."""
        if self.buffer.full:
            if self.policy.should_flush(self.fill, self.cfg.capacity,
                                        self._age_s()):
                self.flush()
            elif self.policy.backpressure == "reject":
                self.rejected += 1
                return False
            # "overwrite": fall through; the ring wraps onto the oldest.
        if self.buffer.fill == 0:
            self._oldest_ts = time.perf_counter()
        self.buffer.push(update)
        self._maybe_flush()
        return True

    def submit_many(self, updates: Any) -> int:
        """Bulk ingest of stacked updates (leading axis = machines): full
        ``ingest_block`` chunks go through one compiled block write each,
        the tail through the row path. Returns how many were accepted."""
        n = jax.tree_util.tree_leaves(updates)[0].shape[0]
        block = self.buffer.block
        i = accepted = 0
        while i < n:
            room = self.cfg.capacity - self.fill
            if room >= block and (n - i) >= block:
                if self.buffer.fill == 0:
                    self._oldest_ts = time.perf_counter()
                self.buffer.push_block(updates, i)
                i += block
                accepted += block
                self._maybe_flush()
            else:
                if self.submit(self._take_row(updates, jnp.int32(i))):
                    accepted += 1
                elif self.policy.backpressure == "reject":
                    self.rejected += n - i - 1
                    return accepted
                i += 1
        return accepted

    # ------------------------------------------------------------- flush

    def _maybe_flush(self) -> None:
        if self.policy.should_flush(self.fill, self.cfg.capacity,
                                    self._age_s()):
            self.flush()

    def poll(self) -> Optional[Any]:
        """Deadline tick: flush iff the policy says the buffered updates
        have waited long enough. Call from the serving loop's idle path."""
        if self.fill >= self.policy.min_fill and self._age_s() > 0 \
                and self.policy.max_delay_s is not None \
                and self._age_s() >= self.policy.max_delay_s:
            return self.flush()
        return None

    def flush(self) -> Optional[Any]:
        """Aggregate the buffered prefix and update theta. Returns the
        round's aggregate (theta's structure), or None when the buffer
        holds fewer than ``min_fill`` updates."""
        fill = self.fill
        if fill < self.policy.min_fill:
            return None
        key = jax.random.fold_in(self._key, self.round_idx)
        t0 = time.perf_counter()
        self.theta, agg = self._step(self.buffer.arrays, jnp.int32(fill),
                                     self.theta, key)
        jax.block_until_ready(self.theta)
        now = time.perf_counter()

        cfg = self.cfg
        if self._sigma is not None:
            self.accountant.spend_tree(f"serve round {self.round_idx}",
                                       cfg.eps, cfg.delta, self._sigma)
            sigmas = [float(s) for s in
                      jax.tree_util.tree_leaves(self._sigma)]
        else:
            sigmas = [0.0] * len(self._dims)
        self.ledger.extend(
            {"transmission": f"serve round {self.round_idx}", "leaf": p,
             "dim": d, "sigma": s,
             "eps": cfg.eps if self._sigma is not None else 0.0,
             "delta": cfg.delta if self._sigma is not None else 0.0,
             "noise": self._sigma is not None,
             "accountant": cfg.accountant,
             **({"failure_prob": self._acct.failure_prob(d, cfg.dp_n,
                                                         cfg.dp_gamma)}
                if self._acct.failure_prob is not None
                and self._sigma is not None else {})}
            for p, d, s in zip(self._paths, self._dims, sigmas))
        self.history.append({
            "round": self.round_idx, "fill": fill,
            "latency_s": now - (self._oldest_ts
                                if self._oldest_ts is not None else t0),
            "flush_s": now - t0,
        })
        self.round_idx += 1
        self.buffer.reset()
        self._oldest_ts = None
        return agg
