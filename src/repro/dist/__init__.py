"""Distribution layer: the paper's robust DP aggregation as infrastructure.

Three pieces, layered bottom-up:

  * ``grad_agg``          — per-machine DP noise, Byzantine corruption and
                            robust aggregation over a leading machine axis
                            (pytree-of-gradients API used by the trainer);
  * ``collectives``       — the same aggregation executed SPMD on a
                            ``Mesh``-sharded machine axis (shard_map +
                            all-gather), matching the replicated path;
  * ``sharded_protocol``  — Algorithm 1 (core/protocol.py) run SPMD with
                            one machine's shard per device, reusing the
                            sequential protocol's central math verbatim.
"""
from repro.dist.grad_agg import (GradAggConfig, add_dp_noise,
                                 aggregate_machine_axis, corrupt_machines,
                                 robust_aggregate)
from repro.dist.collectives import sharded_aggregate_leaf
from repro.dist.sharded_protocol import run_sharded

__all__ = ["GradAggConfig", "add_dp_noise", "aggregate_machine_axis",
           "corrupt_machines", "robust_aggregate",
           "sharded_aggregate_leaf", "run_sharded"]
