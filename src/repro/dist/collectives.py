"""SPMD robust aggregation over a Mesh-sharded machine axis.

``grad_agg.aggregate_machine_axis`` is pure math over a local (m, ...)
array; this module runs the same math when the machine axis is sharded
across devices. The schedule is gather-then-reduce:

    shard_map over the machine axis
      -> lax.all_gather the machine rows (tiled)     # the only collective
        -> aggregate_machine_axis on the full axis   # identical math

Every device then holds the identical aggregate, so the output is
replicated over the machine axis while any *payload* sharding (e.g. a
"model" axis on the parameter dims) is preserved — the robust aggregators
(median / trimmed / DCQ) are coordinate-wise, so payload shards never
need to communicate.

The replicated reference and this path agree to fp32 tolerance (1e-4 in
tests/test_dist.py): the post-gather reduction is the same program, the
only difference is the gather's concatenation order, which is the machine
order by construction (tiled all-gather).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.agg import get_aggregator
from repro.compat import shard_map
from repro.dist.grad_agg import GradAggConfig, aggregate_machine_axis


def tree_machine_specs(tree, mesh: Mesh, fsdp: bool = False,
                       machine_axis=None):
    """Per-leaf PartitionSpecs for a machine-stacked pytree: the machine
    axis rides the mesh's batch axes while every payload dim keeps the
    PARAM sharding rule from models/sharding.py. (Dropping the payload
    sharding replicates every machine's gradient over the model axis — a
    16x memory/collective blow-up; see EXPERIMENTS.md §Perf HC-train it1.)

    Extracted from train/trainer.py so the sharded tree protocol, the
    trainer and the sweep executor route leaves over the mesh with the
    same rule.
    """
    from repro.models import sharding as shd
    ax = machine_axis if machine_axis is not None else shd.batch_axes(mesh)
    if isinstance(ax, str) and ax not in mesh.axis_names:
        # pure machine mesh (e.g. 1-D ("machines",)): no "data" axis
        ax = mesh.axis_names[0]

    def mspec(kp, leaf):
        path = tuple(str(getattr(k, "key", getattr(k, "idx", "")))
                     for k in kp)
        ps = shd.param_spec(path, tuple(leaf.shape[1:]), mesh, fsdp=fsdp)
        return P(*((ax,) + tuple(ps)))
    return jax.tree_util.tree_map_with_path(mspec, tree)


def sharded_aggregate_leaf(values: jax.Array, cfg: GradAggConfig,
                           mesh: Mesh, spec: P) -> jax.Array:
    """Aggregate one (m, ...) leaf whose machine axis is sharded.

    Args:
      values: array with the machine axis leading; sharded as ``spec``.
      cfg: aggregation config (method/trim/K as in grad_agg).
      mesh: the device mesh carrying ``spec``'s axis names.
      spec: PartitionSpec of ``values``; ``spec[0]`` names the mesh
        axis (or axes) the machine dimension is sharded over, the rest
        describes payload sharding and is preserved on the output.

    Returns: the aggregate, shape ``values.shape[1:]``, replicated over
    the machine axis and sharded as ``spec[1:]`` on the payload dims.
    """
    machine_axis = spec[0] if len(spec) else None
    if machine_axis is None:
        # machine axis replicated: nothing to gather, aggregate in place
        return aggregate_machine_axis(values, cfg)
    rest = P(*spec[1:])
    reg_name = "dcq_mad" if cfg.method == "dcq" else cfg.method
    try:
        coordinatewise = get_aggregator(reg_name).coordinatewise
    except KeyError:
        # match the ValueError contract of aggregate_machine_axis
        raise ValueError(f"unknown aggregation method {cfg.method!r}") \
            from None
    if not coordinatewise and any(s is not None for s in rest):
        # e.g. geomedian: Weiszfeld weights couple all coordinates; a
        # payload shard would compute a different (wrong) aggregate than
        # the replicated path. The registry declares which rules commute
        # with payload sharding.
        raise ValueError(
            f"{cfg.method} is not coordinate-wise: payload dims must be "
            f"replicated in the sharded strategy, got spec {spec}")

    def gather_and_reduce(x):
        full = jax.lax.all_gather(x, machine_axis, axis=0, tiled=True)
        return aggregate_machine_axis(full, cfg)

    return shard_map(gather_and_reduce, mesh=mesh, in_specs=(spec,),
                     out_specs=rest, check_rep=False)(values)
