"""Algorithm 1 executed SPMD: one machine's shard per device.

The sequential reference (core/protocol.py) expresses every machine-local
computation as ``machine_map(fn, *machine_args, bcast=...)`` with
``jax.vmap`` as the default map. Here the same protocol runs with a
shard_map-based machine map over a 1-D ``("machines",)`` mesh:

  * ``X``/``y`` are placed with the machine axis sharded — each device
    holds exactly its machines' raw data, which never moves;
  * the five per-machine statistics rounds (local M-estimator, gradients,
    Newton directions, gradient differences, BFGS directions) run in
    parallel, one shard per device, with round-level broadcast inputs
    (theta_cq, g_cq, ...) replicated;
  * the central quasi-Newton update — aggregation, DP accounting, the
    rank-1 BFGS correction — is *the same code* as the reference, applied
    to the gathered five-vector transmissions.

Because the per-machine math and the central math are shared with the
sequential implementation, the noiseless protocol matches it to fp32
round-off (<=1e-5 in tests/test_dist.py).

The pure core (core/protocol.py protocol_rounds) is machine-map-agnostic,
so the whole SPMD protocol jit-compiles once per (mesh, shape) through the
same compile-once engine as the single-host path — shard_map composes with
jax.jit — and repeated run_sharded calls on one protocol instance reuse
the compiled executable.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ProtocolConfig, TreeProtocolConfig
from repro.core.losses import MEstimationProblem
from repro.core.protocol import (DPQNProtocol, ProtocolResult,
                                 ProtocolTreeArrays, protocol_tree_rounds)


def machine_map(mesh: Mesh, axis: str = "machines"):
    """Build a mesh-sharded drop-in for core.protocol.vmap_machines.

    ``machine_args`` arrive with the machine axis leading and sharded over
    ``axis``; ``bcast`` values are replicated to every device. Inside the
    shard each device vmaps over its local machines (usually exactly one),
    so per-machine numerics are identical to the sequential reference.
    """
    def mmap(fn, *machine_args, bcast=()):
        n_machine = len(machine_args)

        def per_shard(*args):
            local_args, bc = args[:n_machine], args[n_machine:]
            return jax.vmap(lambda *ma: fn(*ma, *bc))(*local_args)

        in_specs = (P(axis),) * n_machine + (P(),) * len(bcast)
        return shard_map(per_shard, mesh=mesh, in_specs=in_specs,
                         out_specs=P(axis), check_rep=False)(
                             *machine_args, *bcast)
    return mmap


def run_sharded(prob: MEstimationProblem, cfg: ProtocolConfig, mesh: Mesh,
                key: jax.Array, X: jnp.ndarray, y: jnp.ndarray,
                byz_mask: Optional[jnp.ndarray] = None,
                attack: str = "scale", attack_factor: float = -3.0,
                theta0: Optional[jnp.ndarray] = None,
                jit: bool = True) -> Dict[str, object]:
    """Run Algorithm 1 with machines sharded over ``mesh``'s first axis.

    ``X``: (m+1, n, p), ``y``: (m+1, n) — machine 0 is the central
    processor, as in ``DPQNProtocol.run``; m+1 must divide evenly over the
    mesh axis. Returns the three estimators plus the full ProtocolResult.
    """
    axis = mesh.axis_names[0]
    n_dev = mesh.shape[axis]
    if X.shape[0] % n_dev:
        raise ValueError(
            f"{X.shape[0]} machines do not shard evenly over "
            f"{n_dev} devices on axis {axis!r}")
    machine_sharding = NamedSharding(mesh, P(axis))
    X = jax.device_put(X, machine_sharding)
    y = jax.device_put(y, machine_sharding)
    proto = DPQNProtocol(prob, cfg, machine_map=machine_map(mesh, axis),
                         jit=jit)
    res: ProtocolResult = proto.run(key, X, y, byz_mask=byz_mask,
                                    attack=attack,
                                    attack_factor=attack_factor,
                                    theta0=theta0)
    return {"theta_cq": res.theta_cq, "theta_os": res.theta_os,
            "theta_qn": res.theta_qn, "result": res}


def run_sharded_tree(key: jax.Array, theta, batches, grad_fn,
                     cfg: TreeProtocolConfig, mesh: Mesh, mem=None,
                     byz_mask: Optional[jnp.ndarray] = None,
                     attack: str = "none", attack_factor: float = -3.0,
                     n: Optional[int] = None,
                     jit: bool = True) -> ProtocolTreeArrays:
    """The pytree protocol with machines sharded over ``mesh``'s first
    axis: each device holds its machines' batch shard (raw data never
    moves), the five per-machine statistics rounds run one shard per
    device through the same ``machine_map``, and every leaf of every
    transmission is aggregated by the same central code as the
    single-host engine. ``shard_map``'s spec prefixes broadcast
    ``P(axis)`` over pytree machine args, so parameter trees and the
    per-machine L-BFGS memory shard without per-leaf plumbing.

    ``batches``: pytree with leading machine axis m (must divide the mesh
    axis evenly). The other arguments are ``protocol_tree_rounds``'s.
    """
    axis = mesh.axis_names[0]
    n_dev = mesh.shape[axis]
    m = jax.tree_util.tree_leaves(batches)[0].shape[0]
    if m % n_dev:
        raise ValueError(
            f"{m} machines do not shard evenly over {n_dev} devices on "
            f"axis {axis!r}")
    machine_sharding = NamedSharding(mesh, P(axis))
    batches = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, machine_sharding), batches)
    mmap = machine_map(mesh, axis)

    def run(key, theta, batches, mem, byz_mask):
        return protocol_tree_rounds(
            key, theta, batches, grad_fn, cfg, mem=mem, byz_mask=byz_mask,
            attack=attack, attack_factor=attack_factor, n=n,
            machine_map=mmap)
    if jit:
        run = jax.jit(run)
    return run(key, theta, batches, mem, byz_mask)
