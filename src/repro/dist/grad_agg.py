"""Gradient-level robust DP aggregation over a leading machine axis.

The paper's wire model (§4) applied to training: every leaf of a gradient
pytree has shape ``(m, ...)`` — one slice per node machine. A step is

    corrupt_machines (Byzantine attack on the transmitted message)
      -> add_dp_noise (per-machine Gaussian mechanism)
        -> aggregate_machine_axis (mean / median / trimmed mean / DCQ)

composed by ``robust_aggregate``. With ``method="mean"``, ``dp_sigma=0``
and ``attack="none"`` this reduces exactly to data-parallel gradient
averaging (asserted in tests/test_train.py).

The DCQ path has no oracle scale (unlike the convex protocol, which
transmits variance estimates), so it uses the MAD-calibrated variant:
median anchor, 1.4826*MAD scale, composite-quantile correction. On TPU it
runs through the Pallas bisection kernel (kernels/dcq.py); elsewhere it
uses the pure-jnp oracle (kernels/dcq_ref.py) — same math, tested to
agree in tests/test_kernels.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import byzantine as byz
from repro.core import robust_agg
from repro.kernels.dcq import dcq_pallas
from repro.kernels.dcq_ref import dcq_mad_reference

# launcher-friendly aliases for the attack names in core/byzantine.py
_ATTACK_ALIASES = {"sign": "signflip", "noise": "gauss"}


@dataclasses.dataclass(frozen=True)
class GradAggConfig:
    """Configuration of the attack -> noise -> aggregation pipeline."""
    method: str = "dcq"            # mean | median | trimmed | dcq
    dp_sigma: float = 0.0          # per-machine Gaussian mechanism s.d.
    attack: str = "none"           # none | scale | signflip | gauss | random
    attack_factor: float = -3.0
    trim_beta: float = 0.2         # trimmed-mean fraction
    K: int = 10                    # DCQ composite-quantile levels
    strategy: str = "replicated"   # replicated | sharded (collectives.py)
    # None = auto: Pallas kernel on TPU, jnp reference elsewhere.
    use_pallas: Optional[bool] = None


def add_dp_noise(grads: Any, sigma: float, key: jax.Array) -> Any:
    """Gaussian mechanism per machine: every leaf row is an independent
    draw (machines do not share randomness). ``sigma == 0`` is an exact
    no-op — the inputs are returned unchanged."""
    if sigma == 0.0:
        return grads
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    noisy = [leaf + jnp.asarray(sigma, leaf.dtype)
             * jax.random.normal(k, leaf.shape, leaf.dtype)
             for leaf, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, noisy)


def corrupt_machines(grads: Any, byz_mask: Optional[jnp.ndarray],
                     cfg: GradAggConfig, key: jax.Array) -> Any:
    """Apply the configured Byzantine attack to the machine rows selected
    by ``byz_mask`` on every leaf. ``mask=None``, an all-False mask, or
    ``attack="none"`` leave the pytree unchanged."""
    if byz_mask is None or cfg.attack == "none":
        return grads
    attack = _ATTACK_ALIASES.get(cfg.attack, cfg.attack)
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = [byz.apply_attack(leaf, byz_mask, attack=attack,
                            factor=cfg.attack_factor, key=k)
           for leaf, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def _dcq_mad(values: jnp.ndarray, cfg: GradAggConfig) -> jnp.ndarray:
    """MAD-scaled DCQ of one (m, ...) leaf -> (...). Flattens the payload
    to (m, p) for the kernels, restores shape/dtype after."""
    m = values.shape[0]
    flat = values.reshape(m, -1)
    use_pallas = (cfg.use_pallas if cfg.use_pallas is not None
                  else jax.default_backend() == "tpu")
    if use_pallas:
        out = dcq_pallas(flat.astype(jnp.float32), K=cfg.K,
                         interpret=jax.default_backend() != "tpu")
    else:
        out = dcq_mad_reference(flat, K=cfg.K)
    return out.reshape(values.shape[1:]).astype(values.dtype)


def aggregate_machine_axis(values: jnp.ndarray,
                           cfg: GradAggConfig) -> jnp.ndarray:
    """Aggregate one array over its leading machine axis: (m, ...) -> (...)."""
    if values.ndim < 1 or values.shape[0] < 1:
        raise ValueError(f"need a leading machine axis, got {values.shape}")
    if cfg.method in ("mean", "median", "trimmed", "geomedian"):
        return robust_agg.aggregate(values, method=cfg.method,
                                    trim_beta=cfg.trim_beta, axis=0)
    if cfg.method == "dcq":
        return _dcq_mad(values, cfg)
    raise ValueError(f"unknown aggregation method {cfg.method!r}")


def robust_aggregate(grads: Any, cfg: GradAggConfig, key: jax.Array,
                     byz_mask: Optional[jnp.ndarray] = None, *,
                     mesh=None, machine_specs=None) -> Any:
    """Attack -> DP noise -> robust aggregation over a gradient pytree.

    Every leaf must carry the machine axis first. With
    ``cfg.strategy == "sharded"`` and a mesh + per-leaf PartitionSpecs
    (machine axis first), aggregation runs SPMD via
    ``collectives.sharded_aggregate_leaf``; otherwise each leaf is
    aggregated where it lives (GSPMD is free to all-gather).
    """
    k_attack, k_noise = jax.random.split(key)
    grads = corrupt_machines(grads, byz_mask, cfg, k_attack)
    grads = add_dp_noise(grads, cfg.dp_sigma, k_noise)
    if cfg.strategy == "sharded" and mesh is not None \
            and machine_specs is not None:
        from repro.dist.collectives import sharded_aggregate_leaf
        return jax.tree_util.tree_map(
            lambda g, spec: sharded_aggregate_leaf(g, cfg, mesh, spec),
            grads, machine_specs)
    return jax.tree_util.tree_map(
        lambda g: aggregate_machine_axis(g, cfg), grads)
