"""Gradient-level robust DP aggregation over a leading machine axis.

The paper's wire model (§4) applied to training: every leaf of a gradient
pytree has shape ``(m, ...)`` — one slice per node machine. A step is

    corrupt_machines (Byzantine attack on the transmitted message)
      -> add_dp_noise (per-machine Gaussian mechanism)
        -> aggregate_machine_axis (mean / median / trimmed mean / DCQ)

composed by ``robust_aggregate``. With ``method="mean"``, ``dp_sigma=0``
and ``attack="none"`` this reduces exactly to data-parallel gradient
averaging (asserted in tests/test_train.py).

Aggregation dispatches through the ``repro.agg`` registry; the Byzantine
corruption step dispatches through the ``repro.attacks`` registry (the
historical launcher aliases "sign"/"noise" still resolve). The DCQ path
has no oracle scale (unlike the convex protocol, which transmits variance
estimates), so it uses the MAD-calibrated ``"dcq_mad"`` variant: median
anchor, 1.4826*MAD scale, composite-quantile correction. On TPU it runs
through the batched Pallas bisection kernel (repro/agg/kernel.py);
elsewhere it uses the pure-jnp reference — same math, tested to agree in
tests/test_agg.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import attacks
from repro.core.transport import (leaf_paths, tree_leaf_dims,
                                  wire_aggregate, wire_noise)


@dataclasses.dataclass(frozen=True)
class GradAggConfig:
    """Configuration of the attack -> noise -> aggregation pipeline."""
    method: str = "dcq"            # mean | median | trimmed | dcq
    dp_sigma: float = 0.0          # per-machine Gaussian mechanism s.d.
    attack: str = "none"           # any repro.attacks registry name/alias
    attack_factor: float = -3.0
    trim_beta: float = 0.2         # trimmed-mean fraction
    K: int = 10                    # DCQ composite-quantile levels
    strategy: str = "replicated"   # replicated | sharded (collectives.py)
    # None = auto: Pallas kernel on TPU, jnp reference elsewhere.
    use_pallas: Optional[bool] = None
    # Per-leaf DP calibration (core.dp): with dp_eps > 0 the flat
    # ``dp_sigma`` is ignored and every leaf's Gaussian mechanism is
    # calibrated from ITS OWN dimension at budget (dp_eps, dp_delta),
    # given ``dp_n`` samples per machine and tail constant ``dp_gamma``.
    dp_eps: float = 0.0
    dp_delta: float = 0.05
    dp_gamma: float = 2.0
    dp_n: int = 0                  # samples per machine (required if dp_eps>0)
    dp_tail: str = "subexp"


def add_dp_noise(grads: Any, sigma: Any, key: jax.Array) -> Any:
    """Gaussian mechanism per machine: every leaf row is an independent
    draw (machines do not share randomness). ``sigma`` is a scalar (same
    s.d. on every leaf) or a pytree matching ``grads`` (per-leaf
    calibration, ``calibrate_leaf_sigmas``). A scalar ``sigma == 0`` is an
    exact no-op — the inputs are returned unchanged.

    Historical bug (fixed): this function applied one global sigma to
    every leaf regardless of leaf dimension, so a 16-d bias leaf was
    noised as if it were a 4096-d matrix leaf. Noise now routes through
    the shared wire primitive with per-leaf scales.
    """
    if isinstance(sigma, (int, float)) and sigma == 0.0:
        return grads
    return wire_noise(key, grads, sigma)


def calibrate_leaf_sigmas(grads: Any, cfg: GradAggConfig) -> Any:
    """Per-leaf Gaussian-mechanism s.d. from each leaf's OWN dimension:
    the Lemma 4.4 mean mechanism (core.dp.tree_mean_sigma) at d_leaf,
    budget (dp_eps, dp_delta). Leaves carry the machine axis first.
    Returns a pytree of Python floats (static under jit)."""
    from repro.core import dp
    if cfg.dp_n <= 0:
        raise ValueError("per-leaf DP calibration needs dp_n (samples per "
                         f"machine) > 0, got {cfg.dp_n}")
    dims = tree_leaf_dims(grads, machine_axis=True)
    return dp.tree_mean_sigma(dims, cfg.dp_n, cfg.dp_gamma, cfg.dp_eps,
                              cfg.dp_delta, cfg.dp_tail)


def spend_record(tree: Any, cfg: GradAggConfig, accountant=None,
                 name: str = "grad step",
                 machine_axis: bool = False) -> list:
    """The ledger entry pairing ONE :func:`robust_aggregate` transmission
    with the budget its noise spends (host-side, static shapes only).

    Returns one record per leaf — ``{transmission, leaf, dim, sigma, eps,
    delta}`` — mirroring ``dp.tree_spend_ledger``'s shape for the single
    training transmission. With ``dp_eps > 0`` the sigmas are the same
    per-leaf calibration ``add_dp_noise`` applies, and an optional
    ``accountant`` gets one ``spend_tree`` composition entry; legacy flat
    ``dp_sigma`` noise is recorded with ``eps=None`` (uncalibrated — no
    DP claim). No noise, no records.
    """
    from repro.core import dp
    dims_tree = tree_leaf_dims(tree, machine_axis=machine_axis)
    paths = leaf_paths(tree)
    dims = [int(d) for d in jax.tree_util.tree_leaves(dims_tree)]
    if cfg.dp_eps > 0:
        sigma_tree = dp.tree_mean_sigma(dims_tree, cfg.dp_n, cfg.dp_gamma,
                                        cfg.dp_eps, cfg.dp_delta,
                                        cfg.dp_tail)
        sigmas = [float(s) for s in jax.tree_util.tree_leaves(sigma_tree)]
        eps, delta = cfg.dp_eps, cfg.dp_delta
        if accountant is not None:
            accountant.spend_tree(name, eps, delta, sigma_tree)
    elif cfg.dp_sigma:
        sigmas = [float(cfg.dp_sigma)] * len(dims)
        eps = delta = None
    else:
        return []
    return [{"transmission": name, "leaf": p, "dim": d, "sigma": s,
             "eps": eps, "delta": delta}
            for p, d, s in zip(paths, dims, sigmas)]


def corrupt_machines(grads: Any, byz_mask: Optional[jnp.ndarray],
                     cfg: GradAggConfig, key: jax.Array,
                     round_idx: Optional[int] = None) -> Any:
    """Apply the configured Byzantine attack to the machine rows selected
    by ``byz_mask`` on every leaf, dispatching through the
    ``repro.attacks`` registry (aliases like "sign"/"noise" resolve).
    ``mask=None``, an all-False mask, or ``attack="none"`` leave the
    pytree unchanged. The default training path transmits ONE message per
    step (no round structure), so round-aware ramping attacks apply at
    terminal (full) strength rather than silently degenerating to their
    benign round-0 coefficient; the five-round tree protocol passes its
    actual transmission index via ``round_idx``."""
    attack = attacks.resolve(cfg.attack)
    if byz_mask is None or attack == "none":
        return grads
    if round_idx is None:
        round_idx = attacks.N_PROTOCOL_ROUNDS - 1
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    # repro: allow(wire-boundary) — historical per-leaf dispatch splits the
    # key even for single-leaf trees (unlike wire_corrupt's byte-parity
    # rule); routing through the wire would change every pinned training
    # draw. See tests/test_train.py golden losses.
    out = [attacks.apply_attack(leaf, byz_mask, attack=attack,
                                factor=cfg.attack_factor, key=k,
                                round_idx=round_idx)
           for leaf, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def _backend(cfg: GradAggConfig):
    """Registry backend for this config: None = auto (Pallas on TPU,
    reference elsewhere); an explicit ``use_pallas`` pins it."""
    if cfg.use_pallas is None:
        return None
    return "pallas" if cfg.use_pallas else "reference"


def aggregate_machine_axis(values: jnp.ndarray,
                           cfg: GradAggConfig) -> jnp.ndarray:
    """Aggregate one array over its leading machine axis: (m, ...) -> (...).

    Dispatches through the repro.agg registry; ``method="dcq"`` means the
    MAD-self-calibrated variant (registry name ``"dcq_mad"``) since the
    training path transmits no variance estimates.
    """
    if values.ndim < 1 or values.shape[0] < 1:
        raise ValueError(f"need a leading machine axis, got {values.shape}")
    method = "dcq_mad" if cfg.method == "dcq" else cfg.method
    try:
        out = wire_aggregate(values, method, K=cfg.K,
                             trim_beta=cfg.trim_beta,
                             backend=_backend(cfg))
    except KeyError:
        raise ValueError(f"unknown aggregation method {cfg.method!r}") \
            from None
    return out.astype(values.dtype)


def robust_aggregate(grads: Any, cfg: GradAggConfig, key: jax.Array,
                     byz_mask: Optional[jnp.ndarray] = None, *,
                     mesh=None, machine_specs=None,
                     round_idx: Optional[int] = None) -> Any:
    """Attack -> DP noise -> robust aggregation over a gradient pytree.

    Every leaf must carry the machine axis first. With ``cfg.dp_eps > 0``
    the noise s.d. is calibrated PER LEAF from each leaf's own dimension
    (core.dp); otherwise the flat legacy ``cfg.dp_sigma`` applies. With
    ``cfg.strategy == "sharded"`` and a mesh + per-leaf PartitionSpecs
    (machine axis first), aggregation runs SPMD via
    ``collectives.sharded_aggregate_leaf``; otherwise each leaf is
    aggregated where it lives (GSPMD is free to all-gather).
    """
    k_attack, k_noise = jax.random.split(key)
    grads = corrupt_machines(grads, byz_mask, cfg, k_attack,
                             round_idx=round_idx)
    sigma = (calibrate_leaf_sigmas(grads, cfg) if cfg.dp_eps > 0
             else cfg.dp_sigma)
    grads = add_dp_noise(grads, sigma, k_noise)
    if cfg.strategy == "sharded" and mesh is not None \
            and machine_specs is not None:
        from repro.dist.collectives import sharded_aggregate_leaf
        return jax.tree_util.tree_map(
            lambda g, spec: sharded_aggregate_leaf(g, cfg, mesh, spec),
            grads, machine_specs)
    return jax.tree_util.tree_map(
        lambda g: aggregate_machine_axis(g, cfg), grads)


def transmit_tree(values: Any, cfg: GradAggConfig, key: jax.Array,
                  byz_mask: Optional[jnp.ndarray] = None, *,
                  round_idx: int = 0, mesh=None,
                  machine_specs=None) -> Any:
    """One wire transmission of the five-round tree protocol: corrupt ->
    per-leaf DP noise -> per-leaf robust aggregation, with the actual
    transmission index forwarded to round-aware attacks. Thin named
    wrapper over :func:`robust_aggregate` so the sharded protocol and the
    trainer share one transport entry point."""
    return robust_aggregate(values, cfg, key, byz_mask, mesh=mesh,
                            machine_specs=machine_specs,
                            round_idx=round_idx)
