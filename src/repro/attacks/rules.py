"""Pure corruption rules: the attack implementations behind the registry.

Every rule maps the full transmitted stack ``values (m, ...)`` to the
adversarial replacement rows; the dispatcher masks them back onto the
Byzantine rows (honest rows are never touched here). All rules are pure
jnp and jit/vmap-compatible, including under a traced ``factor`` (the
sweep executor batches attack factors along a vmap axis).

Wire attacks (read nothing but their own row):

  * ``scaling_attack``        transmit ``factor`` x the true statistic —
    the paper's §5.1 experiment (factor -3 synthetic, +3 MNIST);
  * ``sign_flip_attack``      transmit the negated statistic;
  * ``gaussian_attack``       additive N(0, sigma^2) noise, sigma=|factor|;
  * ``random_value_attack``   replace with |factor| x N(0, 1) garbage;
  * ``zero_attack``           transmit zeros — a silent drop-out/free-rider
    that biases means toward the origin yet looks like a benign message;
  * ``adaptive_scale_attack`` scaling that ramps linearly from benign (1x)
    at the first transmission to ``factor`` x at the last, evading
    detectors calibrated on early rounds.

Omniscient attacks (read honest-machine statistics via the mask —
the coordinated adversaries of ROSE (arXiv:2307.07767) and the
Newton-like M-estimation line (arXiv:2207.06253) that sort/quantile
aggregators are weakest against):

  * ``alie_attack``  "a little is enough" (Baruch et al. 2019): transmit
    ``honest_mean - factor * honest_std`` — a small consistent shift that
    hides inside the honest spread, so per-coordinate medians/quantiles
    move without any row looking like an outlier;
  * ``ipm_attack``   inner-product manipulation (Xie et al. 2020):
    transmit ``-factor * honest_mean`` so the aggregate loses positive
    inner product with the honest descent direction.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

#: Algorithm 1 performs five p-vector transmissions; round-aware rules
#: ramp over round_idx 0..N_PROTOCOL_ROUNDS-1.
N_PROTOCOL_ROUNDS = 5


def byzantine_mask(key: jax.Array, m: int, alpha: float) -> jnp.ndarray:
    """Choose floor(alpha*m) machines (excluding the center, which is
    machine index -1 conceptually; the caller decides indexing)."""
    n_byz = int(alpha * m)
    perm = jax.random.permutation(key, m)
    return jnp.zeros((m,), bool).at[perm[:n_byz]].set(True)


def honest_mean_std(values: jnp.ndarray,
                    mask: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-coordinate mean/std over the HONEST rows (mask False) — what an
    omniscient adversary reconstructs from the transmissions it observes.
    All-Byzantine masks degenerate to (0, 0) instead of dividing by zero."""
    honest = (~mask).astype(values.dtype)
    honest = honest.reshape((-1,) + (1,) * (values.ndim - 1))
    count = jnp.maximum(honest.sum(axis=0), 1.0)
    mean = (values * honest).sum(axis=0) / count
    var = (((values - mean) ** 2) * honest).sum(axis=0) / count
    return mean, jnp.sqrt(var)


# ------------------------------------------------------------- wire attacks

def scaling_attack(values: jnp.ndarray, factor: float = -3.0) -> jnp.ndarray:
    return factor * values


def sign_flip_attack(values: jnp.ndarray) -> jnp.ndarray:
    return -values


def gaussian_attack(values: jnp.ndarray, key: jax.Array,
                    sigma: float = 10.0) -> jnp.ndarray:
    return values + sigma * jax.random.normal(key, values.shape, values.dtype)


def random_value_attack(values: jnp.ndarray, key: jax.Array,
                        scale: float = 10.0) -> jnp.ndarray:
    return scale * jax.random.normal(key, values.shape, values.dtype)


def zero_attack(values: jnp.ndarray) -> jnp.ndarray:
    return jnp.zeros_like(values)


def adaptive_scale_attack(values: jnp.ndarray, factor: float,
                          round_idx: int = 0) -> jnp.ndarray:
    """Scaling coefficient ramps linearly over the protocol's rounds:
    1x (benign) at round_idx 0 up to ``factor`` x at the final round.
    Beyond the protocol's rounds (e.g. the GD baseline's T > 5 loop) the
    ramp clamps at full strength."""
    ramp = jnp.minimum(
        jnp.asarray(round_idx, values.dtype) / (N_PROTOCOL_ROUNDS - 1), 1.0)
    coeff = 1.0 + (factor - 1.0) * ramp
    return coeff * values


# ------------------------------------------------------- omniscient attacks

def alie_attack(values: jnp.ndarray, mask: jnp.ndarray,
                z: float = 1.0) -> jnp.ndarray:
    """'A little is enough': hide ``z`` honest standard deviations below
    the honest mean — inside the honest spread, invisible to outlier
    screens, yet enough to drag quantile aggregates."""
    mean, std = honest_mean_std(values, mask)
    return jnp.broadcast_to(mean - z * std, values.shape)


def ipm_attack(values: jnp.ndarray, mask: jnp.ndarray,
               eps: float = 1.0) -> jnp.ndarray:
    """Inner-product manipulation: transmit the negated (scaled) honest
    mean so the aggregate opposes the honest direction."""
    mean, _ = honest_mean_std(values, mask)
    return jnp.broadcast_to(-eps * mean, values.shape)
