"""Attack registry: one entry per Byzantine wire-corruption rule.

The paper's robustness claims (§1.1, §5.1) are statements about a threat
model: some machines transmit adversarial statistics instead of honest
ones. This registry is the single place those threat models live — the
adversary-side mirror of the ``repro.agg`` aggregator registry. An
:class:`Attack` bundles

  * ``corrupt``     — the pure jittable corruption rule
    ``(values (m, ...), mask (m,), factor, key) -> values``: it returns
    the adversarial replacement for EVERY row; dispatch
    (``repro.attacks.apply_attack``) masks it back onto the Byzantine
    rows with ``jnp.where``, so honest rows are bit-identical by
    construction and rules never need to touch the mask for writing;
  * ``omniscient``  — whether the rule reads honest-machine statistics
    (ALIE perturbs around the honest mean/std, IPM transmits the negated
    honest mean), which it computes from ``(values, mask)``: corruption
    is applied where the full machine axis is visible, so coordinated
    attacks see exactly what a colluding adversary would see;
  * ``needs_key``   — whether the rule draws randomness; dispatch raises
    a clear ``ValueError`` when the key is omitted instead of crashing
    inside ``jax.random`` with an opaque trace error;
  * ``round_aware`` — whether the rule receives the protocol round index
    (``adaptive_scale`` ramps its corruption over Algorithm 1's rounds);
  * ``factor_grid`` — the sensible sweep values for ``factor``, the axis
    the ``attack-sensitivity`` preset expands per attack.

Registering an attack makes it immediately dispatchable from
``apply_attack``, sweepable (``Scenario.attack`` validates against this
registry exactly as ``Scenario.aggregator`` validates against
``repro.agg``) and selectable from the training launcher.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple


@dataclasses.dataclass(frozen=True)
class Attack:
    """One Byzantine corruption rule over the transmitted machine axis.

    ``corrupt(values, mask, factor, key)`` -> replacement rows, same shape
    and dtype as ``values`` (round-aware rules additionally accept a
    ``round_idx`` keyword). The mask argument is read-only context for
    omniscient rules; dispatch performs the actual row selection.
    """
    name: str
    corrupt: Callable
    #: reads honest-machine statistics via (values, mask)
    omniscient: bool = False
    #: draws randomness; apply_attack raises ValueError if key is None
    needs_key: bool = False
    #: receives round_idx (position within Algorithm 1's transmissions)
    round_aware: bool = False
    #: sensible factor sweep values (empty = not in attack-sensitivity)
    factor_grid: Tuple[float, ...] = ()
    doc: str = ""


_REGISTRY: Dict[str, Attack] = {}

#: launcher-friendly aliases (the historical dist/grad_agg names)
ALIASES: Dict[str, str] = {"sign": "signflip", "noise": "gauss"}


def register(attack: Attack) -> Attack:
    """Register (or replace) an attack under ``attack.name``."""
    if attack.name in ALIASES:
        raise ValueError(f"{attack.name!r} shadows alias for "
                         f"{ALIASES[attack.name]!r}")
    _REGISTRY[attack.name] = attack
    return attack


def unregister(name: str) -> None:
    """Remove a registered attack (tests registering temporary entries
    clean up through this instead of the private dict)."""
    _REGISTRY.pop(name, None)


def resolve(name: str) -> str:
    """Canonical registry name for ``name`` (aliases resolved)."""
    return ALIASES.get(name, name)


def get_attack(name: str) -> Attack:
    try:
        return _REGISTRY[resolve(name)]
    except KeyError:
        raise KeyError(f"unknown attack {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def registered() -> Tuple[str, ...]:
    """Names of all registered attacks, sorted."""
    return tuple(sorted(_REGISTRY))


def needs_key(name: str) -> bool:
    return get_attack(name).needs_key
