"""``repro.attacks`` — the registry-backed threat-model subsystem.

Every Byzantine corruption in this repo routes through here: the paper's
Algorithm 1 rounds (core/protocol.py — and therefore the shard_map SPMD
path), the comparison baselines (core/baselines.py), the gradient
aggregation pipeline (dist/grad_agg.py), the sweep engine
(``Scenario.attack`` validates against this registry) and the training
launcher. The design mirrors ``repro.agg``: adding an attack is one
registry entry that is immediately dispatchable, sweepable
(``python -m repro.sweep --preset attack-sensitivity`` expands every
registered attack over its declared factor grid) and benchmarkable
(``benchmarks/attack_sweep.py``).

Dispatch contract (``apply_attack``): the rule produces replacement rows
for the whole ``(m, ...)`` stack; ``jnp.where(mask, bad, values)`` puts
them only on the Byzantine rows, so honest transmissions are bit-identical
no matter the attack. Omniscient rules (ALIE, IPM) read honest-machine
statistics from ``(values, mask)`` — corruption is applied at the point
where the full machine axis is visible, exactly what a coordinating
adversary observes. ``attack="none"`` is an exact no-op (the input object
is returned untouched).

Migration note: ``core/byzantine.py`` is now a thin import shim over this
package; import from ``repro.attacks`` directly in new code.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.attacks import registry, rules
from repro.attacks.registry import (ALIASES, Attack, get_attack, needs_key,
                                    register, registered, resolve,
                                    unregister)
from repro.attacks.rules import (N_PROTOCOL_ROUNDS, adaptive_scale_attack,
                                 alie_attack, byzantine_mask,
                                 gaussian_attack, honest_mean_std,
                                 ipm_attack, random_value_attack,
                                 scaling_attack, sign_flip_attack,
                                 zero_attack)

__all__ = [
    "Attack", "register", "unregister", "get_attack", "registered",
    "resolve", "needs_key", "ALIASES",
    "apply_attack", "byzantine_mask", "honest_mean_std",
    "N_PROTOCOL_ROUNDS",
    "scaling_attack", "sign_flip_attack", "gaussian_attack",
    "random_value_attack", "zero_attack", "adaptive_scale_attack",
    "alie_attack", "ipm_attack",
    "registry", "rules",
]


# ------------------------------------------------------- built-in attacks
#
# corrupt signature: (values, mask, factor, key) -> replacement rows
# (round-aware rules take an extra ``round_idx`` keyword). Factors may be
# traced scalars — the sweep executor batches them along a vmap axis.

register(Attack(
    name="none",
    corrupt=lambda values, mask, factor, key: values,
    factor_grid=(),
    doc="no corruption (the honest-execution control)"))

register(Attack(
    name="scale",
    corrupt=lambda values, mask, factor, key:
        rules.scaling_attack(values, factor),
    factor_grid=(-10.0, -3.0, 3.0, 10.0),
    doc="transmit factor x the true statistic (paper §5.1: -3/+3)"))

register(Attack(
    name="signflip",
    corrupt=lambda values, mask, factor, key:
        rules.sign_flip_attack(values),
    factor_grid=(1.0,),
    doc="transmit the negated statistic (factor ignored)"))

register(Attack(
    name="gauss",
    corrupt=lambda values, mask, factor, key:
        rules.gaussian_attack(values, key, sigma=abs(factor)),
    needs_key=True,
    factor_grid=(3.0, 10.0, 30.0),
    doc="additive N(0, sigma^2) noise with sigma = |factor|"))

register(Attack(
    name="random",
    corrupt=lambda values, mask, factor, key:
        rules.random_value_attack(values, key, scale=abs(factor)),
    needs_key=True,
    factor_grid=(3.0, 10.0, 30.0),
    doc="replace with |factor| x N(0, 1) garbage"))

register(Attack(
    name="zero",
    corrupt=lambda values, mask, factor, key:
        rules.zero_attack(values),
    factor_grid=(1.0,),
    doc="transmit zeros: silent drop-out / free-rider (factor ignored)"))

register(Attack(
    name="adaptive_scale",
    corrupt=lambda values, mask, factor, key, round_idx=0:
        rules.adaptive_scale_attack(values, factor, round_idx=round_idx),
    round_aware=True,
    factor_grid=(-10.0, -3.0, 3.0),
    doc="scaling ramping 1x -> factor x over Algorithm 1's rounds "
        "(evades early-round detectors)"))

register(Attack(
    name="alie",
    corrupt=lambda values, mask, factor, key:
        rules.alie_attack(values, mask, z=factor),
    omniscient=True,
    factor_grid=(0.5, 1.0, 2.0),
    doc="'a little is enough' (Baruch et al. 2019): honest_mean - "
        "factor x honest_std, hidden inside the honest spread"))

register(Attack(
    name="ipm",
    corrupt=lambda values, mask, factor, key:
        rules.ipm_attack(values, mask, eps=factor),
    omniscient=True,
    factor_grid=(0.5, 1.5, 10.0),
    doc="inner-product manipulation (Xie et al. 2020): -factor x "
        "honest_mean, reversing the aggregate's descent direction"))


# ------------------------------------------------------------ dispatch API

def apply_attack(values: jnp.ndarray, mask: jnp.ndarray,
                 attack: str = "scale", factor=-3.0,
                 key: Optional[jax.Array] = None,
                 round_idx: int = 0) -> jnp.ndarray:
    """Corrupt the machine-axis rows of ``values`` selected by ``mask``.

    ``values``: (m, ...); ``mask``: (m,) bool. Returns a corrupted copy
    whose honest rows are bit-identical to the input — the attack is
    applied to the *transmitted* message only, matching the paper's
    threat model (local data stays clean; the wire is corrupted).
    ``round_idx`` is the transmission's position within Algorithm 1
    (0-based); only round-aware attacks read it.

    Raises ``ValueError`` for an unregistered attack, or when a
    randomness-consuming attack (``needs_key``) is dispatched without a
    PRNG key.
    """
    name = resolve(attack)
    if name == "none":
        return values
    try:
        entry = get_attack(name)
    except KeyError as e:
        # historical core/byzantine.py contract raised ValueError; keep
        # the registry's message as the single source of truth
        raise ValueError(e.args[0]) from None
    if entry.needs_key and key is None:
        raise ValueError(
            f"attack {entry.name!r} draws randomness (needs_key=True) but "
            f"apply_attack was called with key=None; pass a jax.random "
            f"PRNG key")
    kw = {"round_idx": round_idx} if entry.round_aware else {}
    bad = entry.corrupt(values, mask, factor, key, **kw)
    sel = mask.reshape((-1,) + (1,) * (values.ndim - 1))
    return jnp.where(sel, bad, values)
