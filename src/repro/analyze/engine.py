"""Analysis orchestration: file collection, rule dispatch, suppression
matching, and the human / JSON reports the CLI and CI consume."""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import repro.analyze.rules  # noqa: F401  (registers the shipped rules)
from repro.analyze import callgraph, suppress
from repro.analyze.registry import Finding, get_rule, registered

SCHEMA = "repro.analyze/v1"

# trees never worth analyzing (seeded-violation fixtures, caches)
_SKIP_PARTS = {"__pycache__", ".git", "fixtures"}


def collect_files(paths: list, include_fixtures: bool = False) -> list:
    skip = _SKIP_PARTS - ({"fixtures"} if include_fixtures else set())
    files = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(
                str(f) for f in p.rglob("*.py")
                if not (skip & set(f.parts))))
        elif p.suffix == ".py":
            files.append(str(p))
    return files


@dataclasses.dataclass
class Report:
    roots: list
    files: list
    findings: list        # active Finding objects
    suppressed: list      # suppressed Finding objects (reason attached)

    @property
    def exit_code(self) -> int:
        return 1 if self.findings else 0

    def per_rule(self) -> dict:
        counts: dict = {}
        for f in self.findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        return counts

    def to_json(self) -> dict:
        return {
            "schema": SCHEMA,
            "roots": [str(r) for r in self.roots],
            "files": len(self.files),
            "rules": {name: get_rule(name).doc for name in registered()},
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "counts": {"findings": len(self.findings),
                       "suppressed": len(self.suppressed),
                       "per_rule": self.per_rule()},
        }

    def human(self) -> str:
        lines = []
        for f in sorted(self.findings, key=lambda f: (f.path, f.line, f.col)):
            lines.append(f"{f.path}:{f.line}:{f.col + 1}: "
                         f"[{f.rule}] {f.message}")
        n, s = len(self.findings), len(self.suppressed)
        if n:
            per = ", ".join(f"{k}={v}" for k, v in sorted(
                self.per_rule().items()))
            lines.append(f"{n} finding(s) ({per}); {s} suppressed; "
                         f"{len(self.files)} file(s)")
        else:
            lines.append(f"clean: 0 findings ({s} suppressed) across "
                         f"{len(self.files)} file(s)")
        return "\n".join(lines)


def analyze_paths(paths: list, rules: list | None = None,
                  include_fixtures: bool = False) -> Report:
    """Run the registered rules (or the named subset) over ``paths``."""
    files = collect_files(paths, include_fixtures=include_fixtures)
    graph = callgraph.build(files)
    rule_names = list(rules) if rules else registered()
    rule_objs = [get_rule(name) for name in rule_names]

    active, suppressed = [], []
    # unused-suppression has no per-module check; the engine decides it
    # here, after matching, and only for waivers whose rule actually ran
    # this invocation (a --rules subset must not flag waivers of the
    # rules it skipped).
    check_unused = "unused-suppression" in rule_names
    for path in files:
        mod = graph.modules.get(path)
        if mod is None:
            continue
        sups = suppress.parse(mod.source)
        # malformed suppressions are findings themselves
        for s in sups:
            for rname in s.rules:
                if rname not in registered() and rname != "suppression":
                    active.append(Finding(
                        rule="suppression", path=path, line=s.line, col=0,
                        message=f"suppression names unknown rule {rname!r}"))
            if not s.reason:
                active.append(Finding(
                    rule="suppression", path=path, line=s.line, col=0,
                    message="suppression without a reason; write "
                            "# repro: allow(<rule>) — <why>"))
        matched = set()               # (Suppression, rule name) pairs
        for rule in rule_objs:
            for f in rule.check(mod, graph):
                s = suppress.match(f.rule, f.line, sups, mod.lines)
                if s is not None:
                    matched.add((s, f.rule))
                if s is not None and s.reason:
                    suppressed.append(dataclasses.replace(
                        f, suppressed=True, reason=s.reason))
                else:
                    active.append(f)
        if check_unused:
            for s in sups:
                for rname in s.rules:
                    if rname in ("suppression", "unused-suppression"):
                        continue      # flagged elsewhere / self-waiver
                    if rname not in rule_names or rname not in registered():
                        continue      # rule skipped or unknown this run
                    if (s, rname) in matched:
                        continue
                    f = Finding(
                        rule="unused-suppression", path=path, line=s.line,
                        col=0,
                        message=f"# repro: {s.kind}({rname}) silenced no "
                                f"{rname!r} finding — stale waiver; remove "
                                "it (or add unused-suppression to the "
                                "rule list if it is prophylactic)")
                    cover = suppress.match("unused-suppression", s.line,
                                           sups, mod.lines)
                    if cover is not None and cover.reason:
                        suppressed.append(dataclasses.replace(
                            f, suppressed=True, reason=cover.reason))
                    else:
                        active.append(f)
    return Report(roots=list(paths), files=files, findings=active,
                  suppressed=suppressed)


def write_json(report: Report, path: str) -> None:
    Path(path).write_text(json.dumps(report.to_json(), indent=2) + "\n")
