"""Shared AST infrastructure: module parsing, name resolution, call graph.

Everything here is deliberately approximate in the sound-for-our-tree
direction: name resolution follows ``import``/``from-import`` aliases and
``self.`` methods, call-graph edges include *references* to known
functions (so higher-order wiring like ``jax.vmap(one_rep)`` or a nested
``step`` returned from a factory still produces an edge), and
jit-reachability is a BFS from every ``jax.jit`` / ``shard_map`` /
``pallas_call`` root over those edges.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

# Call targets whose function-typed arguments become jit roots. The
# executor registers jit groups by calling ``jax.jit`` on factory output,
# so a Call argument marks the factory itself (its nested defs are then
# reached through ordinary reference edges).
JIT_WRAPPERS = ("jax.jit", "jit")
SHARD_WRAPPERS = ("jax.experimental.shard_map.shard_map", "shard_map",
                  "repro.compat.shard_map", "machine_map",
                  "repro.dist.sharded_protocol.machine_map")
PALLAS_WRAPPERS = ("jax.experimental.pallas.pallas_call", "pl.pallas_call",
                   "pallas_call")


@dataclasses.dataclass
class FunctionInfo:
    """One def (or the module body, under the pseudo-name ``<module>``)."""
    qual: str                    # modname + "." + dotted def path
    module: "ModuleInfo"
    node: ast.AST
    class_ctx: str | None = None  # enclosing class dotted path, if any
    refs: list = dataclasses.field(default_factory=list)   # raw dotted refs
    edges: set = dataclasses.field(default_factory=set)    # resolved quals
    is_jit_root: bool = False

    @property
    def name(self) -> str:
        return self.qual.rsplit(".", 1)[-1]


@dataclasses.dataclass
class ModuleInfo:
    path: str
    modname: str
    tree: ast.Module
    source: str
    lines: list
    imports: dict = dataclasses.field(default_factory=dict)
    functions: dict = dataclasses.field(default_factory=dict)
    classes: set = dataclasses.field(default_factory=set)


def module_name(path: str) -> str:
    """src/repro/core/dp.py -> repro.core.dp; benchmarks/x.py -> benchmarks.x."""
    parts = list(Path(path).with_suffix("").parts)
    if "src" in parts:
        parts = parts[parts.index("src") + 1:]
    else:
        for anchor in ("tests", "benchmarks", "examples", "repro"):
            if anchor in parts:
                parts = parts[parts.index(anchor):]
                break
        else:
            parts = parts[-1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def dotted(node: ast.AST, imports: dict | None = None) -> str | None:
    """Flatten an Attribute/Name chain to "a.b.c", resolving the head
    through the module's import aliases when given. Returns None for
    anything that is not a plain chain (calls, subscripts, ...)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    parts.reverse()
    if imports and parts[0] in imports:
        parts[0:1] = imports[parts[0]].split(".")
    return ".".join(parts)


def _collect_imports(mod: ModuleInfo) -> None:
    pkg = mod.modname.split(".")
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                local = alias.asname or alias.name.split(".")[0]
                target = alias.name if alias.asname else alias.name.split(".")[0]
                mod.imports[local] = target
        elif isinstance(node, ast.ImportFrom):
            base = node.module or ""
            if node.level:  # relative import: resolve against our package
                anchor = pkg[: max(len(pkg) - node.level, 0)]
                base = ".".join(anchor + ([base] if base else []))
            for alias in node.names:
                if alias.name == "*":
                    continue
                local = alias.asname or alias.name
                mod.imports[local] = f"{base}.{alias.name}" if base else alias.name


class _Collector(ast.NodeVisitor):
    """Builds FunctionInfo entries and their raw reference lists."""

    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.stack: list[str] = []
        self.class_stack: list[str] = []
        top = FunctionInfo(qual=f"{mod.modname}.<module>", module=mod,
                           node=mod.tree)
        mod.functions[top.qual] = top
        self.fn_stack = [top]

    def _qual(self, name: str) -> str:
        return ".".join([self.mod.modname] + self.stack + [name])

    def visit_ClassDef(self, node: ast.ClassDef):
        self.mod.classes.add(self._qual(node.name))
        self.stack.append(node.name)
        self.class_stack.append(".".join(self.stack))
        self.generic_visit(node)
        self.class_stack.pop()
        self.stack.pop()

    def _visit_fn(self, node):
        qual = self._qual(node.name)
        info = FunctionInfo(
            qual=qual, module=self.mod, node=node,
            class_ctx=self.class_stack[-1] if self.class_stack else None)
        self.mod.functions[qual] = info
        # decorators run in the enclosing scope and can make jit roots
        for dec in node.decorator_list:
            self._scan_expr(dec)
            if _is_jit_decorator(dec, self.mod.imports):
                info.is_jit_root = True
        self.stack.append(node.name)
        self.fn_stack.append(info)
        for child in ast.iter_child_nodes(node):
            if child in node.decorator_list:
                continue
            self.visit(child)
        self.fn_stack.pop()
        self.stack.pop()
        # a nested def is referenced (returned, passed along) by its
        # enclosing function in every pattern we use
        self.fn_stack[-1].refs.append(qual)

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def _scan_expr(self, node):
        """Record every dotted reference inside an expression subtree."""
        fn = self.fn_stack[-1]
        for sub in ast.walk(node):
            if isinstance(sub, (ast.Name, ast.Attribute)):
                d = dotted(sub, self.mod.imports)
                if d:
                    fn.refs.append(d)

    def visit_Call(self, node: ast.Call):
        d = dotted(node.func, self.mod.imports)
        if d and (d in JIT_WRAPPERS or d in SHARD_WRAPPERS
                  or d in PALLAS_WRAPPERS):
            # every function referenced in the wrapped arguments is a root
            for arg in (*node.args, *(kw.value for kw in node.keywords)):
                for sub in ast.walk(arg):
                    if isinstance(sub, (ast.Name, ast.Attribute)):
                        r = dotted(sub, self.mod.imports)
                        if r:
                            self.fn_stack[-1].refs.append(("jit-root", r))
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name):
        d = dotted(node, self.mod.imports)
        if d:
            self.fn_stack[-1].refs.append(d)

    def visit_Attribute(self, node: ast.Attribute):
        d = dotted(node, self.mod.imports)
        if d:
            self.fn_stack[-1].refs.append(d)
        else:
            self.generic_visit(node)


def _is_jit_decorator(dec: ast.AST, imports: dict) -> bool:
    d = dotted(dec, imports)
    if d in JIT_WRAPPERS:
        return True
    if isinstance(dec, ast.Call):
        d = dotted(dec.func, imports)
        if d in JIT_WRAPPERS:
            return True
        if d in ("functools.partial", "partial") and dec.args:
            return dotted(dec.args[0], imports) in JIT_WRAPPERS
    return False


@dataclasses.dataclass
class CallGraph:
    modules: dict                # path -> ModuleInfo
    functions: dict              # qual -> FunctionInfo
    callers: dict                # qual -> set of caller quals
    jit_reachable: set           # quals reachable from a jit root

    def enclosing(self, mod: ModuleInfo, node: ast.AST) -> FunctionInfo:
        """The innermost FunctionInfo whose def contains ``node``."""
        best = mod.functions[f"{mod.modname}.<module>"]
        for info in mod.functions.values():
            if isinstance(info.node, ast.Module):
                continue
            n = info.node
            if (n.lineno <= node.lineno <= (n.end_lineno or n.lineno)
                    and (best.node is mod.tree
                         or n.lineno >= best.node.lineno)):
                best = info
        return best

    def scope_modules(self, fn: FunctionInfo) -> set:
        """Module names of ``fn`` plus its transitive CALLERS — the
        "protocol scope" the ledger-pairing rule searches. Callers only:
        the ledger record belongs to whoever orchestrates the noise, and
        following callees would trivially reach core/dp.py (where the
        accounting primitives live) and vacuously satisfy every site."""
        seen, frontier = set(), {fn.qual}
        while frontier:
            q = frontier.pop()
            if q in seen or q not in self.functions:
                continue
            seen.add(q)
            frontier |= self.callers.get(q, set()) - seen
        return {self.functions[q].module.modname for q in seen}


def _resolve(graph_fns: dict, classes: set, fn: FunctionInfo,
             ref: str) -> str | None:
    """Map a raw dotted reference to a known function qual, trying
    self-methods, enclosing scopes, the module's globals, then the
    already-import-resolved absolute path (and __init__ for classes)."""
    mod = fn.module
    candidates = []
    if ref.startswith("self.") and fn.class_ctx:
        candidates.append(f"{mod.modname}.{fn.class_ctx}.{ref[5:]}")
        candidates.append(f"{mod.modname}.{fn.class_ctx}.{ref[5:]}.__init__")
    # walk lexical scopes outward: a.b.c inside mod.f tries mod.f.a.b.c,
    # then mod.a.b.c
    local = fn.qual[len(mod.modname) + 1:]
    parts = [] if local == "<module>" else local.split(".")
    for i in range(len(parts), -1, -1):
        candidates.append(".".join([mod.modname] + parts[:i] + [ref]))
    candidates.append(ref)
    for cand in candidates:
        if cand in graph_fns:
            return cand
        if cand in classes and f"{cand}.__init__" in graph_fns:
            return f"{cand}.__init__"
    return None


def build(paths: list) -> CallGraph:
    modules: dict = {}
    for path in paths:
        src = Path(path).read_text()
        try:
            tree = ast.parse(src, filename=str(path))
        except SyntaxError:
            continue
        mod = ModuleInfo(path=str(path), modname=module_name(path),
                         tree=tree, source=src, lines=src.splitlines())
        _collect_imports(mod)
        _Collector(mod).visit(tree)
        modules[str(path)] = mod

    functions: dict = {}
    classes: set = set()
    for mod in modules.values():
        functions.update(mod.functions)
        classes |= mod.classes

    roots = set()
    for mod in modules.values():
        for fn in mod.functions.values():
            if fn.is_jit_root:
                roots.add(fn.qual)
            for ref in fn.refs:
                tagged = isinstance(ref, tuple)
                raw = ref[1] if tagged else ref
                target = _resolve(functions, classes, fn, raw)
                if target is None:
                    continue
                fn.edges.add(target)
                if tagged:
                    roots.add(target)

    callers: dict = {}
    for fn in functions.values():
        for target in fn.edges:
            callers.setdefault(target, set()).add(fn.qual)

    reachable, frontier = set(), set(roots)
    while frontier:
        q = frontier.pop()
        if q in reachable:
            continue
        reachable.add(q)
        frontier |= functions[q].edges - reachable

    return CallGraph(modules=modules, functions=functions, callers=callers,
                     jit_reachable=reachable)
