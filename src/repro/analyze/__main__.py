"""Entry point for ``python -m repro.analyze``."""
import sys

from repro.analyze.cli import main

sys.exit(main())
