"""Inline suppressions: ``# repro: allow(<rule>) — <reason>``.

A suppression on the finding's line (or the line directly above it)
silences that rule there; ``allow-file`` at any line silences the rule
for the whole file. The reason is mandatory — a suppression without one
is itself reported (rule name ``suppression``), as is one naming an
unknown rule. Multiple rules may be listed comma-separated.
"""
from __future__ import annotations

import dataclasses
import io
import re
import tokenize

_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*(?P<kind>allow|allow-file)\((?P<rules>[^)]*)\)"
    r"\s*(?:—|--|-)?\s*(?P<reason>.*\S)?\s*$")


@dataclasses.dataclass(frozen=True)
class Suppression:
    kind: str          # "allow" | "allow-file"
    rules: tuple       # rule names
    reason: str
    line: int          # 1-based source line of the comment


def parse(source: str) -> list:
    """Extract suppressions from real COMMENT tokens only — a suppression
    example quoted in a docstring is not a suppression."""
    out = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return out
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _SUPPRESS_RE.match(tok.string)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group("rules").split(",")
                      if r.strip())
        out.append(Suppression(kind=m.group("kind"), rules=rules,
                               reason=(m.group("reason") or "").strip(),
                               line=tok.start[0]))
    return out


def match(finding_rule: str, finding_line: int, suppressions: list,
          lines: list | None = None) -> "Suppression | None":
    """The suppression covering a finding, if any: same line, or anywhere
    in the contiguous comment block directly above it (a multi-line
    reason keeps its marker on the first line)."""
    candidates = [s for s in suppressions if finding_rule in s.rules]
    for s in candidates:
        if s.kind == "allow-file" or s.line == finding_line:
            return s
    block_top = finding_line
    if lines is not None:
        i = finding_line - 1
        while i >= 1 and lines[i - 1].lstrip().startswith("#"):
            block_top = i
            i -= 1
    else:
        block_top = finding_line - 1
    for s in candidates:
        if s.kind == "allow" and block_top <= s.line < finding_line:
            return s
    return None
