"""repro.analyze: privacy- and trace-safety static analysis.

The paper's DP guarantee rests on invariants the type system cannot see:
every one of Algorithm 1's five transmissions gets *independently keyed*,
per-dimension-calibrated Gaussian noise, and every noise injection is
matched by a spend-ledger record. After the PR 4-6 refactors those
invariants live as conventions — transport.py is the only wire, PRNG keys
are never consumed twice, ``protocol_rounds`` stays host-sync-free. This
package is their compiler:

  * ``registry``  — one :class:`Rule` entry per invariant, mirroring the
    ``repro.agg`` / ``repro.attacks`` registry style;
  * ``callgraph`` — the shared AST walker: module parsing, name
    resolution, call-graph edges and jit-reachability (functions reachable
    from ``jax.jit`` / ``shard_map`` / ``pallas_call`` roots);
  * ``rules``     — the shipped rules: key-reuse, wire-boundary,
    ledger-pairing, jit-purity, pallas-static;
  * ``engine``    — orchestration, inline suppressions
    (``# repro: allow(<rule>) — <reason>``), human + JSON reports;
  * ``cli``       — ``python -m repro.analyze`` / ``repro-analyze``,
    the CI gate.
"""
from repro.analyze.engine import Report, analyze_paths
from repro.analyze.registry import (Finding, Rule, get_rule, register,
                                    registered, unregister)

__all__ = ["analyze_paths", "Report", "Finding", "Rule", "register",
           "unregister", "get_rule", "registered"]
