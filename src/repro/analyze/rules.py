"""The shipped rules. Importing this module populates the registry.

Each check is ``check(mod, graph) -> list[Finding]`` where ``mod`` is a
:class:`~repro.analyze.callgraph.ModuleInfo` and ``graph`` the whole-tree
:class:`~repro.analyze.callgraph.CallGraph`. Rules are tuned to this
repo's conventions (transport wire, spend ledger, compile-once engine) —
they are not general-purpose lint.
"""
from __future__ import annotations

import ast

from repro.analyze.callgraph import CallGraph, ModuleInfo, dotted
from repro.analyze.registry import Finding, Rule, register

# --------------------------------------------------------------------------
# key-reuse: the DP-critical rule. A jax.random key consumed by a sampler
# may not be consumed again — reuse correlates noise across Algorithm 1's
# transmissions and voids the privacy accounting. Also flags arithmetic
# seeds (PRNGKey(a + b)): adjacent streams collide; derive with fold_in
# (repro.core.keys.stream_key) instead.
# --------------------------------------------------------------------------

# jax.random attributes that do NOT consume their first argument
_NONCONSUMING = {"PRNGKey", "key", "fold_in", "key_data", "wrap_key_data",
                 "clone", "key_impl", "default_prng_impl", "split"}

_FRESH, _CONSUMED = "fresh", "consumed"


def _key_expr(node) -> str | None:
    """A trackable key expression: a bare name or name[const]."""
    if isinstance(node, ast.Name):
        return node.id
    if (isinstance(node, ast.Subscript) and isinstance(node.value, ast.Name)
            and isinstance(node.slice, ast.Constant)):
        return f"{node.value.id}[{node.slice.value!r}]"
    return None


_PRODUCERS = ("PRNGKey", "key", "split", "fold_in", "clone",
              "wrap_key_data")


def _is_key_producing(node, imports) -> bool:
    """True if the expression *itself* evaluates to PRNG keys. Top-level
    only: ``jax.eval_shape(lambda: init(PRNGKey(0)))`` produces shapes,
    not keys, even though a key ctor appears in the subtree."""
    if isinstance(node, (ast.Tuple, ast.List)):
        return any(_is_key_producing(e, imports) for e in node.elts)
    if isinstance(node, ast.Subscript):
        return _is_key_producing(node.value, imports)
    if isinstance(node, ast.Call):
        d = dotted(node.func, imports)
        return bool(d and d.startswith("jax.random.")
                    and d.rsplit(".", 1)[-1] in _PRODUCERS)
    return False


class _KeyChecker:
    """Per-function abstract interpreter over key lifecycles."""

    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.state: dict = {}       # key expr -> (_FRESH|_CONSUMED, line)
        self.findings: list = []
        self._seen: set = set()

    # -- reporting ---------------------------------------------------------
    def _emit(self, node, message):
        sig = (node.lineno, node.col_offset, message)
        if sig not in self._seen:
            self._seen.add(sig)
            self.findings.append(Finding(
                rule="key-reuse", path=self.mod.path, line=node.lineno,
                col=node.col_offset, message=message))

    # -- state helpers -----------------------------------------------------
    def _consume(self, argnode):
        e = _key_expr(argnode)
        if e is None:
            return
        # first consumption marks the expression key-typed (covers
        # function parameters, which are never explicitly bound)
        status, line = self.state.get(e, (_FRESH, argnode.lineno))
        if status == _CONSUMED:
            self._emit(argnode,
                       f"PRNG key {e!r} reused after being consumed at "
                       f"line {line}; derive a fresh key with "
                       "jax.random.split/fold_in before sampling again")
        self.state[e] = (_CONSUMED, argnode.lineno)

    def _bind(self, target, producing):
        if isinstance(target, ast.Name):
            # reassignment invalidates the name and any tracked elements
            for k in [k for k in self.state
                      if k == target.id or k.startswith(f"{target.id}[")]:
                del self.state[k]
            if producing:
                self.state[target.id] = (_FRESH, target.lineno)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, producing)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, producing)
        elif isinstance(target, ast.Subscript):
            e = _key_expr(target)
            if e is not None:
                if producing:
                    self.state[e] = (_FRESH, target.lineno)
                else:
                    self.state.pop(e, None)

    # -- expression evaluation --------------------------------------------
    def eval(self, node):
        if node is None:
            return
        if isinstance(node, ast.Call):
            self._eval_call(node)
            return
        if isinstance(node, ast.IfExp):
            self.eval(node.test)
            before = dict(self.state)
            self.eval(node.body)
            branch = self.state
            self.state = dict(before)
            self.eval(node.orelse)
            self._merge(branch)
            return
        if isinstance(node, (ast.Lambda,)):
            self.eval(node.body)
            return
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            self._eval_comp(node)
            return
        for child in ast.iter_child_nodes(node):
            self.eval(child)

    def _eval_comp(self, node):
        for gen in node.generators:
            self.eval(gen.iter)
            self._bind(gen.target, producing=False)
            for cond in gen.ifs:
                self.eval(cond)
        body = ([node.key, node.value] if isinstance(node, ast.DictComp)
                else [node.elt])
        # two passes catch cross-iteration reuse of OUTER keys; the loop
        # targets are rebound fresh before each pass (new value per iter)
        for _ in range(2):
            for gen in node.generators:
                self._bind(gen.target, producing=False)
            for expr in body:
                self.eval(expr)

    def _eval_call(self, node: ast.Call):
        for arg in node.args:
            self.eval(arg)
        for kw in node.keywords:
            self.eval(kw.value)
        d = dotted(node.func, self.mod.imports)
        if d and d.startswith("jax.random."):
            name = d[len("jax.random."):]
            if name in ("PRNGKey", "key"):
                if node.args and any(isinstance(s, ast.BinOp)
                                     for s in ast.walk(node.args[0])):
                    self._emit(node,
                               "arithmetic seed in jax.random."
                               f"{name}(...): nearby streams collide; "
                               "derive streams with fold_in "
                               "(repro.core.keys.stream_key)")
                return
            if name == "split":
                if node.args:
                    self._consume(node.args[0])
                return
            if name in _NONCONSUMING:
                return
            if node.args:  # a sampler: consumes its key argument
                self._consume(node.args[0])
            return
        # unknown call: passing a tracked key hands over ownership — treat
        # as consumption so `f(key); normal(key)` and double `f(key)` flag
        for arg in (*node.args, *(kw.value for kw in node.keywords)):
            e = _key_expr(arg)
            if e is not None and e in self.state:
                self._consume(arg)

    def _merge(self, other: dict):
        """Join two branch states: consumed on either path wins."""
        for k, (status, line) in other.items():
            cur = self.state.get(k)
            if cur is None or status == _CONSUMED:
                self.state[k] = (status, line)

    # -- statements --------------------------------------------------------
    def exec_block(self, stmts):
        for stmt in stmts:
            self.exec_stmt(stmt)

    def exec_stmt(self, stmt):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return  # analyzed as its own function
        if isinstance(stmt, ast.Assign):
            self.eval(stmt.value)
            producing = _is_key_producing(stmt.value, self.mod.imports)
            for target in stmt.targets:
                self._bind(target, producing)
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
            self.eval(stmt.value)
            if getattr(stmt, "target", None) is not None:
                self._bind(stmt.target, _is_key_producing(
                    stmt.value, self.mod.imports) if stmt.value else False)
        elif isinstance(stmt, (ast.Expr, ast.Return)):
            self.eval(stmt.value)
        elif isinstance(stmt, ast.If):
            self.eval(stmt.test)
            before = dict(self.state)
            self.exec_block(stmt.body)
            branch = self.state
            self.state = dict(before)
            self.exec_block(stmt.orelse)
            # a branch that leaves the function contributes nothing to the
            # fall-through state (if flag: return sample(key) / sample(key))
            body_ends = _terminates(stmt.body)
            if _terminates(stmt.orelse):
                if not body_ends:
                    self.state = branch
            elif not body_ends:
                self._merge(branch)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self.eval(stmt.iter)
            # second pass catches carry-over reuse of outer keys; the loop
            # target is rebound fresh before each pass
            for _ in range(2):
                self._bind(stmt.target, producing=False)
                self.exec_block(stmt.body)
            self.exec_block(stmt.orelse)
        elif isinstance(stmt, ast.While):
            for _ in range(2):
                self.eval(stmt.test)
                self.exec_block(stmt.body)
            self.exec_block(stmt.orelse)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                self.eval(item.context_expr)
            self.exec_block(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.exec_block(stmt.body)
            for handler in stmt.handlers:
                self.exec_block(handler.body)
            self.exec_block(stmt.orelse)
            self.exec_block(stmt.finalbody)
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self.eval(child)


def _terminates(stmts) -> bool:
    """True if a straight-line block always leaves the enclosing scope."""
    return any(
        isinstance(s, (ast.Return, ast.Raise, ast.Break, ast.Continue))
        for s in stmts
    )


def check_key_reuse(mod: ModuleInfo, graph: CallGraph) -> list:
    findings = []
    for fn in mod.functions.values():
        checker = _KeyChecker(mod)
        if isinstance(fn.node, ast.Module):
            body = [s for s in fn.node.body
                    if not isinstance(s, (ast.FunctionDef,
                                          ast.AsyncFunctionDef, ast.ClassDef))]
            checker.exec_block(body)
        else:
            checker.exec_block(fn.node.body)
        findings.extend(checker.findings)
    return findings


# --------------------------------------------------------------------------
# wire-boundary: outside core/transport.py (and the subsystems' own
# packages), nobody dispatches repro.agg kernels or repro.attacks
# primitives directly — consumers go through wire_noise / wire_corrupt /
# wire_aggregate so single-leaf byte parity and per-leaf keying stay in
# one audited place.
# --------------------------------------------------------------------------

_WIRE_FORBIDDEN = {
    "repro.agg.aggregate": "wire_aggregate",
    "repro.agg.registry.aggregate": "wire_aggregate",
    "repro.agg.kernel.ostat_pallas": "wire_aggregate",
    "repro.agg.ostat_pallas": "wire_aggregate",
    "repro.agg.kernel.dcq_pallas": "wire_aggregate",
    "repro.agg.dcq_pallas": "wire_aggregate",
    "repro.attacks.apply_attack": "wire_corrupt",
    "repro.attacks.registry.apply_attack": "wire_corrupt",
}
_WIRE_ALLOWED_PREFIXES = ("repro.core.transport", "repro.agg",
                          "repro.attacks", "repro.analyze")


def check_wire_boundary(mod: ModuleInfo, graph: CallGraph) -> list:
    if any(mod.modname == p or mod.modname.startswith(p + ".")
           for p in _WIRE_ALLOWED_PREFIXES):
        return []
    findings = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func, mod.imports)
        if d in _WIRE_FORBIDDEN:
            findings.append(Finding(
                rule="wire-boundary", path=mod.path, line=node.lineno,
                col=node.col_offset,
                message=f"direct call to {d} outside the transport wire; "
                        f"use repro.core.transport.{_WIRE_FORBIDDEN[d]}"))
    return findings


# --------------------------------------------------------------------------
# ledger-pairing: every noise-injection site must reach a spend /
# tree_spend_ledger record in the same protocol scope (the module closure
# of the site's transitive callers and callees). Noise without a matching
# ledger entry is unaccounted privacy spend.
# --------------------------------------------------------------------------

_NOISE_PRIMS = {
    "repro.core.transport.wire_noise",
    "repro.dist.grad_agg.add_dp_noise",
    "repro.core.dp.add_noise",
}
_NOISE_SHORT = {q.rsplit(".", 1)[-1] for q in _NOISE_PRIMS}
_LEDGER_CALL_NAMES = {"spend", "spend_tree", "tree_spend_ledger"}
_LEDGER_KEYWORDS = {"ledger_eps", "ledger_delta", "ledger"}


def _module_has_ledger_marker(mod: ModuleInfo) -> bool:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func, mod.imports)
        last = d.rsplit(".", 1)[-1] if d else ""
        if last in _LEDGER_CALL_NAMES or "spend_record" in last:
            return True
        if any(kw.arg in _LEDGER_KEYWORDS for kw in node.keywords):
            return True
    return False


def check_ledger_pairing(mod: ModuleInfo, graph: CallGraph) -> list:
    findings = []
    marker_cache: dict = {}

    def has_marker(modname: str) -> bool:
        if modname not in marker_cache:
            infos = [m for m in graph.modules.values()
                     if m.modname == modname]
            marker_cache[modname] = any(_module_has_ledger_marker(m)
                                        for m in infos)
        return marker_cache[modname]

    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func, mod.imports)
        if d is not None and "." not in d:
            d = f"{mod.modname}.{d}"  # unqualified call in defining module
        if d not in _NOISE_PRIMS:
            continue
        fn = graph.enclosing(mod, node)
        if fn.name in _NOISE_SHORT:
            continue  # the primitive's own definition
        scope = graph.scope_modules(fn) | {mod.modname}
        if not any(has_marker(m) for m in scope):
            findings.append(Finding(
                rule="ledger-pairing", path=mod.path, line=node.lineno,
                col=node.col_offset,
                message=f"noise injection via {d.rsplit('.', 1)[-1]} has no "
                        "spend/tree_spend_ledger record anywhere in its "
                        "protocol scope; record the budget this noise "
                        "spends (see core/dp.py)"))
    return findings


# --------------------------------------------------------------------------
# jit-purity: inside jit-reachable functions, flag host syncs (float(),
# int-from-traced is allowed, .item(), bool(), np.*) and Python branches
# on traced values — core/protocol.py documents this contract in prose;
# this rule enforces it.
# --------------------------------------------------------------------------

_HOST_CASTS = {"float", "bool"}


def _walk_own(fn_node):
    """Walk a function body without descending into nested defs/classes
    (they are separate FunctionInfos); lambdas belong to the enclosing
    function and are included."""
    stack = list(ast.iter_child_nodes(fn_node))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _traced_branch_test(test, imports) -> bool:
    """A test expression that calls into jax.numpy — a Python branch on a
    traced value, which fails (or silently constant-folds) under jit."""
    for sub in ast.walk(test):
        if isinstance(sub, ast.Call):
            d = dotted(sub.func, imports)
            if d and (d.startswith("jax.numpy.") or d.startswith("jnp.")):
                return True
    return False


def check_jit_purity(mod: ModuleInfo, graph: CallGraph) -> list:
    findings = []
    for fn in mod.functions.values():
        if fn.qual not in graph.jit_reachable:
            continue
        if isinstance(fn.node, ast.Module):
            continue
        for node in _walk_own(fn.node):
            if isinstance(node, ast.Call):
                d = dotted(node.func, mod.imports)
                if (isinstance(node.func, ast.Name)
                        and node.func.id in _HOST_CASTS and node.args
                        and not isinstance(node.args[0], ast.Constant)):
                    findings.append(Finding(
                        rule="jit-purity", path=mod.path, line=node.lineno,
                        col=node.col_offset,
                        message=f"host cast {node.func.id}(...) inside "
                                f"jit-reachable {fn.name!r}: forces a "
                                "device sync / tracer error under jit"))
                elif (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "item" and not node.args):
                    findings.append(Finding(
                        rule="jit-purity", path=mod.path, line=node.lineno,
                        col=node.col_offset,
                        message=f".item() inside jit-reachable {fn.name!r}: "
                                "host sync; keep values on device"))
                elif d and d.startswith("numpy."):
                    findings.append(Finding(
                        rule="jit-purity", path=mod.path, line=node.lineno,
                        col=node.col_offset,
                        message=f"numpy call {d}(...) inside jit-reachable "
                                f"{fn.name!r}: silently syncs to host; use "
                                "jax.numpy (or math.* on static shapes)"))
            elif isinstance(node, (ast.If, ast.While)):
                if _traced_branch_test(node.test, mod.imports):
                    findings.append(Finding(
                        rule="jit-purity", path=mod.path, line=node.lineno,
                        col=node.col_offset,
                        message="Python branch on a traced value inside "
                                f"jit-reachable {fn.name!r}: use jnp.where/"
                                "lax.cond"))
    return findings


# --------------------------------------------------------------------------
# pallas-static: pl.pallas_call grids and BlockSpec dims must be
# compile-time constants, and library code must not hardcode
# interpret=True (backend selection belongs to the caller / auto-detect).
# --------------------------------------------------------------------------

def _dynamic_dim(expr, imports) -> bool:
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Call):
            d = dotted(sub.func, imports)
            if d and (d.startswith("jax.numpy.") or d.startswith("jnp.")
                      or d.startswith("jax.")):
                return True
        if isinstance(sub, ast.Constant) and isinstance(sub.value, float):
            return True
    return False


def check_pallas_static(mod: ModuleInfo, graph: CallGraph) -> list:
    findings = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func, mod.imports)
        if d and d.rsplit(".", 1)[-1] == "pallas_call":
            for kw in node.keywords:
                if (kw.arg == "interpret"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value is True):
                    findings.append(Finding(
                        rule="pallas-static", path=mod.path,
                        line=kw.value.lineno, col=kw.value.col_offset,
                        message="hardcoded interpret=True in pallas_call: "
                                "thread an interpret flag / auto-detect "
                                "off-TPU instead"))
                elif kw.arg == "grid" and _dynamic_dim(kw.value, mod.imports):
                    findings.append(Finding(
                        rule="pallas-static", path=mod.path,
                        line=kw.value.lineno, col=kw.value.col_offset,
                        message="pallas_call grid must be built from "
                                "compile-time constants (ints, static "
                                "shapes), not traced values"))
        elif d and d.rsplit(".", 1)[-1] == "BlockSpec" and node.args:
            if _dynamic_dim(node.args[0], mod.imports):
                findings.append(Finding(
                    rule="pallas-static", path=mod.path,
                    line=node.args[0].lineno, col=node.args[0].col_offset,
                    message="BlockSpec block shape must be compile-time "
                            "constant ints"))
    return findings


# --------------------------------------------------------------------------
# retrace-hazard: a jitted function's static arguments are compile-cache
# keys. Passing a float-VALUED expression (float(x), x * 0.5) retraces on
# every distinct value, and an unhashable literal ([..], {..}) raises —
# both silently defeat the compile-once engine. Bare float constants are
# fine (one value, one trace): this rule polices call-site expressions,
# not declarations. The tuning knobs threaded by repro.agg.dispatch are
# ints end-to-end for exactly this reason.
# --------------------------------------------------------------------------

def _jit_static_spec(call, imports):
    """(static_argnums, static_argnames) sets from a jax.jit(...) call or
    a partial(jax.jit, ...) decorator; None when no statics declared."""
    nums, names = set(), set()
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, int):
                    nums.add(c.value)
        elif kw.arg == "static_argnames":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, str):
                    names.add(c.value)
    return (nums, names) if (nums or names) else None


def _local_jitted(mod: ModuleInfo) -> dict:
    """Module-local names bound to jitted callables with declared statics:
    ``f = jax.jit(g, static_argnums=...)`` assignments and
    ``@partial(jax.jit, static_argnames=...)`` decorated defs."""
    jitted = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            d = dotted(node.value.func, mod.imports)
            if d and d.rsplit(".", 1)[-1] == "jit":
                spec = _jit_static_spec(node.value, mod.imports)
                if spec:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            jitted[t.id] = spec
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if not (isinstance(dec, ast.Call) and dec.args):
                    continue
                dd = dotted(dec.func, mod.imports)
                inner = dotted(dec.args[0], mod.imports)
                if (dd and dd.rsplit(".", 1)[-1] == "partial" and inner
                        and inner.rsplit(".", 1)[-1] == "jit"):
                    spec = _jit_static_spec(dec, mod.imports)
                    if spec:
                        jitted[node.name] = spec
    return jitted


def _static_hazard(expr, imports) -> str | None:
    """Why ``expr`` is hazardous as a static argument, or None."""
    if isinstance(expr, ast.List):
        return "unhashable list literal"
    if isinstance(expr, ast.Dict):
        return "unhashable dict literal"
    if isinstance(expr, ast.Set):
        return "unhashable set literal"
    if isinstance(expr, ast.Call):
        if isinstance(expr.func, ast.Name) and expr.func.id == "float":
            return "float(...) value (retraces per value)"
    if isinstance(expr, ast.BinOp):
        for sub in ast.walk(expr):
            if (isinstance(sub, ast.Constant)
                    and isinstance(sub.value, float)):
                return "float-valued expression (retraces per value)"
    return None


def check_retrace_hazard(mod: ModuleInfo, graph: CallGraph) -> list:
    jitted = _local_jitted(mod)
    if not jitted:
        return []
    findings = []
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id in jitted):
            continue
        nums, names = jitted[node.func.id]
        slots = [(a, f"positional static arg {i}") for i, a in
                 enumerate(node.args) if i in nums]
        slots += [(kw.value, f"static arg {kw.arg!r}") for kw in
                  node.keywords if kw.arg in names]
        for expr, where in slots:
            why = _static_hazard(expr, mod.imports)
            if why:
                findings.append(Finding(
                    rule="retrace-hazard", path=mod.path, line=expr.lineno,
                    col=expr.col_offset,
                    message=f"{why} passed as {where} of jitted "
                            f"{node.func.id!r}: static args are compile-"
                            "cache keys — pass hashable ints/strs"))
    return findings


# --------------------------------------------------------------------------

register(Rule(
    name="key-reuse", check=check_key_reuse,
    doc="a consumed jax.random key may not be consumed again without "
        "split/fold_in; arithmetic PRNGKey seeds collide across streams"))
register(Rule(
    name="wire-boundary", check=check_wire_boundary,
    doc="outside core/transport.py, use wire_noise/wire_corrupt/"
        "wire_aggregate instead of raw agg/attacks dispatch"))
register(Rule(
    name="ledger-pairing", check=check_ledger_pairing,
    doc="every noise-injection site must reach a spend/tree_spend_ledger "
        "record in its protocol scope", uses_callgraph=True))
register(Rule(
    name="jit-purity", check=check_jit_purity,
    doc="no float()/bool()/.item()/np.* host syncs or Python branches on "
        "traced values inside jit-reachable functions",
    uses_callgraph=True))
register(Rule(
    name="pallas-static", check=check_pallas_static,
    doc="pallas_call grid/BlockSpec dims must be compile-time constants; "
        "no hardcoded interpret=True in library code"))
register(Rule(
    name="retrace-hazard", check=check_retrace_hazard,
    doc="no float-valued or unhashable expressions in the static-argument "
        "slots of jitted calls: statics are compile-cache keys and "
        "silently retrace (or raise) per value"))
# The check lives in the engine, not here: whether a suppression matched
# anything is only known after every other rule has run and the engine
# has done the suppression matching. This registration gives the rule a
# stable name for --rules/--list-rules and lets ``# repro:
# allow(<rule>, unused-suppression) — <why>`` self-waive a deliberately
# prophylactic marker.
register(Rule(
    name="unused-suppression", check=lambda mod, graph: [],
    doc="every # repro: allow(<rule>) must silence at least one finding "
        "of that rule; a waiver whose rule ran but never fired is stale "
        "and must be removed (suppress with allow(<rule>, "
        "unused-suppression) when intentionally prophylactic)"))
