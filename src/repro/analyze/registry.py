"""Rule registry, mirroring the repro.agg / repro.attacks registry style.

A :class:`Rule` pairs a stable name with a check callable. Checks run
per-module with the shared :class:`~repro.analyze.callgraph.CallGraph`
in hand and yield :class:`Finding`s; the engine owns suppression
matching and reporting, so rules stay pure detectors.
"""
from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation at a source location."""
    rule: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False
    reason: str = ""

    def to_dict(self) -> dict:
        d = {"rule": self.rule, "path": self.path, "line": self.line,
             "col": self.col, "message": self.message}
        if self.suppressed:
            d["reason"] = self.reason
        return d


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered analysis pass.

    ``check(module, graph)`` yields findings for one module; ``doc`` is
    the one-line description shown by ``--list-rules`` and the README
    table; ``uses_callgraph`` marks rules that need whole-tree context
    (reported per-module regardless).
    """
    name: str
    check: Callable
    doc: str
    uses_callgraph: bool = False


_REGISTRY: dict = {}


def register(rule: Rule) -> Rule:
    if rule.name in _REGISTRY:
        raise ValueError(f"rule {rule.name!r} already registered")
    _REGISTRY[rule.name] = rule
    return rule


def unregister(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_rule(name: str) -> Rule:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown rule {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def registered() -> list:
    return sorted(_REGISTRY)
