"""``python -m repro.analyze`` / ``repro-analyze``: the CI gate.

Exits 1 when any active (non-suppressed) finding remains, 0 on a clean
tree. ``--json`` writes the machine-readable report CI uploads as an
artifact.
"""
from __future__ import annotations

import argparse
import sys

from repro.analyze.engine import analyze_paths, write_json
from repro.analyze.registry import get_rule, registered


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro-analyze",
        description="privacy- and trace-safety static analysis for the "
                    "repro tree")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to analyze (default: src)")
    ap.add_argument("--rules", default="",
                    help="comma-separated subset of rules to run")
    ap.add_argument("--json", dest="json_out", default="",
                    help="also write a JSON report to this path")
    ap.add_argument("--list-rules", action="store_true",
                    help="print registered rules and exit")
    ap.add_argument("--include-fixtures", action="store_true",
                    help="analyze tests/fixtures trees too (they hold "
                    "seeded violations and are skipped by default)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress the human report (exit code only)")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for name in registered():
            print(f"{name:16s} {get_rule(name).doc}")
        return 0
    rules = [r.strip() for r in args.rules.split(",") if r.strip()] or None
    report = analyze_paths(args.paths or ["src"], rules=rules,
                           include_fixtures=args.include_fixtures)
    if args.json_out:
        write_json(report, args.json_out)
    if not args.quiet:
        print(report.human())
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
