"""Distributed Composite Quantile (DCQ) estimation — paper §3, eq. (3.1)/(4.4).

Given m machine-local statistics ``Y_1..Y_m`` whose sampling distribution is
(asymptotically) ``mu + scale * Z`` with ``Z ~ G`` (standard normal here),
the DCQ estimator sharpens the coordinate-wise median with a composite
quantile correction:

    med  = med{Y_j}
    S    = sum_k sum_j [ I(Y_j <= med + scale*Delta_k) - kappa_k ]
    DCQ  = med - scale * S / (m * sum_k g(Delta_k))

with ``kappa_k = k/(K+1)`` and ``Delta_k = G^{-1}(kappa_k)``.

Asymptotics (Thm 3.1): sqrt(m)(DCQ - mu)/sigma_cq -> N(0,1) with
``sigma_cq^2 = D_K * scale^2``. NOTE: the paper's printed D_K omits the
``- kappa_{k1} kappa_{k2}`` centring term; the centred form (used in
Thm 4.3's V_{g,vr} and required to reproduce ARE 3/pi ~= 0.955) is

    D_K = sum_{k1,k2} [min(k1,k2)/(K+1) - k1*k2/(K+1)^2] / {sum_k psi(Delta_k)}^2.

We implement the centred form (see DESIGN.md §1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtri  # Psi^{-1}
from jax.scipy.stats import norm


def quantile_levels(K: int) -> jnp.ndarray:
    """kappa_k = k/(K+1), k = 1..K."""
    return jnp.arange(1, K + 1, dtype=jnp.float64 if jax.config.jax_enable_x64
                      else jnp.float32) / (K + 1)


def quantile_knots(K: int) -> jnp.ndarray:
    """Delta_k = Psi^{-1}(kappa_k) for the standard-normal reference G."""
    return ndtri(quantile_levels(K))


def d_k(K: int) -> float:
    """Variance inflation D_K of the DCQ estimator vs the mean (centred form).

    ARE(DCQ vs mean) = 1/D_K ; K -> inf gives D_K -> pi/3 (ARE 3/pi ~ 0.955).
    """
    kappa = quantile_levels(K)
    delta = quantile_knots(K)
    num = (jnp.minimum(kappa[:, None], kappa[None, :])
           - kappa[:, None] * kappa[None, :]).sum()
    den = norm.pdf(delta).sum() ** 2
    return float(num / den)


def are_dcq(K: int) -> float:
    """Asymptotic relative efficiency of DCQ vs the sample mean."""
    return 1.0 / d_k(K)


ARE_MEDIAN = 2.0 / jnp.pi  # ~0.637, quoted in the paper §1


def dcq(values: jnp.ndarray, scale: jnp.ndarray, K: int = 10,
        axis: int = 0) -> jnp.ndarray:
    """Coordinate-wise DCQ estimate over the machine axis.

    Args:
      values: array with the machine axis at ``axis`` (e.g. (m, p)).
      scale: per-coordinate standard deviation of one machine's statistic
        (shape = values.shape without ``axis``). In the protocol this is
        ``sigma_hat_b / sqrt(n)`` etc. — the caller supplies the final scale.
      K: number of composite quantile levels.
      axis: machine axis.

    Returns: DCQ estimate, shape = values.shape without ``axis``.
    """
    values = jnp.moveaxis(values, axis, 0)
    m = values.shape[0]
    med = jnp.median(values, axis=0)
    delta = quantile_knots(K).astype(values.dtype)          # (K,)
    kappa = quantile_levels(K).astype(values.dtype)         # (K,)
    # thresholds: med + scale * Delta_k  -> (K, ...)
    thr = med[None] + scale[None] * delta.reshape((K,) + (1,) * med.ndim)
    ind = (values[None, :] <= thr[:, None]).astype(values.dtype)  # (K, m, ...)
    s = (ind - kappa.reshape((K,) + (1,) * values.ndim)).sum(axis=(0, 1))
    denom = m * norm.pdf(delta).sum().astype(values.dtype)
    return med - scale * s / denom


def dcq_with_sigma(values: jnp.ndarray, scale: jnp.ndarray, K: int = 10,
                   axis: int = 0):
    """DCQ estimate plus its asymptotic s.d. sigma_cq/sqrt(m) (Thm 3.1)."""
    est = dcq(values, scale, K=K, axis=axis)
    m = values.shape[axis]
    sd = jnp.sqrt(jnp.asarray(d_k(K), values.dtype)) * scale / jnp.sqrt(m)
    return est, sd


@functools.partial(jax.jit, static_argnames=("K", "axis"))
def dcq_jit(values, scale, K: int = 10, axis: int = 0):
    return dcq(values, scale, K=K, axis=axis)
