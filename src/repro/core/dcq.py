"""DEPRECATED shim — the DCQ estimator and its efficiency theory moved to
``repro.agg.reference`` (paper §3, eq. (3.1)/(4.4); centred D_K form, see
the docstrings there and DESIGN.md §1).

Import from ``repro.agg`` in new code; this module re-exports the
historical names so pinned imports keep working.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.core.dcq is deprecated; use repro.agg "
    "(repro.agg.dcq / repro.agg.reference) instead",
    DeprecationWarning, stacklevel=2)

from repro.agg.reference import (ARE_MEDIAN, are_dcq, d_k, dcq,  # noqa: F401,E402
                                 dcq_jit, dcq_with_sigma, quantile_knots,
                                 quantile_levels)

__all__ = ["quantile_levels", "quantile_knots", "d_k", "are_dcq",
           "ARE_MEDIAN", "dcq", "dcq_with_sigma", "dcq_jit"]
