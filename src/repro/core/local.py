"""Machine-local computations: the local M-estimator solve and the
center's variance estimators (Lemma 4.2, eqs. 4.10 and 4.16).

All run on-device with ``lax`` control flow so they can be vmapped over
machines and shard_mapped over the mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.losses import MEstimationProblem


def newton_solve(problem: MEstimationProblem, theta0: jnp.ndarray,
                 X: jnp.ndarray, y: jnp.ndarray, steps: int = 25,
                 ridge: float = 1e-9) -> jnp.ndarray:
    """Damped-Newton solve of the local M-estimation problem.

    Fixed step count (lax.fori_loop) so it is jit/vmap friendly; with the
    convex GLM losses 25 steps is far past quadratic-convergence tolerance.
    """
    p = theta0.shape[0]
    eye = jnp.eye(p, dtype=theta0.dtype)

    def body(_, theta):
        g = problem.grad(theta, X, y)
        h = problem.hessian(theta, X, y) + ridge * eye
        step = jnp.linalg.solve(h, g)
        # cheap trust region: cap the Newton step length at 5
        norm = jnp.linalg.norm(step)
        step = jnp.where(norm > 5.0, step * (5.0 / norm), step)
        return theta - step

    return jax.lax.fori_loop(0, steps, body, theta0)


def sandwich_diag_variance(problem: MEstimationProblem, theta: jnp.ndarray,
                           X: jnp.ndarray, y: jnp.ndarray,
                           ridge: float = 1e-9) -> jnp.ndarray:
    """Lemma 4.2: diag of H^{-1} Cov(grad) H^{-1} at theta, from one shard.

    This estimates (sigma_1^2, ..., sigma_p^2), the asymptotic variance of
    sqrt(n) (theta_hat_j - theta*).
    """
    n, p = X.shape
    h = problem.hessian(theta, X, y) + ridge * jnp.eye(p, dtype=X.dtype)
    hinv = jnp.linalg.inv(h)
    g = problem.per_sample_grads(theta, X, y)          # (n, p)
    gc = g - g.mean(axis=0, keepdims=True)
    cov = gc.T @ gc / n                                 # (p, p)
    return jnp.diag(hinv @ cov @ hinv)


def grad_coordinate_variance(problem: MEstimationProblem, theta: jnp.ndarray,
                             X: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Per-coordinate variance of nabla f_l(X_i, theta) (§4.1.2). This is the
    variance of sqrt(n) * nabla F_jl(theta) before DP noise."""
    return problem.grad_variance(theta, X, y)


def newton_dir_variance(problem: MEstimationProblem, theta: jnp.ndarray,
                        X: jnp.ndarray, y: jnp.ndarray,
                        g_cq: jnp.ndarray, ridge: float = 1e-9) -> jnp.ndarray:
    """Eq. (4.10): per-coordinate variance of sqrt(n) h_jl^(1) (w/o noise).

    Uses identity (4.9): Var_l = Var_i[ (H0^{-1} hess_i H0^{-1} g_cq)_l ].
    """
    n, p = X.shape
    h0 = problem.hessian(theta, X, y) + ridge * jnp.eye(p, dtype=X.dtype)
    hinv = jnp.linalg.inv(h0)
    u = hinv @ g_cq                                     # (p,)
    w = problem.point_hess_weight(theta, X, y)          # (n,)
    # hess_i @ u = w_i * x_i * (x_i . u)  (GLM structure, avoids n*p*p)
    xu = X @ u                                          # (n,)
    hi_u = (w * xu)[:, None] * X                        # (n, p)
    t = hi_u @ hinv.T                                   # (n, p): H0^{-1} hess_i u
    return jnp.var(t, axis=0)


def bfgs_dir_variance(problem: MEstimationProblem, theta: jnp.ndarray,
                      X: jnp.ndarray, y: jnp.ndarray,
                      v_apply, g_os: jnp.ndarray,
                      ridge: float = 1e-9) -> jnp.ndarray:
    """Eq. (4.16): per-coordinate variance of sqrt(n) h_jl^(3) (w/o noise).

    ``v_apply(x, transpose)`` applies V^(1) (rank-1-structured) in O(p).
    Var_l = Var_i[ (V^T H0^{-1} hess_i H0^{-1} V g_os)_l ].
    """
    n, p = X.shape
    h0 = problem.hessian(theta, X, y) + ridge * jnp.eye(p, dtype=X.dtype)
    hinv = jnp.linalg.inv(h0)
    u = hinv @ v_apply(g_os, transpose=False)           # H0^{-1} V g_os
    w = problem.point_hess_weight(theta, X, y)
    xu = X @ u
    hi_u = (w * xu)[:, None] * X                        # (n, p)
    t = hi_u @ hinv.T                                   # H0^{-1} hess_i u, (n, p)
    t = jax.vmap(lambda row: v_apply(row, transpose=True))(t)
    return jnp.var(t, axis=0)
