"""The paper's contribution: DCQ aggregation + DP quasi-Newton protocol.

Aggregation lives in ``repro.agg`` (registry + reference + Pallas kernel)
and the threat models in ``repro.attacks``; the historical names
(``aggregate``, the ``byzantine`` module) are still reachable here but
resolve lazily through their deprecated shims — ``import repro.core``
itself stays warning-free, only touching the legacy names warns.
"""
from repro.agg import dcq, dcq_with_sigma, d_k, are_dcq, ARE_MEDIAN
from repro.core.protocol import (DPQNProtocol, ProtocolArrays, ProtocolResult,
                                 ProtocolTreeArrays, calibrate_sigma_base,
                                 monte_carlo_mrse, n_transmissions,
                                 protocol_rounds, protocol_tree_rounds,
                                 round_budget, transmission_names,
                                 vmap_machines)
from repro.core.losses import get_problem, PROBLEMS
from repro.core import dp, bfgs, local, baselines, transport

__all__ = ["dcq", "dcq_with_sigma", "d_k", "are_dcq", "ARE_MEDIAN",
           "aggregate", "DPQNProtocol", "ProtocolArrays", "ProtocolResult",
           "ProtocolTreeArrays", "calibrate_sigma_base",
           "protocol_rounds", "protocol_tree_rounds", "round_budget",
           "transmission_names",
           "n_transmissions", "monte_carlo_mrse", "vmap_machines",
           "get_problem", "PROBLEMS", "dp", "bfgs", "byzantine", "local",
           "baselines", "transport"]


def __getattr__(name):
    # PEP 562 lazy resolution of the deprecated legacy names: the shim
    # modules fire a DeprecationWarning on first import, so they must not
    # load as a side effect of `import repro.core`.
    if name == "aggregate":
        from repro.core.robust_agg import aggregate
        return aggregate
    if name == "byzantine":
        import importlib
        return importlib.import_module("repro.core.byzantine")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
