"""The paper's contribution: DCQ aggregation + DP quasi-Newton protocol.

Aggregation lives in ``repro.agg`` (registry + reference + Pallas kernel);
the historical names are re-exported here unchanged.
"""
from repro.agg import dcq, dcq_with_sigma, d_k, are_dcq, ARE_MEDIAN
from repro.core.robust_agg import aggregate
from repro.core.protocol import (DPQNProtocol, ProtocolArrays, ProtocolResult,
                                 ProtocolTreeArrays, calibrate_sigma_base,
                                 monte_carlo_mrse, n_transmissions,
                                 protocol_rounds, protocol_tree_rounds,
                                 round_budget, transmission_names,
                                 vmap_machines)
from repro.core.losses import get_problem, PROBLEMS
from repro.core import dp, bfgs, byzantine, local, baselines, transport

__all__ = ["dcq", "dcq_with_sigma", "d_k", "are_dcq", "ARE_MEDIAN",
           "aggregate", "DPQNProtocol", "ProtocolArrays", "ProtocolResult",
           "ProtocolTreeArrays", "calibrate_sigma_base",
           "protocol_rounds", "protocol_tree_rounds", "round_budget",
           "transmission_names",
           "n_transmissions", "monte_carlo_mrse", "vmap_machines",
           "get_problem", "PROBLEMS", "dp", "bfgs", "byzantine", "local",
           "baselines", "transport"]
