"""The paper's contribution: DCQ aggregation + DP quasi-Newton protocol."""
from repro.core.dcq import dcq, dcq_with_sigma, d_k, are_dcq, ARE_MEDIAN
from repro.core.robust_agg import aggregate
from repro.core.protocol import DPQNProtocol, ProtocolResult
from repro.core.losses import get_problem, PROBLEMS
from repro.core import dp, bfgs, byzantine, local, baselines

__all__ = ["dcq", "dcq_with_sigma", "d_k", "are_dcq", "ARE_MEDIAN",
           "aggregate", "DPQNProtocol", "ProtocolResult", "get_problem",
           "PROBLEMS", "dp", "bfgs", "byzantine", "local", "baselines"]
