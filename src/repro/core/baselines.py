"""Comparison strategies the paper argues against (§1.2(1), §6):

  * ``newton_estimator``      — distributed one-step Newton (Huang & Huo
    2019 style): every machine transmits its FULL p x p Hessian + gradient.
    Under DP each of the p^2 entries needs noise, so the per-round privacy
    cost is ~p x that of a vector round — the paper's key budget argument.
  * ``gd_estimator``          — multi-round distributed gradient descent
    (Jordan et al. 2019 style): T rounds of one p-vector each; the privacy
    budget grows linearly in T.

Both support the same robust aggregation + Byzantine attack interface so
benchmarks/comm_cost.py and mrse_vs_eps.py can compare like-for-like.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro import attacks
from repro.configs.base import ProtocolConfig
from repro.core import dp, local
from repro.core.losses import MEstimationProblem
from repro.core.transport import wire_aggregate, wire_corrupt


@dataclasses.dataclass
class BaselineResult:
    theta: jnp.ndarray
    accountant: dp.PrivacyAccountant
    bytes_per_machine: int  # transmitted payload (fp32) for comm comparison


def newton_estimator(problem: MEstimationProblem, cfg: ProtocolConfig,
                     key: jax.Array, X: jnp.ndarray, y: jnp.ndarray,
                     byz_mask: Optional[jnp.ndarray] = None,
                     attack: str = "scale", attack_factor: float = -3.0,
                     theta0: Optional[jnp.ndarray] = None) -> BaselineResult:
    """One-step Newton with full-Hessian transmission (2 rounds: theta, then
    grad+Hessian). DP noise on the Hessian is calibrated for a p^2-dim
    query: sensitivity grows by sqrt(p) vs a vector (same per-entry tails),
    which is exactly the budget blow-up the paper criticises."""
    m1, n, p = X.shape
    eps_r, delta_r = cfg.eps / 2, cfg.delta / 2
    acct = dp.PrivacyAccountant()
    if byz_mask is None:
        byz_mask = jnp.zeros((m1,), bool)
    else:
        byz_mask = jnp.concatenate([jnp.zeros((1,), bool), byz_mask])
    keys = jax.random.split(key, 6)
    if theta0 is None:
        theta0 = jnp.zeros((p,), X.dtype)

    # Round 1: local estimators (same as protocol R1, median init)
    theta_local = jax.vmap(lambda Xi, yi: local.newton_solve(
        problem, theta0, Xi, yi, steps=cfg.newton_steps))(X, y)
    # lambda_s = None means "calibrate locally" in the protocol; the baseline
    # uses the median local-Hessian eigenvalue as its single constant.
    if cfg.lambda_s is None:
        lam = float(jnp.median(jax.vmap(lambda Xi, yi, ti: jnp.clip(
            jnp.linalg.eigvalsh(problem.hessian(ti, Xi, yi))[0],
            1e-3, None))(X, y, theta_local)))
    else:
        lam = cfg.lambda_s
    s1 = dp.s1_theta(p, n, cfg.gammas[0], eps_r, delta_r, lam, cfg.tail)
    theta_dp = theta_local if cfg.noiseless else dp.add_noise(keys[0], theta_local, s1)
    theta_dp = wire_corrupt(keys[1], theta_dp, byz_mask, attack=attack,
                            factor=attack_factor, round_idx=0)
    acct.spend("R1 theta", eps_r, delta_r, s1)
    theta_init = jnp.median(theta_dp, axis=0)

    # Round 2: gradient (p) + full Hessian (p^2) transmission
    grads = jax.vmap(lambda Xi, yi: problem.grad(theta_init, Xi, yi))(X, y)
    hesss = jax.vmap(lambda Xi, yi: problem.hessian(theta_init, Xi, yi))(X, y)
    s2g = dp.s2_grad(p, n, cfg.gammas[1], eps_r / 2, delta_r / 2, cfg.tail)
    # Hessian = p^2-dimensional query: Lemma 4.4 sensitivity scales sqrt(dim)
    s2h = dp.s2_grad(p * p, n, cfg.gammas[1], eps_r / 2, delta_r / 2, cfg.tail)
    if not cfg.noiseless:
        grads = dp.add_noise(keys[2], grads, s2g)
        hesss = dp.add_noise(keys[3], hesss, s2h)
    # final transmission of this 2-round baseline: ramping attacks hit at
    # terminal strength (round_idx would otherwise freeze them mid-ramp
    # and misreport the baseline as artificially robust)
    last = attacks.N_PROTOCOL_ROUNDS - 1
    grads = wire_corrupt(keys[4], grads, byz_mask, attack=attack,
                         factor=attack_factor, round_idx=last)
    hesss = wire_corrupt(keys[5], hesss, byz_mask, attack=attack,
                         factor=attack_factor, round_idx=last)
    acct.spend("R2 grad", eps_r / 2, delta_r / 2, s2g)
    acct.spend("R2 hessian", eps_r / 2, delta_r / 2, s2h)

    g_agg = wire_aggregate(grads, "median")
    h_agg = wire_aggregate(hesss, "median")
    # symmetrise + ridge for invertibility under heavy DP noise
    h_agg = 0.5 * (h_agg + h_agg.T) + 1e-6 * jnp.eye(p, dtype=X.dtype)
    # guard: project onto PD cone (noise can flip eigenvalues when p large)
    evals, evecs = jnp.linalg.eigh(h_agg)
    evals = jnp.maximum(evals, 1e-3)
    h_pd = (evecs * evals) @ evecs.T
    theta = theta_init - jnp.linalg.solve(h_pd, g_agg)
    return BaselineResult(theta=theta, accountant=acct,
                          bytes_per_machine=4 * (p + p + p * p))


def gd_estimator(problem: MEstimationProblem, cfg: ProtocolConfig,
                 key: jax.Array, X: jnp.ndarray, y: jnp.ndarray,
                 rounds: int = 20, lr: float = 1.0,
                 byz_mask: Optional[jnp.ndarray] = None,
                 attack: str = "scale", attack_factor: float = -3.0,
                 theta0: Optional[jnp.ndarray] = None) -> BaselineResult:
    """T-round distributed GD; budget eps/T per round so total matches."""
    m1, n, p = X.shape
    eps_r, delta_r = cfg.eps / rounds, cfg.delta / rounds
    acct = dp.PrivacyAccountant()
    if byz_mask is None:
        byz_mask = jnp.zeros((m1,), bool)
    else:
        byz_mask = jnp.concatenate([jnp.zeros((1,), bool), byz_mask])
    theta = jnp.zeros((p,), X.dtype) if theta0 is None else theta0
    s2 = dp.s2_grad(p, n, cfg.gammas[1], eps_r, delta_r, cfg.tail)
    keys = jax.random.split(key, 2 * rounds)
    for t in range(rounds):
        grads = jax.vmap(lambda Xi, yi: problem.grad(theta, Xi, yi))(X, y)
        if not cfg.noiseless:
            grads = dp.add_noise(keys[2 * t], grads, s2)
        # round_idx = t: ramping attacks climb over the first protocol-
        # length window of GD rounds, then clamp at full strength
        grads = wire_corrupt(keys[2 * t + 1], grads, byz_mask, attack=attack,
                             factor=attack_factor, round_idx=t)
        g = wire_aggregate(grads, "median")
        theta = theta - lr * g
        acct.spend(f"GD round {t}", eps_r, delta_r, s2)
    return BaselineResult(theta=theta, accountant=acct,
                          bytes_per_machine=4 * p * rounds)
