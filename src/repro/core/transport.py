"""Pytree wire transport: the protocol's noise / corrupt / aggregate
primitives over arbitrary gradient pytrees.

Algorithm 1's wire model is: a per-machine statistic is stacked along a
leading machine axis, DP noise is added per machine, Byzantine corruption
replaces the selected rows, and a robust aggregator reduces the machine
axis. At p=10 the statistic is one flat vector; at model scale it is a
parameter pytree. This module is the single implementation of that wire
for both regimes:

  * every primitive takes ``values`` as EITHER a single ``(m, ...)`` array
    OR a pytree of them, and dispatches per leaf;
  * each leaf is flattened to ``(m, d_leaf)`` at the aggregation boundary
    and unflattened afterwards — the registry kernels (repro.agg) only
    ever see 2-D machine-by-coordinate tiles, so the batched Pallas
    order-statistics path applies unchanged to every leaf of a model;
  * noise scales (``sigma``) and aggregation scales may be scalars,
    per-machine ``(m,)`` vectors, or pytrees matching ``values`` — the
    per-leaf DP calibration (core/dp.py) feeds pytree sigmas so each
    leaf's Gaussian mechanism uses a sensitivity computed from ITS OWN
    dimension;
  * corruption routes through the ``repro.attacks`` registry per leaf,
    with the transmission index forwarded to round-aware attacks.

Byte-parity invariant (tested in tests/test_protocol_pytree.py): a
SINGLE-leaf tree consumes the transmission PRNG key directly — no
``jax.random.split`` — so the flat ``(m, p)`` protocol refactored onto
these primitives reproduces its pre-refactor draws bit-for-bit, per key.
Multi-leaf trees split the key once per leaf (machines never share leaf
randomness).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import agg, attacks

__all__ = ["tree_leaf_dims", "tree_size", "leaf_paths", "is_single_leaf",
           "wire_noise", "wire_corrupt", "wire_aggregate", "tree_axpy",
           "tree_sub", "tree_add", "tree_scale", "tree_dot"]


# ------------------------------------------------------------ tree algebra

def tree_dot(a: Any, b: Any) -> jnp.ndarray:
    """Global inner product <a, b> over matching pytrees."""
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return sum(jnp.vdot(x, y) for x, y in zip(la, lb))


def tree_add(a: Any, b: Any) -> Any:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a: Any, b: Any) -> Any:
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(c, a: Any) -> Any:
    return jax.tree_util.tree_map(lambda x: c * x, a)


def tree_axpy(c, x: Any, y: Any) -> Any:
    """y + c * x, leaf-wise."""
    return jax.tree_util.tree_map(lambda xx, yy: yy + c * xx, x, y)


# ------------------------------------------------------------- leaf layout

def tree_leaf_dims(tree: Any, machine_axis: bool = False) -> Any:
    """Per-leaf flat dimension d_leaf (ints, same tree structure).

    With ``machine_axis=True`` the leading axis is the machine stack and
    is excluded — d_leaf is the dimension of ONE machine's transmission.
    """
    def dim(leaf):
        shape = tuple(leaf.shape)[1:] if machine_axis else tuple(leaf.shape)
        return int(math.prod(shape)) if shape else 1
    return jax.tree_util.tree_map(dim, tree)


def tree_size(tree: Any, machine_axis: bool = False) -> int:
    """Total transmitted dimension: sum of per-leaf dims."""
    return sum(jax.tree_util.tree_leaves(
        tree_leaf_dims(tree, machine_axis=machine_axis)))


def leaf_paths(tree: Any) -> list:
    """Stable human-readable leaf names ("layers/w_q", ...) in
    tree_leaves order — the per-leaf spend-ledger keys."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, _leaf in flat:
        out.append("/".join(str(getattr(k, "key", getattr(k, "idx", "")))
                            for k in kp) or "theta")
    return out


def is_single_leaf(tree: Any) -> bool:
    return len(jax.tree_util.tree_leaves(tree)) == 1


def _leaf_keys(key: jax.Array, n: int):
    """One PRNG key per leaf. Single-leaf trees consume ``key`` directly:
    this is the byte-parity rule that makes the flat (m, p) protocol a
    strict special case of the pytree wire."""
    return [key] if n == 1 else list(jax.random.split(key, n))


def _match(tree: Any, value: Any) -> list:
    """Broadcast ``value`` (scalar / per-machine vector / matching pytree)
    to one entry per leaf of ``tree``, in tree_leaves order."""
    n = len(jax.tree_util.tree_leaves(tree))
    if jax.tree_util.tree_structure(value, is_leaf=lambda x: x is None) \
            == jax.tree_util.tree_structure(tree):
        return jax.tree_util.tree_leaves(value)
    return [value] * n


def _bcast_sigma(sig, leaf):
    """Scalar sigma, or a per-machine (m,) sigma vector broadcast over the
    leaf's payload dims."""
    sig = jnp.asarray(sig, leaf.dtype)
    if sig.ndim == 1 and leaf.ndim >= 1 and sig.shape[0] == leaf.shape[0]:
        return sig.reshape((-1,) + (1,) * (leaf.ndim - 1))
    return sig


# ----------------------------------------------------------- the wire ops

def wire_noise(key: jax.Array, values: Any, sigma: Any,
               noiseless: bool = False) -> Any:
    """Gaussian mechanism on the wire: every machine row of every leaf
    gets an independent draw. ``sigma``: scalar, per-machine ``(m,)``
    vector, or a pytree of those matching ``values``."""
    if noiseless:
        return values
    leaves, treedef = jax.tree_util.tree_flatten(values)
    sigs = _match(values, sigma)
    keys = _leaf_keys(key, len(leaves))
    noisy = [leaf + _bcast_sigma(s, leaf)
             * jax.random.normal(k, leaf.shape, leaf.dtype)
             for leaf, s, k in zip(leaves, sigs, keys)]
    return jax.tree_util.tree_unflatten(treedef, noisy)


def wire_corrupt(key: Optional[jax.Array], values: Any,
                 byz_mask: Optional[jnp.ndarray], attack: str = "scale",
                 factor=-3.0, round_idx: int = 0) -> Any:
    """Byzantine corruption of the selected machine rows on every leaf,
    through the ``repro.attacks`` registry (omniscient attacks see each
    leaf's full machine axis; round-aware attacks get ``round_idx``)."""
    if byz_mask is None or attacks.resolve(attack) == "none":
        return values
    leaves, treedef = jax.tree_util.tree_flatten(values)
    keys = _leaf_keys(key, len(leaves)) if key is not None \
        else [None] * len(leaves)
    out = [attacks.apply_attack(leaf, byz_mask, attack=attack,
                                factor=factor, key=k, round_idx=round_idx)
           for leaf, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def wire_aggregate(values: Any, method: str, scale: Any = None,
                   K: int = 10, trim_beta: float = 0.2,
                   backend: Optional[str] = None,
                   fill: Optional[Any] = None) -> Any:
    """Robust aggregation of the leading machine axis, per leaf, through
    the ``repro.agg`` registry.

    Flatten/unflatten boundary: every pytree leaf ``(m, *payload)`` is
    reshaped to ``(m, d_leaf)`` before dispatch — the registry's batched
    kernels only ever see 2-D tiles — and the aggregate is reshaped back
    to ``payload``. Single arrays pass through at their native shape
    (bit-identical to the historical flat path).

    ``fill`` (the serving path): when given, the leading axis is a
    fixed-capacity ring buffer whose first ``fill`` (traced scalar) rows
    are valid, and dispatch routes to ``repro.agg.aggregate_masked`` —
    byte-identical to aggregating the dense unpadded prefix, at one trace
    per capacity. On the masked path ``backend`` selects between the
    masked forms (``"sort"`` / ``"bisect"``); ``None`` consults the
    measured dispatch table (``repro.agg.dispatch``).
    """
    if fill is not None:
        if not isinstance(values, (dict, list, tuple)):
            return agg.aggregate_masked(values, fill, method=method,
                                        scale=scale, K=K,
                                        trim_beta=trim_beta, axis=0,
                                        backend=backend)
        leaves, treedef = jax.tree_util.tree_flatten(values)
        out = [agg.aggregate_masked(leaf, fill, method=method, scale=sc,
                                    K=K, trim_beta=trim_beta, axis=0,
                                    backend=backend)
               for leaf, sc in zip(leaves, _match(values, scale))]
        return jax.tree_util.tree_unflatten(treedef, out)
    if not isinstance(values, (dict, list, tuple)):
        # plain (m, p) array: the historical flat call, verbatim —
        # guarantees the refactored protocol_rounds is byte-identical.
        return agg.aggregate(values, method=method, scale=scale, K=K,
                             trim_beta=trim_beta, axis=0, backend=backend)
    leaves, treedef = jax.tree_util.tree_flatten(values)
    scales = _match(values, scale)
    out = []
    for leaf, sc in zip(leaves, scales):
        payload = leaf.shape[1:]
        flat = leaf.reshape(leaf.shape[0], -1)
        fsc = None
        if sc is not None:
            fsc = jnp.broadcast_to(jnp.asarray(sc, leaf.dtype),
                                   payload).reshape(-1) if payload \
                else jnp.asarray(sc, leaf.dtype).reshape(1)
        red = agg.aggregate(flat, method=method, scale=fsc, K=K,
                            trim_beta=trim_beta, axis=0, backend=backend)
        out.append(red.reshape(payload).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
