"""Differential privacy: Gaussian mechanism with tail-bound sensitivity.

Implements the paper's DP layer (§2.2, §4.2):
  * Lemma 2.1   — classic Gaussian mechanism sigma >= sqrt(2 log(1.25/delta)) * Delta / eps.
  * Lemmas 4.3/4.4 — high-probability sensitivity of a mean of sub-Gaussian /
    sub-exponential vectors (the paper's replacement for boundedness).
  * Theorems 4.4/4.5 — noise s.d. s_1..s_5 for the five protocol rounds
    (sub-exponential; Remark 4.4 / Lemma 39 give the sqrt(log n) sub-Gaussian
    discount).
  * Theorem 4.6 — DP for transmitted *variances* (untrusted-center mode).
  * Corollary 4.1 — Kairouz–Oh–Viswanath advanced composition.
  * PrivacyAccountant — tracks the five transmissions and the total budget.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- mechanism

def gaussian_sigma(sensitivity: float, eps: float, delta: float) -> float:
    """Lemma 2.1: noise s.d. for (eps, delta)-DP given l2-sensitivity."""
    if eps <= 0 or not (0 < delta < 1):
        raise ValueError("need eps > 0 and 0 < delta < 1")
    return math.sqrt(2.0 * math.log(1.25 / delta)) * sensitivity / eps


def noise_multiplier(eps, delta):
    """The paper's Delta := sqrt(2 log(1/delta)) / eps (Thms 4.4/4.5).

    Dual-mode: exact ``math`` arithmetic for Python floats (the static
    compile-once path), ``jnp`` arithmetic when eps/delta are traced arrays
    (the sweep executor batches privacy budgets along a vmap axis).
    """
    if isinstance(eps, (int, float)) and isinstance(delta, (int, float)):
        return math.sqrt(2.0 * math.log(1.0 / delta)) / eps
    return jnp.sqrt(2.0 * jnp.log(1.0 / delta)) / eps


def add_noise(key: jax.Array, x: jnp.ndarray, s: float) -> jnp.ndarray:
    """Gaussian mechanism G(X, s) = M(X) + N(0, s^2 I)."""
    return x + s * jax.random.normal(key, x.shape, x.dtype)


# ------------------------------------------------- tail-bound sensitivities

def mean_sensitivity_subgauss(p: int, n: int, gamma: float) -> float:
    """Lemma 4.3: Delta = 2*gamma*sqrt(p log n)/n for sub-Gaussian means."""
    return 2.0 * gamma * math.sqrt(p * math.log(n)) / n


def mean_sensitivity_subexp(p: int, n: int, gamma: float) -> float:
    """Lemma 4.4: Delta = 2*gamma*sqrt(p)*log(n)/n for sub-exponential means."""
    return 2.0 * gamma * math.sqrt(p) * math.log(n) / n


def mean_dp_failure_prob_subgauss(p: int, n: int, gamma: float,
                                  nu: float) -> float:
    """Lemma 4.3: DP fails with prob <= 2 p n^{-gamma^2/nu^2}."""
    return min(1.0, 2.0 * p * n ** (-(gamma ** 2) / nu ** 2))


def mean_dp_failure_prob_subexp(p: int, n: int, gamma: float, nu: float,
                                alpha: float) -> float:
    """Lemma 4.4: 2 p max{n^{-gamma^2 log n/nu^2}, n^{-gamma/alpha}}."""
    a = n ** (-(gamma ** 2) * math.log(n) / nu ** 2)
    b = n ** (-gamma / alpha)
    return min(1.0, 2.0 * p * max(a, b))


def variance_sensitivity(n: int, gamma: float) -> float:
    """Thm 4.6: Delta = (4*gamma*log n + 1)/n for a sub-Gaussian sample
    variance (untrusted-center variance transmission)."""
    if gamma < 1:
        raise ValueError("Thm 4.6 requires gamma >= 1")
    return (4.0 * gamma * math.log(n) + 1.0) / n


# ----------------------------------------------- protocol noise calibration

def _tail_factor(n: int, tail: str) -> float:
    """sub-exponential: log n; sub-Gaussian: sqrt(log n) (Remark 4.4)."""
    if tail == "subexp":
        return math.log(n)
    if tail == "subgauss":
        return math.sqrt(math.log(n))
    raise ValueError(f"tail must be subexp|subgauss, got {tail!r}")


def s1_theta(p: int, n: int, gamma: float, eps: float, delta: float,
             lambda_s: float, tail: str = "subexp") -> float:
    """Thm 4.5(1): s1 = 2.02 gamma sqrt(p) log(n) Delta / (lambda_s n)."""
    d = noise_multiplier(eps, delta)
    return 2.02 * gamma * math.sqrt(p) * _tail_factor(n, tail) * d / (lambda_s * n)


def s2_grad(p: int, n: int, gamma: float, eps: float, delta: float,
            tail: str = "subexp") -> float:
    """Thm 4.5(2): s2 = 2 gamma sqrt(p) log(n) Delta / n."""
    d = noise_multiplier(eps, delta)
    return 2.0 * gamma * math.sqrt(p) * _tail_factor(n, tail) * d / n


def s3_newton_dir(p: int, n: int, gamma: float, eps: float, delta: float,
                  lambda_s: float, dir_norm: float,
                  tail: str = "subexp") -> float:
    """Thm 4.5(3): s3j = 2.02 gamma sqrt(p) log(n) ||H_j^{-1} g_cq|| Delta / (lambda_s n)."""
    d = noise_multiplier(eps, delta)
    return (2.02 * gamma * math.sqrt(p) * _tail_factor(n, tail)
            * dir_norm * d / (lambda_s * n))


def s4_grad_diff(p: int, n: int, gamma: float, eps: float, delta: float,
                 step_norm: float, tail: str = "subexp") -> float:
    """Thm 4.5(4): s4 = 2 gamma sqrt(p) log(n) ||theta_os - theta_cq|| Delta / n."""
    d = noise_multiplier(eps, delta)
    return 2.0 * gamma * math.sqrt(p) * _tail_factor(n, tail) * step_norm * d / n


def s5_bfgs_dir(p: int, n: int, gamma: float, eps: float, delta: float,
                vh_norm: float, dir_norm: float,
                tail: str = "subexp") -> float:
    """Thm 4.5(5): s5j = 2.02 gamma sqrt(p) log(n) ||V H_j^{-1}|| ||H_j^{-1} V g_os|| Delta / n."""
    d = noise_multiplier(eps, delta)
    return (2.02 * gamma * math.sqrt(p) * _tail_factor(n, tail)
            * vh_norm * dir_norm * d / n)


def s6_variance(p: int, n: int, gamma: float, eps, delta):
    """§4.3: s6 = sqrt(2) gamma p (4 log n + 1) sqrt(log(1.25 p/delta)) / (n eps).

    Dual-mode in (eps, delta) like ``noise_multiplier``.
    """
    c = math.sqrt(2.0) * gamma * p * (4.0 * math.log(n) + 1.0) / n
    if isinstance(eps, (int, float)) and isinstance(delta, (int, float)):
        return c * math.sqrt(math.log(1.25 * p / delta)) / eps
    return c * jnp.sqrt(jnp.log(1.25 * p / delta)) / eps


# ------------------------------------------- per-leaf (pytree) calibration

#: the five pytree-engine transmissions, in wire order (Algorithm 1's
#: vector rounds at model scale; no untrusted-variance round).
TREE_TRANSMISSIONS = ("R1 theta", "R2 grad", "R3 newton-dir",
                      "R4 grad-diff", "R5 bfgs-dir")


def tree_mean_sigma(tree_dims, n: int, gamma: float, eps_r: float,
                    delta_r: float, tail: str = "subexp"):
    """Per-leaf noise s.d. for ONE transmitted pytree: the Lemma 4.4 mean
    mechanism calibrated at EACH leaf's own dimension ``d_leaf`` instead of
    one global ``p``. A 4096-d embedding leaf and a 16-d norm-scale leaf in
    the same transmission get different sigmas — the per-leaf sensitivity
    2*gamma*sqrt(d_leaf)*log(n)/n is what (eps_r, delta_r)-DP actually
    requires of each leaf, and the small leaves stop paying the big leaves'
    sqrt(d) penalty.

    ``tree_dims``: pytree of ints (``transport.tree_leaf_dims``). Returns
    a matching pytree of Python-float sigmas (static, compile-once safe).
    """
    return jax.tree_util.tree_map(
        lambda d: s2_grad(int(d), n, gamma, eps_r, delta_r, tail), tree_dims)


def calibrate_tree_sigmas(tree, n: int, eps: float, delta: float,
                          gammas=(2.0, 2.0, 2.0, 2.0, 2.0),
                          tail: str = "subexp",
                          machine_axis: bool = False,
                          accountant: str = "basic"):
    """Per-transmission, per-leaf noise s.d. for the pytree protocol:
    ``{transmission name: pytree of sigmas}``.

    The total (eps, delta) is split over the five transmissions by the
    named ``accountant`` (repro.privacy registry; the default "basic" is
    the even eps/5 split of Remark 4.5 and stays byte-identical — the
    sigmas are never rescaled, not even by 1.0). At model scale the
    norm-dependent refinements of Thm 4.5 (s1, s3..s5 need ``lambda_s``
    and direction norms) are not available before the trace, so every
    transmission uses the sub-exponential mean mechanism (Lemma 4.4 /
    Thm 4.5(2)) with its round's ``gamma`` — conservative but valid, and
    per-leaf in dimension.
    """
    from repro.core.transport import tree_leaf_dims
    k = len(TREE_TRANSMISSIONS)
    eps_r, delta_r = eps / k, delta / k
    dims = tree_leaf_dims(tree, machine_axis=machine_axis)
    sigmas = {name: tree_mean_sigma(dims, n, gammas[i], eps_r, delta_r,
                                    tail)
              for i, name in enumerate(TREE_TRANSMISSIONS)}
    if accountant != "basic":
        from repro.privacy import multiplier_ratio
        ratio = multiplier_ratio(accountant, eps, delta, k)
        if ratio != 1.0:
            sigmas = {name: jax.tree_util.tree_map(lambda s: s * ratio, t)
                      for name, t in sigmas.items()}
    return sigmas


def tree_spend_ledger(tree, n: int, eps: float, delta: float,
                      gammas=(2.0, 2.0, 2.0, 2.0, 2.0),
                      tail: str = "subexp",
                      machine_axis: bool = False,
                      accountant: str = "basic") -> List[dict]:
    """Flat per-(transmission, leaf) spend records for the artifact ledger:
    each entry carries the leaf path, its own dimension, the sigma that
    dimension bought, and the accountant that certified the per-round
    budget — the per-leaf calibration made auditable. High-probability
    accountants ("subexp") additionally record each leaf's Lemma 4.4
    sensitivity failure probability."""
    from repro.core.transport import leaf_paths, tree_leaf_dims
    from repro.privacy import get_accountant
    acct = get_accountant(accountant)
    k = len(TREE_TRANSMISSIONS)
    eps_r, delta_r = acct.per_round(eps, delta, k)
    sigmas = calibrate_tree_sigmas(tree, n, eps, delta, gammas, tail,
                                   machine_axis, accountant=accountant)
    paths = leaf_paths(tree)
    dims = jax.tree_util.tree_leaves(
        tree_leaf_dims(tree, machine_axis=machine_axis))
    records = []
    for i, name in enumerate(TREE_TRANSMISSIONS):
        for path, d, s in zip(paths, dims,
                              jax.tree_util.tree_leaves(sigmas[name])):
            rec = {"transmission": name, "leaf": path,
                   "dim": int(d), "sigma": float(s),
                   "eps": eps_r, "delta": delta_r,
                   "accountant": acct.name}
            if acct.failure_prob is not None:
                rec["failure_prob"] = acct.failure_prob(int(d), n,
                                                        gammas[i])
            records.append(rec)
    return records


# ---------------------------------------------------------------- composition

def compose_basic(budgets: List[Tuple[float, float]]) -> Tuple[float, float]:
    """Dwork et al. 2006: k queries compose to (sum eps_i, sum delta_i)."""
    return sum(e for e, _ in budgets), sum(d for _, d in budgets)


def compose_advanced(eps: float, delta: float, k: int,
                     slack: float) -> Tuple[float, float]:
    """Cor 4.1 (Kairouz–Oh–Viswanath Thm 3.2): k-fold adaptive composition
    of (eps, delta)-DP mechanisms is (eps_tilde, 1-(1-delta)^k (1-slack))-DP.
    """
    a = k * eps
    common = (math.e ** eps - 1.0) * k * eps / (math.e ** eps + 1.0)
    b = common + eps * math.sqrt(
        2.0 * k * math.log(math.e + math.sqrt(k * eps ** 2) / slack))
    c = common + eps * math.sqrt(2.0 * k * math.log(1.0 / slack))
    eps_tilde = min(a, b, c)
    delta_total = 1.0 - (1.0 - delta) ** k * (1.0 - slack)
    return eps_tilde, delta_total


#: slack grid for inverting Cor 4.1: fractions of the total delta handed
#: to the composition slack (the rest is split over the k rounds).
_ADVANCED_SLACK_FRACS = (0.01, 0.05, 0.1, 0.25, 0.5, 0.75, 0.9)


def invert_advanced(eps: float, delta: float, k: int,
                    slack_fracs=_ADVANCED_SLACK_FRACS
                    ) -> Tuple[float, float]:
    """Largest per-round (eps_r, delta_r) whose k-fold Cor 4.1 composition
    stays within total (eps, delta) — the CALIBRATION direction of
    advanced composition, best-of with the basic eps/k split.

    For each slack fraction the per-round delta_r solves
    1-(1-delta_r)^k (1-slack) = delta exactly, and eps_r is bisected on
    the (monotone) sqrt-k bounds b/c of Cor 4.1. The basic candidate
    (eps/k, delta/k) is always in the pool, so the result is never a
    LARGER noise multiplier than basic; at the paper's k in {5, 6} it IS
    basic (Cor 4.1's sqrt-k regime needs k >~ 2 ln(1/slack) ~ 23+), and
    the strict win appears at many-round scale. Returns the candidate
    minimizing :func:`noise_multiplier`.
    """
    if eps <= 0 or not (0 < delta < 1) or k < 1:
        raise ValueError("need eps > 0, 0 < delta < 1, k >= 1")
    best = (eps / k, delta / k)
    for frac in slack_fracs:
        slack = frac * delta
        delta_r = 1.0 - ((1.0 - delta) / (1.0 - slack)) ** (1.0 / k)
        if delta_r <= 0.0:
            continue

        def bound_bc(e: float) -> float:
            common = (math.e ** e - 1.0) * k * e / (math.e ** e + 1.0)
            b = common + e * math.sqrt(
                2.0 * k * math.log(math.e + math.sqrt(k * e * e) / slack))
            c = common + e * math.sqrt(2.0 * k * math.log(1.0 / slack))
            return min(b, c)

        lo, hi = 0.0, eps          # bound_bc(eps) > eps in any DP regime
        for _ in range(100):
            mid = 0.5 * (lo + hi)
            if bound_bc(mid) <= eps:
                lo = mid
            else:
                hi = mid
        if lo > 0.0 and noise_multiplier(lo, delta_r) \
                < noise_multiplier(*best):
            best = (lo, delta_r)
    return best


# --------------------------------------------------------- Renyi accounting

def rdp_gaussian_epsilon(mu: float, alpha: float, k: int = 1) -> float:
    """Renyi-DP curve of k composed Gaussian mechanisms at noise
    multiplier mu (sigma = mu * sensitivity): eps_alpha = k alpha/(2 mu^2)
    (Mironov 2017, Prop 7 + additivity under composition)."""
    return k * alpha / (2.0 * mu * mu)


def rdp_to_dp(eps_alpha: float, alpha: float, delta: float) -> float:
    """Tight RDP -> (eps, delta) conversion (Canonne–Kamath–Steinke '20 /
    Balle et al. '20): eps = eps_alpha + log((alpha-1)/alpha)
    - (log delta + log alpha)/(alpha - 1). Requires alpha > 1."""
    if alpha <= 1.0:
        raise ValueError("RDP order alpha must exceed 1")
    return (eps_alpha + math.log((alpha - 1.0) / alpha)
            - (math.log(delta) + math.log(alpha)) / (alpha - 1.0))


#: default RDP order grid: dense near 1 (tiny budgets), log-spread above.
RDP_ALPHAS = tuple([1.0 + x / 10.0 for x in range(1, 10)]
                   + list(range(2, 64)) + [80, 128, 256, 512, 1024])


def rdp_total_epsilon(mu: float, k: int, delta: float,
                      alphas=RDP_ALPHAS) -> float:
    """(eps, delta) guarantee of k composed Gaussian releases at noise
    multiplier mu: the tight conversion optimized over the order grid."""
    return min(rdp_to_dp(rdp_gaussian_epsilon(mu, a, k), a, delta)
               for a in alphas)


def calibrate_rdp_multiplier(eps: float, delta: float, k: int) -> float:
    """Smallest per-round noise multiplier mu such that k Gaussian
    releases at sigma = mu * sensitivity compose to (eps, delta)-DP under
    RDP with the tight conversion. Bisection (total eps is monotone
    decreasing in mu); host-side Python floats only."""
    if eps <= 0 or not (0 < delta < 1) or k < 1:
        raise ValueError("need eps > 0, 0 < delta < 1, k >= 1")
    lo, hi = 1e-4, 1.0
    while rdp_total_epsilon(hi, k, delta) > eps:
        hi *= 2.0
        if hi > 1e10:
            raise ValueError(f"no Gaussian multiplier reaches eps={eps}")
    for _ in range(100):
        mid = 0.5 * (lo + hi)
        if rdp_total_epsilon(mid, k, delta) > eps:
            lo = mid
        else:
            hi = mid
    return hi


# ---------------------------------------------------------------- accountant

@dataclasses.dataclass
class QueryRecord:
    name: str
    eps: float
    delta: float
    sigma: float
    failure_prob: float = 0.0
    per_leaf: Optional[List[dict]] = None   # pytree transmissions: one
    #                                         {leaf, dim, sigma} per leaf


class PrivacyAccountant:
    """Tracks the per-round budgets of Algorithm 1 and reports totals.

    Basic composition (Remark 4.5) plus the tighter Cor 4.1 bound when all
    rounds share (eps, delta).
    """

    def __init__(self) -> None:
        self.records: List[QueryRecord] = []
        #: audit annotations (e.g. the advanced-composition fallback) —
        #: part of the ledger, surfaced by ``summary()``.
        self.notes: List[str] = []
        self._warned_advanced_fallback = False

    def spend(self, name: str, eps: float, delta: float, sigma: float,
              failure_prob: float = 0.0) -> None:
        self.records.append(QueryRecord(name, eps, delta, sigma, failure_prob))

    def spend_tree(self, name: str, eps: float, delta: float,
                   sigma_tree) -> None:
        """One pytree transmission = ONE composition entry (all leaves are
        released by a single mechanism under the same (eps, delta) — adding
        per-leaf entries to the composition would over-count the budget).
        The per-leaf sigmas ride on the record for the artifact ledger; the
        reported scalar sigma is the worst (largest) leaf's."""
        from repro.core.transport import leaf_paths
        paths = leaf_paths(sigma_tree)
        sig_leaves = [float(s) for s in
                      jax.tree_util.tree_leaves(sigma_tree)]
        per_leaf = [{"leaf": pth, "sigma": s}
                    for pth, s in zip(paths, sig_leaves)]
        self.records.append(QueryRecord(
            name, eps, delta, max(sig_leaves) if sig_leaves else 0.0,
            per_leaf=per_leaf))

    def total_basic(self) -> Tuple[float, float]:
        return compose_basic([(r.eps, r.delta) for r in self.records])

    def total_advanced(self, slack: float = 1e-3) -> Tuple[float, float]:
        """Cor 4.1 total when all rounds share one (eps, delta).

        Heterogeneous budgets fall OUTSIDE Cor 4.1's hypothesis, so the
        total falls back to basic composition — but never silently: the
        fallback is recorded as a ledger note and warned once per
        accountant (regression: tests/test_dp.py)."""
        if not self.records:
            return 0.0, 0.0
        eps0 = self.records[0].eps
        delta0 = self.records[0].delta
        if any(abs(r.eps - eps0) > 1e-12 or abs(r.delta - delta0) > 1e-12
               for r in self.records):
            note = ("advanced composition fell back to basic: "
                    f"heterogeneous per-round budgets over "
                    f"{len(self.records)} records "
                    f"(eps range [{min(r.eps for r in self.records):.4g}, "
                    f"{max(r.eps for r in self.records):.4g}])")
            if note not in self.notes:
                self.notes.append(note)
            if not self._warned_advanced_fallback:
                import warnings
                warnings.warn(
                    "PrivacyAccountant.total_advanced: per-round budgets "
                    "are heterogeneous, which Cor 4.1 does not cover — "
                    "reporting the basic-composition total instead (noted "
                    "in accountant.notes)", RuntimeWarning, stacklevel=2)
                self._warned_advanced_fallback = True
            return self.total_basic()
        return compose_advanced(eps0, delta0, len(self.records), slack)

    def total_failure_prob(self) -> float:
        """Union bound over the high-probability sensitivity events."""
        return min(1.0, sum(r.failure_prob for r in self.records))

    def summary(self) -> str:
        e_b, d_b = self.total_basic()
        e_a, d_a = self.total_advanced()
        lines = [f"{r.name}: (eps={r.eps:.4g}, delta={r.delta:.4g}) "
                 f"sigma={r.sigma:.4g}" for r in self.records]
        lines.append(f"basic composition:    ({e_b:.4g}, {d_b:.4g})")
        lines.append(f"advanced composition: ({e_a:.4g}, {d_a:.4g})")
        lines.append(f"sensitivity failure prob <= {self.total_failure_prob():.3g}")
        lines.extend(f"note: {n}" for n in self.notes)
        return "\n".join(lines)
