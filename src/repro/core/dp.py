"""Differential privacy: Gaussian mechanism with tail-bound sensitivity.

Implements the paper's DP layer (§2.2, §4.2):
  * Lemma 2.1   — classic Gaussian mechanism sigma >= sqrt(2 log(1.25/delta)) * Delta / eps.
  * Lemmas 4.3/4.4 — high-probability sensitivity of a mean of sub-Gaussian /
    sub-exponential vectors (the paper's replacement for boundedness).
  * Theorems 4.4/4.5 — noise s.d. s_1..s_5 for the five protocol rounds
    (sub-exponential; Remark 4.4 / Lemma 39 give the sqrt(log n) sub-Gaussian
    discount).
  * Theorem 4.6 — DP for transmitted *variances* (untrusted-center mode).
  * Corollary 4.1 — Kairouz–Oh–Viswanath advanced composition.
  * PrivacyAccountant — tracks the five transmissions and the total budget.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- mechanism

def gaussian_sigma(sensitivity: float, eps: float, delta: float) -> float:
    """Lemma 2.1: noise s.d. for (eps, delta)-DP given l2-sensitivity."""
    if eps <= 0 or not (0 < delta < 1):
        raise ValueError("need eps > 0 and 0 < delta < 1")
    return math.sqrt(2.0 * math.log(1.25 / delta)) * sensitivity / eps


def noise_multiplier(eps, delta):
    """The paper's Delta := sqrt(2 log(1/delta)) / eps (Thms 4.4/4.5).

    Dual-mode: exact ``math`` arithmetic for Python floats (the static
    compile-once path), ``jnp`` arithmetic when eps/delta are traced arrays
    (the sweep executor batches privacy budgets along a vmap axis).
    """
    if isinstance(eps, (int, float)) and isinstance(delta, (int, float)):
        return math.sqrt(2.0 * math.log(1.0 / delta)) / eps
    return jnp.sqrt(2.0 * jnp.log(1.0 / delta)) / eps


def add_noise(key: jax.Array, x: jnp.ndarray, s: float) -> jnp.ndarray:
    """Gaussian mechanism G(X, s) = M(X) + N(0, s^2 I)."""
    return x + s * jax.random.normal(key, x.shape, x.dtype)


# ------------------------------------------------- tail-bound sensitivities

def mean_sensitivity_subgauss(p: int, n: int, gamma: float) -> float:
    """Lemma 4.3: Delta = 2*gamma*sqrt(p log n)/n for sub-Gaussian means."""
    return 2.0 * gamma * math.sqrt(p * math.log(n)) / n


def mean_sensitivity_subexp(p: int, n: int, gamma: float) -> float:
    """Lemma 4.4: Delta = 2*gamma*sqrt(p)*log(n)/n for sub-exponential means."""
    return 2.0 * gamma * math.sqrt(p) * math.log(n) / n


def mean_dp_failure_prob_subgauss(p: int, n: int, gamma: float,
                                  nu: float) -> float:
    """Lemma 4.3: DP fails with prob <= 2 p n^{-gamma^2/nu^2}."""
    return min(1.0, 2.0 * p * n ** (-(gamma ** 2) / nu ** 2))


def mean_dp_failure_prob_subexp(p: int, n: int, gamma: float, nu: float,
                                alpha: float) -> float:
    """Lemma 4.4: 2 p max{n^{-gamma^2 log n/nu^2}, n^{-gamma/alpha}}."""
    a = n ** (-(gamma ** 2) * math.log(n) / nu ** 2)
    b = n ** (-gamma / alpha)
    return min(1.0, 2.0 * p * max(a, b))


def variance_sensitivity(n: int, gamma: float) -> float:
    """Thm 4.6: Delta = (4*gamma*log n + 1)/n for a sub-Gaussian sample
    variance (untrusted-center variance transmission)."""
    if gamma < 1:
        raise ValueError("Thm 4.6 requires gamma >= 1")
    return (4.0 * gamma * math.log(n) + 1.0) / n


# ----------------------------------------------- protocol noise calibration

def _tail_factor(n: int, tail: str) -> float:
    """sub-exponential: log n; sub-Gaussian: sqrt(log n) (Remark 4.4)."""
    if tail == "subexp":
        return math.log(n)
    if tail == "subgauss":
        return math.sqrt(math.log(n))
    raise ValueError(f"tail must be subexp|subgauss, got {tail!r}")


def s1_theta(p: int, n: int, gamma: float, eps: float, delta: float,
             lambda_s: float, tail: str = "subexp") -> float:
    """Thm 4.5(1): s1 = 2.02 gamma sqrt(p) log(n) Delta / (lambda_s n)."""
    d = noise_multiplier(eps, delta)
    return 2.02 * gamma * math.sqrt(p) * _tail_factor(n, tail) * d / (lambda_s * n)


def s2_grad(p: int, n: int, gamma: float, eps: float, delta: float,
            tail: str = "subexp") -> float:
    """Thm 4.5(2): s2 = 2 gamma sqrt(p) log(n) Delta / n."""
    d = noise_multiplier(eps, delta)
    return 2.0 * gamma * math.sqrt(p) * _tail_factor(n, tail) * d / n


def s3_newton_dir(p: int, n: int, gamma: float, eps: float, delta: float,
                  lambda_s: float, dir_norm: float,
                  tail: str = "subexp") -> float:
    """Thm 4.5(3): s3j = 2.02 gamma sqrt(p) log(n) ||H_j^{-1} g_cq|| Delta / (lambda_s n)."""
    d = noise_multiplier(eps, delta)
    return (2.02 * gamma * math.sqrt(p) * _tail_factor(n, tail)
            * dir_norm * d / (lambda_s * n))


def s4_grad_diff(p: int, n: int, gamma: float, eps: float, delta: float,
                 step_norm: float, tail: str = "subexp") -> float:
    """Thm 4.5(4): s4 = 2 gamma sqrt(p) log(n) ||theta_os - theta_cq|| Delta / n."""
    d = noise_multiplier(eps, delta)
    return 2.0 * gamma * math.sqrt(p) * _tail_factor(n, tail) * step_norm * d / n


def s5_bfgs_dir(p: int, n: int, gamma: float, eps: float, delta: float,
                vh_norm: float, dir_norm: float,
                tail: str = "subexp") -> float:
    """Thm 4.5(5): s5j = 2.02 gamma sqrt(p) log(n) ||V H_j^{-1}|| ||H_j^{-1} V g_os|| Delta / n."""
    d = noise_multiplier(eps, delta)
    return (2.02 * gamma * math.sqrt(p) * _tail_factor(n, tail)
            * vh_norm * dir_norm * d / n)


def s6_variance(p: int, n: int, gamma: float, eps, delta):
    """§4.3: s6 = sqrt(2) gamma p (4 log n + 1) sqrt(log(1.25 p/delta)) / (n eps).

    Dual-mode in (eps, delta) like ``noise_multiplier``.
    """
    c = math.sqrt(2.0) * gamma * p * (4.0 * math.log(n) + 1.0) / n
    if isinstance(eps, (int, float)) and isinstance(delta, (int, float)):
        return c * math.sqrt(math.log(1.25 * p / delta)) / eps
    return c * jnp.sqrt(jnp.log(1.25 * p / delta)) / eps


# ------------------------------------------- per-leaf (pytree) calibration

#: the five pytree-engine transmissions, in wire order (Algorithm 1's
#: vector rounds at model scale; no untrusted-variance round).
TREE_TRANSMISSIONS = ("R1 theta", "R2 grad", "R3 newton-dir",
                      "R4 grad-diff", "R5 bfgs-dir")


def tree_mean_sigma(tree_dims, n: int, gamma: float, eps_r: float,
                    delta_r: float, tail: str = "subexp"):
    """Per-leaf noise s.d. for ONE transmitted pytree: the Lemma 4.4 mean
    mechanism calibrated at EACH leaf's own dimension ``d_leaf`` instead of
    one global ``p``. A 4096-d embedding leaf and a 16-d norm-scale leaf in
    the same transmission get different sigmas — the per-leaf sensitivity
    2*gamma*sqrt(d_leaf)*log(n)/n is what (eps_r, delta_r)-DP actually
    requires of each leaf, and the small leaves stop paying the big leaves'
    sqrt(d) penalty.

    ``tree_dims``: pytree of ints (``transport.tree_leaf_dims``). Returns
    a matching pytree of Python-float sigmas (static, compile-once safe).
    """
    return jax.tree_util.tree_map(
        lambda d: s2_grad(int(d), n, gamma, eps_r, delta_r, tail), tree_dims)


def calibrate_tree_sigmas(tree, n: int, eps: float, delta: float,
                          gammas=(2.0, 2.0, 2.0, 2.0, 2.0),
                          tail: str = "subexp",
                          machine_axis: bool = False):
    """Per-transmission, per-leaf noise s.d. for the pytree protocol:
    ``{transmission name: pytree of sigmas}``.

    The total (eps, delta) is split evenly over the five transmissions
    (basic composition, Remark 4.5). At model scale the norm-dependent
    refinements of Thm 4.5 (s1, s3..s5 need ``lambda_s`` and direction
    norms) are not available before the trace, so every transmission uses
    the sub-exponential mean mechanism (Lemma 4.4 / Thm 4.5(2)) with its
    round's ``gamma`` — conservative but valid, and per-leaf in dimension.
    """
    from repro.core.transport import tree_leaf_dims
    k = len(TREE_TRANSMISSIONS)
    eps_r, delta_r = eps / k, delta / k
    dims = tree_leaf_dims(tree, machine_axis=machine_axis)
    return {name: tree_mean_sigma(dims, n, gammas[i], eps_r, delta_r, tail)
            for i, name in enumerate(TREE_TRANSMISSIONS)}


def tree_spend_ledger(tree, n: int, eps: float, delta: float,
                      gammas=(2.0, 2.0, 2.0, 2.0, 2.0),
                      tail: str = "subexp",
                      machine_axis: bool = False) -> List[dict]:
    """Flat per-(transmission, leaf) spend records for the artifact ledger:
    each entry carries the leaf path, its own dimension, and the sigma that
    dimension bought — the per-leaf calibration made auditable."""
    from repro.core.transport import leaf_paths, tree_leaf_dims
    k = len(TREE_TRANSMISSIONS)
    eps_r, delta_r = eps / k, delta / k
    sigmas = calibrate_tree_sigmas(tree, n, eps, delta, gammas, tail,
                                   machine_axis)
    paths = leaf_paths(tree)
    dims = jax.tree_util.tree_leaves(
        tree_leaf_dims(tree, machine_axis=machine_axis))
    records = []
    for name in TREE_TRANSMISSIONS:
        for path, d, s in zip(paths, dims,
                              jax.tree_util.tree_leaves(sigmas[name])):
            records.append({"transmission": name, "leaf": path,
                            "dim": int(d), "sigma": float(s),
                            "eps": eps_r, "delta": delta_r})
    return records


# ---------------------------------------------------------------- composition

def compose_basic(budgets: List[Tuple[float, float]]) -> Tuple[float, float]:
    """Dwork et al. 2006: k queries compose to (sum eps_i, sum delta_i)."""
    return sum(e for e, _ in budgets), sum(d for _, d in budgets)


def compose_advanced(eps: float, delta: float, k: int,
                     slack: float) -> Tuple[float, float]:
    """Cor 4.1 (Kairouz–Oh–Viswanath Thm 3.2): k-fold adaptive composition
    of (eps, delta)-DP mechanisms is (eps_tilde, 1-(1-delta)^k (1-slack))-DP.
    """
    a = k * eps
    common = (math.e ** eps - 1.0) * k * eps / (math.e ** eps + 1.0)
    b = common + eps * math.sqrt(
        2.0 * k * math.log(math.e + math.sqrt(k * eps ** 2) / slack))
    c = common + eps * math.sqrt(2.0 * k * math.log(1.0 / slack))
    eps_tilde = min(a, b, c)
    delta_total = 1.0 - (1.0 - delta) ** k * (1.0 - slack)
    return eps_tilde, delta_total


# ---------------------------------------------------------------- accountant

@dataclasses.dataclass
class QueryRecord:
    name: str
    eps: float
    delta: float
    sigma: float
    failure_prob: float = 0.0
    per_leaf: Optional[List[dict]] = None   # pytree transmissions: one
    #                                         {leaf, dim, sigma} per leaf


class PrivacyAccountant:
    """Tracks the per-round budgets of Algorithm 1 and reports totals.

    Basic composition (Remark 4.5) plus the tighter Cor 4.1 bound when all
    rounds share (eps, delta).
    """

    def __init__(self) -> None:
        self.records: List[QueryRecord] = []

    def spend(self, name: str, eps: float, delta: float, sigma: float,
              failure_prob: float = 0.0) -> None:
        self.records.append(QueryRecord(name, eps, delta, sigma, failure_prob))

    def spend_tree(self, name: str, eps: float, delta: float,
                   sigma_tree) -> None:
        """One pytree transmission = ONE composition entry (all leaves are
        released by a single mechanism under the same (eps, delta) — adding
        per-leaf entries to the composition would over-count the budget).
        The per-leaf sigmas ride on the record for the artifact ledger; the
        reported scalar sigma is the worst (largest) leaf's."""
        from repro.core.transport import leaf_paths
        paths = leaf_paths(sigma_tree)
        sig_leaves = [float(s) for s in
                      jax.tree_util.tree_leaves(sigma_tree)]
        per_leaf = [{"leaf": pth, "sigma": s}
                    for pth, s in zip(paths, sig_leaves)]
        self.records.append(QueryRecord(
            name, eps, delta, max(sig_leaves) if sig_leaves else 0.0,
            per_leaf=per_leaf))

    def total_basic(self) -> Tuple[float, float]:
        return compose_basic([(r.eps, r.delta) for r in self.records])

    def total_advanced(self, slack: float = 1e-3) -> Tuple[float, float]:
        if not self.records:
            return 0.0, 0.0
        eps0 = self.records[0].eps
        delta0 = self.records[0].delta
        if any(abs(r.eps - eps0) > 1e-12 or abs(r.delta - delta0) > 1e-12
               for r in self.records):
            # heterogeneous budgets: fall back to basic
            return self.total_basic()
        return compose_advanced(eps0, delta0, len(self.records), slack)

    def total_failure_prob(self) -> float:
        """Union bound over the high-probability sensitivity events."""
        return min(1.0, sum(r.failure_prob for r in self.records))

    def summary(self) -> str:
        e_b, d_b = self.total_basic()
        e_a, d_a = self.total_advanced()
        lines = [f"{r.name}: (eps={r.eps:.4g}, delta={r.delta:.4g}) "
                 f"sigma={r.sigma:.4g}" for r in self.records]
        lines.append(f"basic composition:    ({e_b:.4g}, {d_b:.4g})")
        lines.append(f"advanced composition: ({e_a:.4g}, {d_a:.4g})")
        lines.append(f"sensitivity failure prob <= {self.total_failure_prob():.3g}")
        return "\n".join(lines)
