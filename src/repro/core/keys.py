"""Named PRNG streams: collision-free key derivation for launchers and
sweeps.

The anti-pattern this replaces is arithmetic seed offsets —
``PRNGKey(1000 + seed)`` for the protocol and ``PRNGKey(seed + 1)`` for
the data collide as soon as seeds span the offset gap (seed 1001's data
stream IS seed 1's protocol stream), silently correlating the DP noise
of different replicates. ``stream_key`` derives every purpose-stream
from ONE root key by :func:`jax.random.fold_in` over a registered stream
index, so distinct (seed, stream, index) triples give independent keys
for every seed range.

The sweep executor keeps its historical arithmetic derivation behind an
annotated ``repro: allow(key-reuse)`` suppression — preset artifacts are
byte-pinned to it (tests/test_analyze.py locks the parity) — and new
code uses these streams.
"""
from __future__ import annotations

import jax

#: registered purpose-streams, in fold_in index order. Append only —
#: reordering re-derives every downstream key.
STREAMS = ("params", "data", "protocol", "batches", "attack", "serve",
           "eval")


def stream_key(seed: int, stream: str, index=None) -> jax.Array:
    """An independent key for ``stream`` under ``seed``.

    ``index`` (optional) folds a per-step / per-replicate counter into
    the stream, replacing ``PRNGKey(seed + i)`` loops. Unknown stream
    names raise (the namespace is the collision guarantee).
    """
    try:
        idx = STREAMS.index(stream)
    except ValueError:
        raise ValueError(
            f"unknown stream {stream!r}; registered: {STREAMS}") from None
    k = jax.random.fold_in(jax.random.PRNGKey(seed), idx)
    if index is not None:
        k = jax.random.fold_in(k, index)
    return k
