"""BFGS machinery (paper §4.1 and eq. 4.13) + L-BFGS two-loop.

The protocol's second iteration updates every machine's inverse Hessian by

    H^+ = V^T H V + rho * s s^T,      V = I - rho * y s^T,
    rho = 1 / (s^T y),   s = theta_os - theta_cq,   y = g_diff,

and only ever needs matrix-vector products with V — we exploit the rank-1
structure (``VOp``) so the center never materialises a p x p matrix
(DESIGN.md hardware-adaptation note).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class VOp:
    """V = I - rho * y s^T applied in O(p)."""
    s: jnp.ndarray
    y: jnp.ndarray
    rho: jnp.ndarray

    def __call__(self, x: jnp.ndarray, transpose: bool = False) -> jnp.ndarray:
        if transpose:   # V^T x = x - rho * s (y . x)
            return x - self.rho * self.s * jnp.dot(self.y, x)
        return x - self.rho * self.y * jnp.dot(self.s, x)


def make_v(s: jnp.ndarray, y: jnp.ndarray) -> VOp:
    rho = 1.0 / jnp.dot(s, y)
    return VOp(s=s, y=y, rho=rho)


def bfgs_inverse_update(h_inv: jnp.ndarray, s: jnp.ndarray,
                        y: jnp.ndarray) -> jnp.ndarray:
    """Dense BFGS inverse update (eq. 4.13), used on the p x p convex head."""
    v = make_v(s, y)
    rho = v.rho
    # V^T H V computed with two rank-1 applications: cost O(p^2)
    hv = h_inv - jnp.outer(h_inv @ v.y, v.s) * rho          # H V
    vthv = hv - jnp.outer(v.s, v.y @ hv) * rho              # V^T (H V)
    return vthv + rho * jnp.outer(s, s)


def bfgs_dir_product(h_inv_apply: Callable[[jnp.ndarray], jnp.ndarray],
                     v: VOp, g: jnp.ndarray,
                     rho_term: bool = True) -> jnp.ndarray:
    """h = V^T H^{-1} V g (+ rho s s^T g): the machine-side product in (4.15)
    plus the center-side rank-1 term. ``h_inv_apply`` is any linear operator
    (dense solve for the convex head, L-BFGS two-loop at NN scale)."""
    out = v(g, transpose=False)
    out = h_inv_apply(out)
    out = v(out, transpose=True)
    if rho_term:
        out = out + v.rho * v.s * jnp.dot(v.s, g)
    return out


# ------------------------------------------------------------- L-BFGS

@dataclasses.dataclass
class LBFGSMemory:
    """Fixed-size (s, y) history for two-loop products at NN scale."""
    s_hist: jnp.ndarray      # (hist, p)
    y_hist: jnp.ndarray      # (hist, p)
    count: jnp.ndarray       # scalar int

    @staticmethod
    def init(hist: int, p: int, dtype=jnp.float32) -> "LBFGSMemory":
        return LBFGSMemory(jnp.zeros((hist, p), dtype),
                           jnp.zeros((hist, p), dtype),
                           jnp.zeros((), jnp.int32))

    def push(self, s: jnp.ndarray, y: jnp.ndarray) -> "LBFGSMemory":
        s_hist = jnp.roll(self.s_hist, -1, axis=0).at[-1].set(s)
        y_hist = jnp.roll(self.y_hist, -1, axis=0).at[-1].set(y)
        return LBFGSMemory(s_hist, y_hist, self.count + 1)


jax.tree_util.register_pytree_node(
    LBFGSMemory,
    lambda mem: ((mem.s_hist, mem.y_hist, mem.count), None),
    lambda _, kids: LBFGSMemory(*kids),
)


def lbfgs_two_loop(mem: LBFGSMemory, g: jnp.ndarray,
                   gamma: float = 1.0) -> jnp.ndarray:
    """Standard two-loop recursion; empty slots are masked out."""
    hist = mem.s_hist.shape[0]
    valid = jnp.arange(hist) >= jnp.maximum(hist - mem.count, 0)

    def bwd(carry, inp):
        q = carry
        s, y, ok = inp
        rho = jnp.where(ok, 1.0 / jnp.maximum(jnp.dot(s, y), 1e-12), 0.0)
        a = rho * jnp.dot(s, q)
        return q - jnp.where(ok, a, 0.0) * y, a

    q, alphas = jax.lax.scan(bwd, g, (mem.s_hist, mem.y_hist, valid),
                             reverse=True)
    r = gamma * q

    def fwd(carry, inp):
        r = carry
        s, y, ok, a = inp
        rho = jnp.where(ok, 1.0 / jnp.maximum(jnp.dot(s, y), 1e-12), 0.0)
        b = rho * jnp.dot(y, r)
        return r + jnp.where(ok, a - b, 0.0) * s, None

    r, _ = jax.lax.scan(fwd, r, (mem.s_hist, mem.y_hist, valid, alphas))
    return r
