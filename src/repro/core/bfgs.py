"""BFGS machinery (paper §4.1 and eq. 4.13) + L-BFGS two-loop.

The protocol's second iteration updates every machine's inverse Hessian by

    H^+ = V^T H V + rho * s s^T,      V = I - rho * y s^T,
    rho = 1 / (s^T y),   s = theta_os - theta_cq,   y = g_diff,

and only ever needs matrix-vector products with V — we exploit the rank-1
structure (``VOp``) so the center never materialises a p x p matrix
(DESIGN.md hardware-adaptation note).

Memory budget at model scale: the dense p x p inverse stays confined to
the convex head (``bfgs_inverse_update``).  For the pytree engine the
curvature state is an ``LBFGSMemory`` of ``hist`` (s, y) PAIRS — leaves
shaped ``(hist, *leaf)`` — so quasi-Newton state costs ``2 * hist``
parameter copies (hist=5 -> 10 copies) instead of p^2 floats; the
two-loop recursion (``lbfgs_two_loop_tree``) applies the implied inverse
Hessian with tree-wide inner products and never materialises a matrix.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.transport import tree_dot, tree_scale


@dataclasses.dataclass(frozen=True)
class VOp:
    """V = I - rho * y s^T applied in O(p)."""
    s: jnp.ndarray
    y: jnp.ndarray
    rho: jnp.ndarray

    def __call__(self, x: jnp.ndarray, transpose: bool = False) -> jnp.ndarray:
        if transpose:   # V^T x = x - rho * s (y . x)
            return x - self.rho * self.s * jnp.dot(self.y, x)
        return x - self.rho * self.y * jnp.dot(self.s, x)


def make_v(s: jnp.ndarray, y: jnp.ndarray) -> VOp:
    rho = 1.0 / jnp.dot(s, y)
    return VOp(s=s, y=y, rho=rho)


def bfgs_inverse_update(h_inv: jnp.ndarray, s: jnp.ndarray,
                        y: jnp.ndarray) -> jnp.ndarray:
    """Dense BFGS inverse update (eq. 4.13), used on the p x p convex head."""
    v = make_v(s, y)
    rho = v.rho
    # V^T H V computed with two rank-1 applications: cost O(p^2)
    hv = h_inv - jnp.outer(h_inv @ v.y, v.s) * rho          # H V
    vthv = hv - jnp.outer(v.s, v.y @ hv) * rho              # V^T (H V)
    return vthv + rho * jnp.outer(s, s)


def bfgs_dir_product(h_inv_apply: Callable[[jnp.ndarray], jnp.ndarray],
                     v: VOp, g: jnp.ndarray,
                     rho_term: bool = True) -> jnp.ndarray:
    """h = V^T H^{-1} V g (+ rho s s^T g): the machine-side product in (4.15)
    plus the center-side rank-1 term. ``h_inv_apply`` is any linear operator
    (dense solve for the convex head, L-BFGS two-loop at NN scale)."""
    out = v(g, transpose=False)
    out = h_inv_apply(out)
    out = v(out, transpose=True)
    if rho_term:
        out = out + v.rho * v.s * jnp.dot(v.s, g)
    return out


# ------------------------------------------------------------- L-BFGS

@dataclasses.dataclass
class LBFGSMemory:
    """Fixed-size (s, y) history for two-loop products at NN scale.

    ``s_hist``/``y_hist`` are either flat ``(hist, p)`` arrays (the
    historical convex path) or pytrees with ``(hist, *leaf)`` leaves (the
    model-zoo path) — the flat form IS the single-leaf special case.  A
    leading machine axis may sit in front of ``hist`` when per-machine
    memories are carried under ``jax.vmap``.
    """
    s_hist: Any              # (hist, p) array or pytree of (hist, *leaf)
    y_hist: Any
    count: jnp.ndarray       # scalar int

    @staticmethod
    def init(hist: int, p: int, dtype=jnp.float32) -> "LBFGSMemory":
        return LBFGSMemory(jnp.zeros((hist, p), dtype),
                           jnp.zeros((hist, p), dtype),
                           jnp.zeros((), jnp.int32))

    @staticmethod
    def init_like(hist: int, tree: Any,
                  machines: Optional[int] = None) -> "LBFGSMemory":
        """Zeroed history shaped after ``tree``; with ``machines=m`` the
        leaves get a leading machine axis ``(m, hist, *leaf)`` (and
        ``count`` becomes ``(m,)``) for per-machine memories that a
        ``jax.vmap`` over machines strips back down."""
        lead = (machines, hist) if machines else (hist,)

        def zeros(p):
            return jnp.zeros(lead + tuple(p.shape), p.dtype)
        count = jnp.zeros((machines,) if machines else (), jnp.int32)
        return LBFGSMemory(jax.tree_util.tree_map(zeros, tree),
                           jax.tree_util.tree_map(zeros, tree), count)

    def push(self, s: Any, y: Any) -> "LBFGSMemory":
        def roll(hist, v):
            return jnp.roll(hist, -1, axis=0).at[-1].set(v)
        s_hist = jax.tree_util.tree_map(roll, self.s_hist, s)
        y_hist = jax.tree_util.tree_map(roll, self.y_hist, y)
        return LBFGSMemory(s_hist, y_hist, self.count + 1)


jax.tree_util.register_pytree_node(
    LBFGSMemory,
    lambda mem: ((mem.s_hist, mem.y_hist, mem.count), None),
    lambda _, kids: LBFGSMemory(*kids),
)


def lbfgs_two_loop(mem: LBFGSMemory, g: jnp.ndarray,
                   gamma: float = 1.0) -> jnp.ndarray:
    """Standard two-loop recursion; empty slots are masked out."""
    hist = mem.s_hist.shape[0]
    valid = jnp.arange(hist) >= jnp.maximum(hist - mem.count, 0)

    def bwd(carry, inp):
        q = carry
        s, y, ok = inp
        rho = jnp.where(ok, 1.0 / jnp.maximum(jnp.dot(s, y), 1e-12), 0.0)
        a = rho * jnp.dot(s, q)
        return q - jnp.where(ok, a, 0.0) * y, a

    q, alphas = jax.lax.scan(bwd, g, (mem.s_hist, mem.y_hist, valid),
                             reverse=True)
    r = gamma * q

    def fwd(carry, inp):
        r = carry
        s, y, ok, a = inp
        rho = jnp.where(ok, 1.0 / jnp.maximum(jnp.dot(s, y), 1e-12), 0.0)
        b = rho * jnp.dot(y, r)
        return r + jnp.where(ok, a - b, 0.0) * s, None

    r, _ = jax.lax.scan(fwd, r, (mem.s_hist, mem.y_hist, valid, alphas))
    return r


def lbfgs_two_loop_tree(mem: LBFGSMemory, g: Any, gamma=1.0) -> Any:
    """Two-loop recursion over an arbitrary gradient pytree.

    ``jax.lax.scan`` slices every history leaf along its ``hist`` axis, so
    each step sees one (s, y) pytree pair; curvatures are tree-wide inner
    products. On a single flat leaf this computes exactly what
    ``lbfgs_two_loop`` computes (asserted in tests/test_protocol_pytree.py).
    """
    hist_leaves = jax.tree_util.tree_leaves(mem.s_hist)
    hist = hist_leaves[0].shape[0]
    valid = jnp.arange(hist) >= jnp.maximum(hist - mem.count, 0)

    def bwd(q, inp):
        s, y, ok = inp
        rho = jnp.where(ok, 1.0 / jnp.maximum(tree_dot(s, y), 1e-12), 0.0)
        a = rho * tree_dot(s, q)
        coef = jnp.where(ok, a, 0.0)
        q = jax.tree_util.tree_map(lambda qq, yy: qq - coef * yy, q, y)
        return q, a

    q, alphas = jax.lax.scan(bwd, g, (mem.s_hist, mem.y_hist, valid),
                             reverse=True)
    r = tree_scale(gamma, q)

    def fwd(r, inp):
        s, y, ok, a = inp
        rho = jnp.where(ok, 1.0 / jnp.maximum(tree_dot(s, y), 1e-12), 0.0)
        b = rho * tree_dot(y, r)
        coef = jnp.where(ok, a - b, 0.0)
        r = jax.tree_util.tree_map(lambda rr, ss: rr + coef * ss, r, s)
        return r, None

    r, _ = jax.lax.scan(fwd, r, (mem.s_hist, mem.y_hist, valid, alphas))
    return r


def lbfgs_gamma(mem: LBFGSMemory) -> jnp.ndarray:
    """Barzilai–Borwein initial scaling gamma = s.y / y.y of the most
    recent pair; 1.0 while the memory is empty."""
    s_last = jax.tree_util.tree_map(lambda h: h[-1], mem.s_hist)
    y_last = jax.tree_util.tree_map(lambda h: h[-1], mem.y_hist)
    sy = tree_dot(s_last, y_last)
    yy = tree_dot(y_last, y_last)
    return jnp.where(mem.count > 0,
                     sy / jnp.maximum(yy, 1e-12), 1.0).astype(jnp.float32)
