"""DEPRECATED shim — the Byzantine threat models moved to
``repro.attacks`` (the registry-backed threat-model subsystem).

Import ``repro.attacks.apply_attack`` / the rule functions in new code;
this module re-exports the historical names so pinned imports keep
working, exactly like ``core/robust_agg.py`` does for ``repro.agg``.
See README "Threat models" for the registry table.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.core.byzantine is deprecated; use the repro.attacks registry "
    "(repro.attacks.apply_attack / repro.attacks.byzantine_mask) instead",
    DeprecationWarning, stacklevel=2)

from repro.attacks import (apply_attack, byzantine_mask,  # noqa: F401,E402
                           gaussian_attack, random_value_attack,
                           scaling_attack, sign_flip_attack)
