"""Byzantine failure models (paper §1.1, §5.1).

A Byzantine machine sends arbitrary statistics; the paper's experiments use
a *scaling attack*: transmit ``factor`` times the true statistic (factor -3
for synthetic, +3 for MNIST). We also implement sign-flip, additive
Gaussian, and random-value attacks for wider coverage.

``apply_attack(values, mask, ...)`` corrupts the machine-axis rows selected
by ``mask`` — it is applied to the *transmitted* message only, matching the
paper's threat model (local data stays clean; the wire is corrupted).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def byzantine_mask(key: jax.Array, m: int, alpha: float) -> jnp.ndarray:
    """Choose floor(alpha*m) machines (excluding the center, which is machine
    index -1 conceptually; the caller decides indexing)."""
    n_byz = int(alpha * m)
    perm = jax.random.permutation(key, m)
    return jnp.zeros((m,), bool).at[perm[:n_byz]].set(True)


def scaling_attack(values: jnp.ndarray, factor: float = -3.0) -> jnp.ndarray:
    return factor * values


def sign_flip_attack(values: jnp.ndarray) -> jnp.ndarray:
    return -values


def gaussian_attack(values: jnp.ndarray, key: jax.Array,
                    sigma: float = 10.0) -> jnp.ndarray:
    return values + sigma * jax.random.normal(key, values.shape, values.dtype)


def random_value_attack(values: jnp.ndarray, key: jax.Array,
                        scale: float = 10.0) -> jnp.ndarray:
    return scale * jax.random.normal(key, values.shape, values.dtype)


def apply_attack(values: jnp.ndarray, mask: jnp.ndarray,
                 attack: str = "scale", factor: float = -3.0,
                 key: jax.Array | None = None) -> jnp.ndarray:
    """values: (m, ...); mask: (m,) bool. Returns corrupted copy."""
    if attack == "none":
        return values
    if attack == "scale":
        bad = scaling_attack(values, factor)
    elif attack == "signflip":
        bad = sign_flip_attack(values)
    elif attack == "gauss":
        bad = gaussian_attack(values, key, sigma=abs(factor))
    elif attack == "random":
        bad = random_value_attack(values, key, scale=abs(factor))
    else:
        raise ValueError(f"unknown attack {attack!r}")
    mask = mask.reshape((-1,) + (1,) * (values.ndim - 1))
    return jnp.where(mask, bad, values)
