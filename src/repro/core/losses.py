"""Convex M-estimation losses (paper eq. 1.1; experiments §5).

Each problem exposes mean loss / gradient / Hessian over a data shard plus
the per-sample quantities needed by the protocol's variance estimators
(Lemma 4.2, eqs. 4.10/4.16). Closed forms are used (autodiff agreement is
asserted in tests/test_losses.py).

Data convention: ``X`` is (n, p), ``y`` is (n,); theta is (p,).
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp


def _sigmoid(z):
    return jax.nn.sigmoid(z)


class MEstimationProblem:
    name: str = "base"

    # -- per-sample primitives -------------------------------------------
    def point_loss(self, theta, x, y):
        raise NotImplementedError

    def point_grad(self, theta, x, y):
        raise NotImplementedError

    def point_hess_weight(self, theta, x, y):
        """Scalar w(x, y, theta) with hess = w * x x^T (GLM structure)."""
        raise NotImplementedError

    # -- shard-level reductions ------------------------------------------
    def loss(self, theta, X, y):
        return jnp.mean(self.point_loss(theta, X, y))

    def grad(self, theta, X, y):
        """(p,) mean gradient nabla F_j(theta)."""
        return jnp.mean(self.per_sample_grads(theta, X, y), axis=0)

    def per_sample_grads(self, theta, X, y):
        """(n, p) per-sample gradients nabla f(X_i, theta)."""
        return self.point_grad(theta, X, y)

    def hessian(self, theta, X, y):
        """(p, p) mean Hessian nabla^2 F_j(theta)."""
        w = self.point_hess_weight(theta, X, y)          # (n,)
        return (X * w[:, None]).T @ X / X.shape[0]

    def per_sample_hessians(self, theta, X, y):
        """(n, p, p); only needed for the h^(1)/h^(3) variance estimates."""
        w = self.point_hess_weight(theta, X, y)
        return w[:, None, None] * (X[:, :, None] * X[:, None, :])

    def grad_variance(self, theta, X, y):
        """(p,) per-coordinate variance of nabla f_l(X_i, theta)."""
        g = self.per_sample_grads(theta, X, y)
        return jnp.var(g, axis=0)


class LogisticRegression(MEstimationProblem):
    """f(x, y; theta) = log(1+exp(x.theta)) - y x.theta  (Experiment 1)."""
    name = "logistic"

    def point_loss(self, theta, X, y):
        z = X @ theta
        return jax.nn.softplus(z) - y * z

    def point_grad(self, theta, X, y):
        z = X @ theta
        return (_sigmoid(z) - y)[:, None] * X

    def point_hess_weight(self, theta, X, y):
        s = _sigmoid(X @ theta)
        return s * (1.0 - s)


class PoissonRegression(MEstimationProblem):
    """f = exp(x.theta) - y x.theta  (Experiment 2)."""
    name = "poisson"

    def point_loss(self, theta, X, y):
        z = X @ theta
        return jnp.exp(z) - y * z

    def point_grad(self, theta, X, y):
        z = X @ theta
        return (jnp.exp(z) - y)[:, None] * X

    def point_hess_weight(self, theta, X, y):
        return jnp.exp(X @ theta)


class LinearRegression(MEstimationProblem):
    """f = 0.5 (y - x.theta)^2."""
    name = "linear"

    def point_loss(self, theta, X, y):
        r = y - X @ theta
        return 0.5 * r * r

    def point_grad(self, theta, X, y):
        return -(y - X @ theta)[:, None] * X

    def point_hess_weight(self, theta, X, y):
        return jnp.ones_like(y)


class HuberRegression(MEstimationProblem):
    """Huber loss with threshold c (robust location-scale regression)."""
    name = "huber"

    def __init__(self, c: float = 1.345):
        self.c = c

    def point_loss(self, theta, X, y):
        r = y - X @ theta
        a = jnp.abs(r)
        return jnp.where(a <= self.c, 0.5 * r * r,
                         self.c * a - 0.5 * self.c ** 2)

    def point_grad(self, theta, X, y):
        r = y - X @ theta
        psi = jnp.clip(r, -self.c, self.c)
        return -psi[:, None] * X

    def point_hess_weight(self, theta, X, y):
        r = y - X @ theta
        return (jnp.abs(r) <= self.c).astype(X.dtype)


PROBLEMS: Dict[str, Callable[[], MEstimationProblem]] = {
    "logistic": LogisticRegression,
    "poisson": PoissonRegression,
    "linear": LinearRegression,
    "huber": HuberRegression,
}


def get_problem(name: str) -> MEstimationProblem:
    return PROBLEMS[name]()
