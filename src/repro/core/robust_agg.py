"""DEPRECATED shim — the robust aggregation baselines moved to
``repro.agg`` (the unified registry-backed aggregation subsystem).

Import ``repro.agg.aggregate`` / ``repro.agg.reference`` in new code;
this module re-exports the historical names so pinned imports keep
working. See README "repro.agg" for the migration note.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.core.robust_agg is deprecated; use the repro.agg registry "
    "(repro.agg.aggregate / repro.agg.reference) instead",
    DeprecationWarning, stacklevel=2)

from repro.agg.reference import (geometric_median_agg, mean_agg,  # noqa: F401,E402
                                 median_agg, trimmed_mean_agg)


def aggregate(values, method: str = "dcq", scale=None, K: int = 10,
              trim_beta: float = 0.2, axis: int = 0):
    """Historical dispatch table; now routes through the repro.agg
    registry (reference backend, preserving the pre-registry numerics)."""
    from repro.agg import aggregate as _aggregate
    try:
        # repro: allow(wire-boundary) — deprecated shim whose whole job is
        # the historical raw dispatch (reference backend, ValueError
        # contract); new code imports repro.agg / the transport wire.
        return _aggregate(values, method, scale=scale, K=K,
                          trim_beta=trim_beta, axis=axis,
                          backend="reference")
    except KeyError as e:            # historical contract raised ValueError
        raise ValueError(str(e)) from None
