"""Robust aggregation baselines the paper compares against (§1.1).

Coordinate-wise median (Yin et al. 2018), trimmed mean (Yin et al. 2018/19),
geometric median (Chen et al. 2017), and the non-robust mean. All operate
over a leading machine axis and serve two consumers: the convex protocol
(core/protocol.py) and the training-time gradient aggregator
(repro.dist.grad_agg.aggregate_machine_axis dispatches here for every
method except its MAD-scaled DCQ path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dcq import dcq


def mean_agg(values, axis: int = 0):
    return jnp.mean(values, axis=axis)


def median_agg(values, axis: int = 0):
    return jnp.median(values, axis=axis)


def trimmed_mean_agg(values, beta: float = 0.2, axis: int = 0):
    """Coordinate-wise beta-trimmed mean (Yin et al. 2018 convention): drop
    the floor(beta*m) smallest AND the floor(beta*m) largest entries per
    coordinate, keeping the central (1-2*beta) fraction. Robust to an
    alpha-fraction of Byzantine machines whenever beta >= alpha; on clean
    normal data ARE = 1 - 2*beta relative to the mean (so beta must be
    < 1/2)."""
    values = jnp.moveaxis(values, axis, 0)
    m = values.shape[0]
    g = max(int(beta * m), 0)
    srt = jnp.sort(values, axis=0)
    if 2 * g >= m:
        raise ValueError(f"trim fraction {beta} too large for m={m}")
    kept = srt[g:m - g]
    return kept.mean(axis=0)


def geometric_median_agg(values, axis: int = 0, iters: int = 50,
                         eps: float = 1e-8):
    """Weiszfeld iteration for the geometric median of m vectors."""
    values = jnp.moveaxis(values, axis, 0)          # (m, ...)
    m = values.shape[0]
    flat = values.reshape(m, -1)

    def step(z, _):
        d = jnp.linalg.norm(flat - z[None], axis=1)
        w = 1.0 / jnp.maximum(d, eps)
        z_new = (w[:, None] * flat).sum(0) / w.sum()
        return z_new, None

    z0 = jnp.median(flat, axis=0)
    z, _ = jax.lax.scan(step, z0, None, length=iters)
    return z.reshape(values.shape[1:])


def aggregate(values, method: str = "dcq", scale=None, K: int = 10,
              trim_beta: float = 0.2, axis: int = 0):
    """Dispatch table used by the protocol and the gradient aggregator."""
    if method == "mean":
        return mean_agg(values, axis=axis)
    if method == "median":
        return median_agg(values, axis=axis)
    if method == "trimmed":
        return trimmed_mean_agg(values, beta=trim_beta, axis=axis)
    if method == "geomedian":
        return geometric_median_agg(values, axis=axis)
    if method == "dcq":
        if scale is None:
            raise ValueError("DCQ needs a per-coordinate scale")
        return dcq(values, scale, K=K, axis=axis)
    raise ValueError(f"unknown aggregator {method!r}")
