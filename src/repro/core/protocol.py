"""Algorithm 1: robust distributed quasi-Newton estimation with DP (§4).

Single-host reference implementation: machines are a leading axis, local
computations are vmapped, "transmissions" are explicit arrays so Byzantine
corruption and DP noise are applied exactly where the paper applies them
(on the wire). Every machine-local computation is routed through a
pluggable ``machine_map`` (default: jax.vmap); the shard_map SPMD version
(dist/sharded_protocol.py) swaps in a mesh-sharded map and reuses all the
central math below verbatim, so the two agree up to collective reduction
order (tested in tests/test_dist.py).

Every center-side reduction — the per-round aggregation AND the
untrusted-center median/variance plug-ins — routes through the
``repro.agg`` registry (jnp reference off-TPU, the batched Pallas
order-statistics kernel on TPU), so the protocol inherits any newly
registered aggregator via ``cfg.aggregator``. Symmetrically, every wire
corruption routes through the ``repro.attacks`` registry: the ``attack``
argument names a registered threat model, corruption is applied where the
full machine axis is visible (omniscient attacks read honest-row
statistics), and round-aware attacks receive the transmission index.

Round structure (five p-vector transmissions):
  R1  theta_hat_j + b1          -> DCQ -> theta_cq            (4.2)/(4.4)
  R2  grad_j(theta_cq) + b2     -> DCQ -> g_cq                (4.6)
  R3  Hinv_j g_cq + b3          -> DCQ -> H1; theta_os        (4.7)/(4.8)
  R4  grad-diff + b4            -> DCQ -> gdiff_cq, g_os      (4.12)
  R5  V^T Hinv_j V g_os + b5    -> DCQ -> H2; theta_qn        (4.15)

In ``center_trust="untrusted"`` mode (§4.3) the node machines additionally
transmit DP gradient variances ("R2b var"), making SIX DP transmissions;
the per-transmission budget is eps/6 so basic composition still totals the
configured (eps, delta).

Compile-once engine: ``protocol_rounds`` is a *pure* function of arrays and
static config — no ``float()`` on traced values, no Python-side accountant
mutation — so it jits once per (shape, config) and vmaps over Monte-Carlo
replicate keys. ``DPQNProtocol`` is the thin stateful shell: ``run`` calls
the cached compiled core and reconstructs ``PrivacyAccountant``/``noise_sd``
from the returned spend ledger *outside* the traced region;
``run_monte_carlo`` batches the core over replicate keys with a single
jit(vmap(...)) trace.

Indexing note: the paper takes the median over machines [m]_0 but sums the
CQ correction over node machines [m] only; we aggregate uniformly over all
m+1 transmitted values (an O(1/m) difference, recorded in DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.agg import median_deviation_variance
from repro.configs.base import ProtocolConfig, TreeProtocolConfig
from repro.core import dp, local
from repro.core import transport
from repro.core.bfgs import (LBFGSMemory, VOp, lbfgs_gamma,
                             lbfgs_two_loop_tree, make_v)
from repro.core.losses import MEstimationProblem
from repro.core.transport import (tree_add, tree_axpy, tree_sub,
                                  wire_aggregate, wire_corrupt, wire_noise)


def vmap_machines(fn, *machine_args, bcast=()):
    """Default machine map: vmap ``fn`` over the leading machine axis of
    ``machine_args``; ``bcast`` entries are passed whole to every machine.
    dist/sharded_protocol.py provides the mesh-sharded drop-in."""
    return jax.vmap(lambda *ma: fn(*ma, *bcast))(*machine_args)


def monte_carlo_mrse(thetas: jnp.ndarray, target: jnp.ndarray) -> float:
    """Mean root-square error over the replicate axis of a
    ``run_monte_carlo`` output field: thetas (reps, p), target (p,)."""
    return float(jnp.mean(jnp.linalg.norm(thetas - target, axis=-1)))


# ------------------------------------------------------------ budget layout

#: transmission name -> reported-noise key in ``ProtocolResult.noise_sd``
_SD_KEY = {"R1 theta": "s1", "R2 grad": "s2", "R2b var": "s6",
           "R3 newton-dir": "s3", "R4 grad-diff": "s4", "R5 bfgs-dir": "s5"}


def transmission_names(cfg: ProtocolConfig) -> Tuple[str, ...]:
    """The DP transmissions Algorithm 1 performs under ``cfg``, in order.

    Trusted center: the five p-vector rounds. Untrusted center (§4.3): the
    node machines additionally transmit DP gradient variances after R2.
    """
    names = ["R1 theta", "R2 grad", "R3 newton-dir", "R4 grad-diff",
             "R5 bfgs-dir"]
    if cfg.n_rounds != len(names):
        raise ValueError(
            f"Algorithm 1 performs exactly {len(names)} vector rounds; "
            f"cfg.n_rounds={cfg.n_rounds} would desynchronise the privacy "
            f"budget split from the actual transmissions")
    if cfg.center_trust == "untrusted":
        names.insert(2, "R2b var")
    return tuple(names)


def n_transmissions(cfg: ProtocolConfig) -> int:
    return len(transmission_names(cfg))


def round_budget(cfg: ProtocolConfig) -> Tuple[float, float]:
    """Per-transmission (eps, delta) so basic composition totals the budget.

    Derived from the ACTUAL number of DP transmissions in the configured
    mode — 6 in untrusted-center mode, not ``cfg.n_rounds = 5`` — so the
    accountant never over-spends (regression: tests/test_protocol_engine.py).
    """
    k = n_transmissions(cfg)
    return cfg.eps / k, cfg.delta / k


def accountant_round_budget(cfg: ProtocolConfig) -> Tuple[float, float]:
    """Per-transmission budget certified by ``cfg.accountant``.

    ``"basic"`` routes through :func:`round_budget` unchanged (the exact
    historical floats); other registry entries invert their composition
    host-side (repro.privacy) — e.g. "rdp" records the LARGER standalone
    per-round eps whose Renyi composition still totals (cfg.eps,
    cfg.delta).
    """
    if cfg.accountant == "basic":
        return round_budget(cfg)
    from repro.privacy import get_accountant
    return get_accountant(cfg.accountant).per_round(
        cfg.eps, cfg.delta, n_transmissions(cfg))


def calibrate_sigma_base(cfg: ProtocolConfig, p: int, n: int,
                         eps=None, delta=None, accountant=None) -> Tuple:
    """Per-transmission BASE noise sds (norm factors = 1), aligned with
    ``transmission_names``. The budget dependence of Algorithm 1's noise
    calibration lives entirely in these scalars, so the sweep executor can
    compute them host-side in float64 per scenario and batch them along a
    vmap axis (``protocol_rounds(sigma_base=...)``) — scenarios that differ
    only in (eps, delta) then share one compiled executable AND match the
    compile-once static path bit-for-bit.

    ``eps``/``delta`` override the totals in ``cfg``; Python floats keep
    exact ``math`` arithmetic, traced scalars route through the dual-mode
    dp.py calibration. ``accountant`` overrides ``cfg.accountant``: the
    basic Thm 4.5 sds are scaled by the accountant's noise-multiplier
    ratio vs basic (repro.privacy). "basic"/"subexp" sds are NEVER
    rescaled (ratio is the literal 1.0 and the multiply is skipped), so
    the default stays byte-identical to the committed golden; non-basic
    accountants bisect host-side and therefore need Python-float budgets.
    """
    eps_t = cfg.eps if eps is None else eps
    delta_t = cfg.delta if delta is None else delta
    acct = cfg.accountant if accountant is None else accountant
    k = n_transmissions(cfg)
    eps_r, delta_r = eps_t / k, delta_t / k
    nl = cfg.noiseless
    s1 = dp.s1_theta(p, n, cfg.gammas[0], eps_r, delta_r, 1.0, cfg.tail)
    s2 = dp.s2_grad(p, n, cfg.gammas[1], eps_r, delta_r, cfg.tail)
    s3 = 0.0 if nl else dp.s3_newton_dir(p, n, cfg.gammas[2], eps_r, delta_r,
                                         1.0, 1.0, cfg.tail)
    s4 = 0.0 if nl else dp.s4_grad_diff(p, n, cfg.gammas[3], eps_r, delta_r,
                                        1.0, cfg.tail)
    s5 = 0.0 if nl else dp.s5_bfgs_dir(p, n, cfg.gammas[4], eps_r, delta_r,
                                       1.0, 1.0, cfg.tail)
    out = [s1, s2, s3, s4, s5]
    if cfg.center_trust == "untrusted":
        out.insert(2, dp.s6_variance(p, n, 1.0, eps_r, delta_r))
    if acct != "basic":
        from repro.privacy import multiplier_ratio
        ratio = multiplier_ratio(acct, eps_t, delta_t, k)
        if ratio != 1.0:
            out = [s * ratio for s in out]
    return tuple(out)


def _failure_probs(cfg: ProtocolConfig, p: int, n: int) -> Tuple[float, ...]:
    """Per-transmission sensitivity-failure probabilities (Lemmas 4.3/4.4),
    aligned with ``transmission_names``. Static in shapes and config.

    High-probability accountants ("subexp") record the Lemma 4.4 failure
    probability for EVERY mean-mechanism transmission — each of R1..R5 is
    a release whose sensitivity bound only holds on the tail event; other
    accountants keep the historical R1/R2 records.
    """
    if cfg.accountant != "basic":
        from repro.privacy import get_accountant
        acct = get_accountant(cfg.accountant)
        if acct.failure_prob is not None:
            probs = [acct.failure_prob(p, n, g) for g in cfg.gammas]
            if cfg.center_trust == "untrusted":
                # Thm 4.6 variance release: sub-Gaussian bound at gamma=1.
                probs.insert(2, dp.mean_dp_failure_prob_subgauss(p, n,
                                                                 1.0, 1.0))
            return tuple(probs)
    f1 = dp.mean_dp_failure_prob_subexp(p, n, cfg.gammas[0], 1.0, 1.0)
    f2 = dp.mean_dp_failure_prob_subexp(p, n, cfg.gammas[1], 1.0, 1.0)
    probs = [f1, f2, 0.0, 0.0, 0.0]
    if cfg.center_trust == "untrusted":
        probs.insert(2, 0.0)
    return tuple(probs)


class ProtocolArrays(NamedTuple):
    """Everything ``protocol_rounds`` produces, as arrays only — a valid jit
    output and a valid vmap carrier. The stateful shell turns this back into
    ``ProtocolResult`` (accountant, noise_sd floats) outside the trace."""
    theta_cq: jnp.ndarray        # initial DCQ estimator (4.4)
    theta_os: jnp.ndarray        # one-stage estimator (4.8)
    theta_qn: jnp.ndarray        # final quasi-Newton estimator
    sigmas: jnp.ndarray          # (n_tx,) reported noise sd per transmission
    ledger_eps: jnp.ndarray      # (n_tx,) per-transmission eps spend
    ledger_delta: jnp.ndarray    # (n_tx,) per-transmission delta spend
    failure_probs: jnp.ndarray   # (n_tx,) sensitivity failure probabilities
    v_s: jnp.ndarray             # BFGS curvature pair: s = theta_os - theta_cq
    v_y: jnp.ndarray             # y = gdiff_cq
    v_rho: jnp.ndarray           # rho = 1 / (s . y)


@dataclasses.dataclass
class ProtocolResult:
    theta_cq: jnp.ndarray          # initial DCQ estimator (4.4)
    theta_os: jnp.ndarray          # one-stage estimator (4.8)
    theta_qn: jnp.ndarray          # final quasi-Newton estimator
    accountant: dp.PrivacyAccountant
    noise_sd: Dict[str, float]
    v_op: Optional[VOp] = None
    arrays: Optional[ProtocolArrays] = None


# ------------------------------------------------------------ the pure core

def protocol_rounds(key: jax.Array, X: jnp.ndarray, y: jnp.ndarray,
                    problem: MEstimationProblem, cfg: ProtocolConfig,
                    byz_mask: Optional[jnp.ndarray] = None,
                    attack: str = "scale", attack_factor=-3.0,
                    theta0: Optional[jnp.ndarray] = None,
                    theta_cq_override: Optional[jnp.ndarray] = None,
                    machine_map=vmap_machines,
                    eps=None, delta=None,
                    sigma_base=None) -> ProtocolArrays:
    """Paper Algorithm 1 as a pure function: arrays in, arrays out.

    jit-compatible with ``problem``/``cfg``/``attack``/``machine_map``
    static (they are baked into the trace; ``DPQNProtocol`` closes over
    them), and vmap-compatible over ``key`` for Monte-Carlo replicates.
    ``X``: (m+1, n, p), ``y``: (m+1, n); machine 0 is the central processor.

    ``eps``/``delta`` optionally override the TOTAL privacy budget in
    ``cfg`` and may be traced scalars; ``sigma_base`` optionally supplies
    the (n_tx,) per-transmission base noise sds from
    ``calibrate_sigma_base`` — the sweep executor computes them host-side
    in float64 per scenario and vmaps over them, so scenarios differing
    only in privacy budget share one compiled executable and reproduce the
    static path bit-for-bit.
    """
    prob = problem
    m_plus_1, n, p = X.shape
    if eps is None and delta is None:
        eps_r, delta_r = accountant_round_budget(cfg)  # exact Python floats
    else:
        # Traced-budget path (the sweep's vmap axis): the ledger arrays
        # carry the basic eps/k share — the per-transmission budget a
        # non-basic accountant certifies is not traceable (bisection), so
        # the executor records it host-side in the artifact spend record.
        k_tx = n_transmissions(cfg)
        eps_r = (cfg.eps if eps is None else eps) / k_tx
        delta_r = (cfg.delta if delta is None else delta) / k_tx
    if sigma_base is None:
        sigma_base = calibrate_sigma_base(cfg, p, n, eps=eps, delta=delta)
    sb = dict(zip(transmission_names(cfg), sigma_base))
    sig = []                         # per-transmission reported noise sd
    if byz_mask is None:
        byz_mask = jnp.zeros((m_plus_1,), bool)
    else:
        # center (machine 0) is honest in trusted mode
        byz_mask = jnp.concatenate([jnp.zeros((1,), bool), byz_mask])
    keys = jax.random.split(key, 16)
    if theta0 is None:
        theta0 = jnp.zeros((p,), X.dtype)

    # The wire primitives are the shared pytree transport layer
    # (core/transport.py): on these flat single-leaf arrays they consume
    # each transmission key unsplit, so the refactor is byte-identical to
    # the historical inline expressions (tests/test_protocol_pytree.py).
    def corrupt(vals, kk, rnd):
        # rnd = 0-based transmission index (round-aware attacks ramp on
        # it); omniscient attacks see the full machine axis here, exactly
        # the coordinated-adversary view of the wire.
        return wire_corrupt(kk, vals, byz_mask, attack=attack,
                            factor=attack_factor, round_idx=rnd)

    def noise(kk, x, s):
        return wire_noise(kk, x, s, noiseless=cfg.noiseless)

    Xc, yc = X[0], y[0]  # center's own shard

    # ---- Round 1: local M-estimators -> theta_cq ----------------------
    theta_local = machine_map(
        lambda Xi, yi, t0: local.newton_solve(prob, t0, Xi, yi,
                                              steps=cfg.newton_steps),
        X, y, bcast=(theta0,))
    # lambda_s (Assumption 7.3): fixed constant, or calibrated by EACH
    # machine from its local Hessian spectrum (local data only => no
    # extra transmission, no extra privacy cost). The center uses its
    # own lambda_0 when reconstructing the noise variance.
    if cfg.lambda_s is None:
        lam_j = machine_map(lambda Xi, yi, ti: jnp.clip(jnp.linalg.eigvalsh(
            prob.hessian(ti, Xi, yi))[0], 1e-3, None), X, y, theta_local)
    else:
        lam_j = jnp.full((m_plus_1,), cfg.lambda_s, X.dtype)
    s1_base = sb["R1 theta"]
    s1_j = s1_base / lam_j                         # per-machine sd
    s1 = wire_aggregate(s1_j, "median")            # reported/summary value
    theta_dp = noise(keys[0], theta_local, s1_j)   # per-machine (m+1,) sd
    theta_dp = corrupt(theta_dp, keys[1], 0)
    sig.append(s1)

    theta_med = wire_aggregate(theta_dp, "median")
    if cfg.center_trust == "trusted":
        sig2 = local.sandwich_diag_variance(prob, theta_med, Xc, yc)
    else:
        # untrusted center: median aggregation, no variance needed here
        sig2 = jnp.ones((p,), X.dtype)
    s1_eff = 0.0 if cfg.noiseless else s1_j[0]     # center's estimate
    scale1 = jnp.sqrt((sig2 + n * s1_eff ** 2)) / jnp.sqrt(n)
    agg1 = "median" if cfg.center_trust == "untrusted" else cfg.aggregator
    theta_cq = wire_aggregate(theta_dp, agg1, scale=scale1, K=cfg.K,
                              trim_beta=cfg.trim_beta)
    if theta_cq_override is not None:
        # warm start / ablation hook: continue the protocol from a
        # caller-supplied initial estimate.
        theta_cq = theta_cq_override

    # ---- Round 2: gradients at theta_cq -> g_cq -----------------------
    grads = machine_map(lambda Xi, yi, t: prob.grad(t, Xi, yi),
                        X, y, bcast=(theta_cq,))
    s2 = sb["R2 grad"]
    grads_dp = noise(keys[2], grads, s2)
    grads_dp = corrupt(grads_dp, keys[3], 1)
    sig.append(s2)

    s2_eff = 0.0 if cfg.noiseless else s2
    if cfg.center_trust == "trusted":
        gvar = local.grad_coordinate_variance(prob, theta_cq, Xc, yc)
    else:
        # §4.3: node machines transmit DP variances; center medians them.
        s6 = sb["R2b var"]
        # node machines only (m of m+1 rows): stays a plain vmap — the
        # slice does not divide a machine mesh evenly.
        node_gvar = jax.vmap(
            lambda Xi, yi: prob.grad_variance(theta_cq, Xi, yi))(X[1:], y[1:])
        node_gvar = noise(keys[4], node_gvar, s6)
        node_gvar = wire_corrupt(keys[5], node_gvar, byz_mask[1:],
                                 attack=attack, factor=attack_factor,
                                 round_idx=1)
        gvar = wire_aggregate(node_gvar, "median")
        sig.append(s6)
    scale2 = jnp.sqrt(jnp.maximum(gvar, 1e-12) + n * s2_eff ** 2) / jnp.sqrt(n)
    g_cq = _agg_for(cfg, "grad", grads_dp, scale2)

    # ---- Round 3: Newton directions -> theta_os -----------------------
    def newton_dir(Xi, yi, t_cq, g):
        h = prob.hessian(t_cq, Xi, yi) + 1e-9 * jnp.eye(p, dtype=X.dtype)
        return jnp.linalg.solve(h, g)
    dirs = machine_map(newton_dir, X, y, bcast=(theta_cq, g_cq))
    dir_norm = jnp.linalg.norm(dirs, axis=1)          # per machine (Thm 4.5(3))
    s3 = sb["R3 newton-dir"]
    s3_j = (s3 / lam_j) * dir_norm                     # per-machine sd
    dirs_dp = noise(keys[6], dirs, s3_j)           # per-machine (m+1,) sd
    dirs_dp = corrupt(dirs_dp, keys[7], 2)
    sig.append(s3)

    if cfg.center_trust == "trusted":
        hvar = local.newton_dir_variance(prob, theta_cq, Xc, yc, g_cq)
    else:
        hvar = median_deviation_variance(dirs_dp, n)
    s3_0 = (s3 / lam_j[0]) * jnp.linalg.norm(dirs[0])
    scale3 = jnp.sqrt(jnp.maximum(hvar, 1e-12) + n * s3_0 ** 2) / jnp.sqrt(n)
    H1 = _agg_for(cfg, "dir", dirs_dp, scale3)
    theta_os = theta_cq - H1

    # ---- Round 4: gradient differences -> gdiff_cq, g_os --------------
    gdiff = machine_map(lambda Xi, yi, t_os, t_cq: prob.grad(t_os, Xi, yi)
                        - prob.grad(t_cq, Xi, yi),
                        X, y, bcast=(theta_os, theta_cq))
    step = theta_os - theta_cq
    s4 = sb["R4 grad-diff"]
    s4_eff = s4 * jnp.linalg.norm(step)
    gdiff_dp = noise(keys[8], gdiff, s4_eff)
    gdiff_dp = corrupt(gdiff_dp, keys[9], 3)
    sig.append(s4)

    if cfg.center_trust == "trusted":
        gd = prob.per_sample_grads(theta_os, Xc, yc) \
            - prob.per_sample_grads(theta_cq, Xc, yc)
        gdvar = jnp.var(gd, axis=0)
        gosvar = local.grad_coordinate_variance(prob, theta_os, Xc, yc)
    else:
        gdvar = median_deviation_variance(gdiff_dp, n)
        gosvar = gvar
    scale4 = jnp.sqrt(jnp.maximum(gdvar, 1e-12)
                      + n * s4_eff ** 2) / jnp.sqrt(n)
    gdiff_cq = _agg_for(cfg, "gdiff", gdiff_dp, scale4)
    scale4b = jnp.sqrt(jnp.maximum(gosvar, 1e-12) + n * s2_eff ** 2
                       + n * s4_eff ** 2) / jnp.sqrt(n)
    g_os = _agg_for(cfg, "g_os", grads_dp + gdiff_dp, scale4b)

    # ---- Round 5: BFGS directions -> theta_qn --------------------------
    v = make_v(s=step, y=gdiff_cq)

    def bfgs_dir(Xi, yi, t_cq, vs, vy, vrho, g):
        vop = VOp(s=vs, y=vy, rho=vrho)
        h = prob.hessian(t_cq, Xi, yi) + 1e-9 * jnp.eye(p, dtype=X.dtype)
        hinv_vg = jnp.linalg.solve(h, vop(g, transpose=False))
        return vop(hinv_vg, transpose=True)            # (4.15) machine part
    h3 = machine_map(bfgs_dir, X, y,
                     bcast=(theta_cq, v.s, v.y, v.rho, g_os))
    s5 = sb["R5 bfgs-dir"]
    s5_j = s5 * jnp.linalg.norm(h3, axis=1)
    h3_dp = noise(keys[10], h3, s5_j)              # per-machine (m+1,) sd
    h3_dp = corrupt(h3_dp, keys[11], 4)
    sig.append(s5)

    if cfg.center_trust == "trusted":
        h3var = local.bfgs_dir_variance(prob, theta_cq, Xc, yc, v, g_os)
    else:
        h3var = median_deviation_variance(h3_dp, n)
    s5_0 = s5 * jnp.linalg.norm(h3[0])
    scale5 = jnp.sqrt(jnp.maximum(h3var, 1e-12) + n * s5_0 ** 2) / jnp.sqrt(n)
    h3_agg = _agg_for(cfg, "h3", h3_dp, scale5)
    # center-side rank-1 term: rho (s s^T) g_os  (below eq. 4.15)
    H2 = h3_agg + v.rho * step * jnp.dot(step, g_os)
    theta_qn = theta_os - H2

    k = n_transmissions(cfg)
    assert len(sig) == k, "spend ledger out of sync with transmission_names"
    return ProtocolArrays(
        theta_cq=theta_cq, theta_os=theta_os, theta_qn=theta_qn,
        sigmas=jnp.stack([jnp.asarray(s, jnp.float32) for s in sig]),
        ledger_eps=jnp.full((k,), eps_r, jnp.float32),
        ledger_delta=jnp.full((k,), delta_r, jnp.float32),
        failure_probs=jnp.asarray(_failure_probs(cfg, p, n), jnp.float32),
        v_s=v.s, v_y=v.y, v_rho=v.rho)


def _agg_for(cfg: ProtocolConfig, name: str, values, scale):
    """Untrusted-center mode uses the median everywhere except the gradient
    round (paper §4.3 keeps DCQ for 'crucial statistics such as gradients').

    Routed through the pytree transport layer: flat arrays hit the
    registry verbatim (byte parity), pytrees dispatch per leaf.
    """
    if cfg.center_trust == "untrusted" and name not in ("grad",):
        return wire_aggregate(values, method="median")
    return wire_aggregate(values, method=cfg.aggregator, scale=scale,
                          K=cfg.K, trim_beta=cfg.trim_beta)


# ---------------------------------------------- pytree (model-scale) engine

class ProtocolTreeArrays(NamedTuple):
    """Output of one pytree protocol step — arrays/pytrees only, a valid
    jit output and scan carrier. ``mem`` is the updated per-machine L-BFGS
    history the trainer threads into the next step."""
    theta_cq: object         # robustly aggregated params after R1
    theta_os: object         # one-stage params after R3
    theta_qn: object         # final quasi-Newton params after R5
    v_s: object              # curvature pair: s = theta_os - theta_cq
    v_y: object              # y = aggregated grad-diff (R4)
    mem: LBFGSMemory         # per-machine (s, y) history, machine axis first
    losses: jnp.ndarray      # (m,) machine-local losses at the incoming theta
    grad_norm: jnp.ndarray   # ||g_cq|| over the whole tree


def protocol_tree_rounds(key: jax.Array, theta, batches, grad_fn,
                         cfg: TreeProtocolConfig,
                         mem: Optional[LBFGSMemory] = None,
                         byz_mask: Optional[jnp.ndarray] = None,
                         attack: str = "none", attack_factor=-3.0,
                         sigmas=None, n: Optional[int] = None,
                         machine_map=vmap_machines) -> ProtocolTreeArrays:
    """Algorithm 1's five transmissions over an arbitrary parameter pytree
    — one robust DP quasi-Newton training step for the model zoo.

    The SAME wire primitives as the flat path (core/transport.py), so
    every transmission is noised per leaf (per-leaf DP calibration),
    corrupted through the ``repro.attacks`` registry, and aggregated per
    leaf through ``repro.agg``. The round mapping from the convex head:

      R1  machine-local SGD steps -> theta_j     -> agg -> theta_cq  (4.4)
      R2  grad_j(theta_cq)                       -> agg -> g_cq      (4.6)
      R3  per-machine L-BFGS dir on g_cq         -> agg -> H1;
          theta_os = theta_cq - lr * H1                              (4.8)
      R4  grad_j(theta_os) - grad_j(theta_cq)    -> agg -> y;
          s = theta_os - theta_cq                                    (4.12)
      R5  push (s, y_j^local) into machine memory; L-BFGS dir on
          g_os = g_cq + y                        -> agg -> H2;
          theta_qn = theta_os - lr * H2                              (4.15)

    Machine-local curvature: each machine pushes its OWN raw grad-diff
    (local data never leaves the machine un-noised) — the L-BFGS analog of
    the paper's machine-side H_j^{-1}; the dense p x p update of the
    convex head is replaced by the two-loop recursion over ``cfg.hist``
    (s, y) pairs.

    Pure and compile-once like ``protocol_rounds``: jit with ``grad_fn``,
    ``cfg``, ``attack``, ``machine_map`` static; vmap over ``key`` for
    replicates. ``batches``: pytree with leading machine axis m;
    ``grad_fn(theta, batch) -> (loss, grad_tree)``. ``sigmas`` overrides
    the per-leaf calibration ({transmission: sigma pytree},
    dp.calibrate_tree_sigmas); otherwise it is computed here from
    ``cfg.eps`` and ``n`` (samples per machine). ``cfg.eps <= 0`` runs
    noiseless.
    """
    m = jax.tree_util.tree_leaves(batches)[0].shape[0]
    noiseless = cfg.eps <= 0.0
    if sigmas is None and not noiseless:
        if n is None:
            raise ValueError("per-leaf DP calibration needs n (samples per "
                             "machine) when sigmas are not supplied")
        sigmas = dp.calibrate_tree_sigmas(theta, n, cfg.eps, cfg.delta,
                                          cfg.gammas, cfg.tail,
                                          accountant=cfg.accountant)
    if sigmas is None:
        sigmas = {name: 0.0 for name in dp.TREE_TRANSMISSIONS}
    if byz_mask is None:
        byz_mask = jnp.zeros((m,), bool)
    if mem is None:
        mem = LBFGSMemory.init_like(cfg.hist, theta, machines=m)
    # Same 16-way key layout as the flat path (indices 4/5 reserved for
    # the untrusted-center variance round).
    keys = jax.random.split(key, 16)

    def tx(name, rnd, k_noise, k_corrupt, values):
        vals = wire_noise(k_noise, values, sigmas[name], noiseless=noiseless)
        vals = wire_corrupt(k_corrupt, vals, byz_mask, attack=attack,
                            factor=attack_factor, round_idx=rnd)
        return wire_aggregate(vals, method=cfg.aggregator, K=cfg.K,
                              trim_beta=cfg.trim_beta)

    # ---- R1: machine-local steps -> theta_cq --------------------------
    def local_fit(batch):
        def step(t, _):
            loss, g = grad_fn(t, batch)
            return tree_axpy(-cfg.local_lr, g, t), loss
        t, losses = jax.lax.scan(step, theta, None, length=cfg.local_steps)
        return t, losses[0]
    theta_j, loss_j = machine_map(local_fit, batches)
    theta_cq = tx("R1 theta", 0, keys[0], keys[1], theta_j)

    # ---- R2: gradients at theta_cq -> g_cq ----------------------------
    g_j = machine_map(lambda b, t: grad_fn(t, b)[1], batches,
                      bcast=(theta_cq,))
    g_cq = tx("R2 grad", 1, keys[2], keys[3], g_j)

    # ---- R3: per-machine L-BFGS directions -> theta_os ----------------
    dir_j = machine_map(
        lambda mm, g: lbfgs_two_loop_tree(mm, g, gamma=lbfgs_gamma(mm)),
        mem, bcast=(g_cq,))
    H1 = tx("R3 newton-dir", 2, keys[6], keys[7], dir_j)
    theta_os = tree_axpy(-cfg.lr, H1, theta_cq)
    s_pair = tree_sub(theta_os, theta_cq)

    # ---- R4: gradient differences -> y --------------------------------
    y_j = machine_map(
        lambda b, t_os, t_cq: tree_sub(grad_fn(t_os, b)[1],
                                       grad_fn(t_cq, b)[1]),
        batches, bcast=(theta_os, theta_cq))
    y_cq = tx("R4 grad-diff", 3, keys[8], keys[9], y_j)

    # ---- R5: curvature push + L-BFGS directions -> theta_qn -----------
    def safe_push(mm, yj, s):
        # skip non-curvature pairs (s.y <= 0 would break the two-loop
        # positive-definiteness); each machine keeps its LOCAL pair.
        ok = transport.tree_dot(s, yj) > 1e-10
        pushed = mm.push(s, yj)
        return jax.tree_util.tree_map(
            lambda a, b: jnp.where(ok, a, b), pushed, mm)
    mem = machine_map(safe_push, mem, y_j, bcast=(s_pair,))
    g_os = tree_add(g_cq, y_cq)
    dir2_j = machine_map(
        lambda mm, g: lbfgs_two_loop_tree(mm, g, gamma=lbfgs_gamma(mm)),
        mem, bcast=(g_os,))
    H2 = tx("R5 bfgs-dir", 4, keys[10], keys[11], dir2_j)
    theta_qn = tree_axpy(-cfg.lr, H2, theta_os)

    return ProtocolTreeArrays(
        theta_cq=theta_cq, theta_os=theta_os, theta_qn=theta_qn,
        v_s=s_pair, v_y=y_cq, mem=mem, losses=loss_j,
        grad_norm=jnp.sqrt(transport.tree_dot(g_cq, g_cq)).astype(
            jnp.float32))


# ------------------------------------------------------- the stateful shell

class DPQNProtocol:
    """Paper Algorithm 1. ``run`` consumes pre-sharded data:
    X: (m+1, n, p), y: (m+1, n); machine 0 is the central processor.

    The protocol core compiles ONCE per (attack, shape) signature and is
    reused across ``run`` calls; ``run_monte_carlo`` vmaps the same core
    over replicate keys. ``jit=False`` keeps the eager per-op path (used as
    the baseline in benchmarks/bench_protocol.py). ``trace_count`` counts
    how many times the core was (re)traced — tests assert a second call
    with identical shapes does not retrace.
    """

    def __init__(self, problem: MEstimationProblem, cfg: ProtocolConfig,
                 machine_map=None, jit: bool = True):
        self.problem = problem
        self.cfg = cfg
        # machine_map(fn, *machine_args, bcast=()) runs fn once per machine;
        # the SPMD protocol passes a shard_map-based implementation.
        self._mmap = machine_map or vmap_machines
        self._jit = jit
        self.trace_count = 0
        self._engines = {}   # attack -> (single, batched)

    def _engine(self, attack: str):
        """(single, batched-over-keys) callables for one attack mode; built
        lazily and cached so jit compiles once per protocol instance."""
        if attack not in self._engines:
            def rounds(key, X, y, byz_mask, theta0, theta_cq_override,
                       attack_factor):
                self.trace_count += 1
                return protocol_rounds(
                    key, X, y, self.problem, self.cfg, byz_mask=byz_mask,
                    attack=attack, attack_factor=attack_factor,
                    theta0=theta0, theta_cq_override=theta_cq_override,
                    machine_map=self._mmap)
            batched = jax.vmap(rounds, in_axes=(0,) + (None,) * 6)
            if self._jit:
                rounds, batched = jax.jit(rounds), jax.jit(batched)
            self._engines[attack] = (rounds, batched)
        return self._engines[attack]

    def _finalize(self, arrays: ProtocolArrays) -> ProtocolResult:
        """Rebuild the Python-side accountant from the spend ledger, OUTSIDE
        any traced region. eps/delta come from the static budget split
        (exact Python floats); sigmas/failure probs from the ledger arrays."""
        names = transmission_names(self.cfg)
        eps_r, delta_r = accountant_round_budget(self.cfg)
        acct = dp.PrivacyAccountant()
        noise_sd: Dict[str, float] = {}
        for i, name in enumerate(names):
            sigma = float(arrays.sigmas[i])
            acct.spend(name, eps_r, delta_r, sigma,
                       float(arrays.failure_probs[i]))
            noise_sd[_SD_KEY[name]] = sigma
        v = VOp(s=arrays.v_s, y=arrays.v_y, rho=arrays.v_rho)
        return ProtocolResult(
            theta_cq=arrays.theta_cq, theta_os=arrays.theta_os,
            theta_qn=arrays.theta_qn, accountant=acct, noise_sd=noise_sd,
            v_op=v, arrays=arrays)

    # -- single replicate ---------------------------------------------------
    def run(self, key: jax.Array, X: jnp.ndarray, y: jnp.ndarray,
            byz_mask: Optional[jnp.ndarray] = None,
            attack: str = "scale", attack_factor: float = -3.0,
            theta0: Optional[jnp.ndarray] = None,
            theta_cq_override: Optional[jnp.ndarray] = None) -> ProtocolResult:
        single, _ = self._engine(attack)
        arrays = single(key, X, y, byz_mask, theta0, theta_cq_override,
                        attack_factor)
        return self._finalize(arrays)

    # -- batched Monte-Carlo driver ----------------------------------------
    def run_monte_carlo(self, keys: jax.Array, X: jnp.ndarray,
                        y: jnp.ndarray,
                        byz_mask: Optional[jnp.ndarray] = None,
                        attack: str = "scale", attack_factor: float = -3.0,
                        theta0: Optional[jnp.ndarray] = None,
                        theta_cq_override: Optional[jnp.ndarray] = None
                        ) -> ProtocolArrays:
        """Run ``len(keys)`` independent replicates of Algorithm 1 in one
        compiled vmap: jit once, batch over the replicate axis. Returns a
        ``ProtocolArrays`` whose every field has a leading replicate axis
        (e.g. ``theta_qn``: (reps, p)). Data/masks are shared across
        replicates; only the PRNG key varies."""
        _, batched = self._engine(attack)
        return batched(keys, X, y, byz_mask, theta0, theta_cq_override,
                       attack_factor)
