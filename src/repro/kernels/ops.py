"""Jitted public wrappers for the Pallas kernels with platform dispatch.

On TPU the compiled kernels run natively (interpret=False); on CPU (this
container) they execute in interpret mode, or fall back to the jnp oracle
when ``prefer="jnp"`` — the oracle IS the model's default path, the
kernels are the TPU hot-spot implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import agg
from repro.kernels import gqa_decode, gqa_decode_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def dcq_aggregate(values: jnp.ndarray, K: int = 10,
                  prefer: str = "pallas") -> jnp.ndarray:
    """Robust DCQ aggregation of (m, p) -> (p,) with MAD scale; routes
    through the repro.agg registry ("dcq_mad")."""
    backend = "reference" if prefer == "jnp" else "pallas"
    # repro: allow(wire-boundary) — kernel-level back-compat shim: this IS
    # a raw registry dispatch by contract (pre-PR4 callers pin the backend
    # here); model-path consumers use wire_aggregate.
    return agg.aggregate(values, "dcq_mad", K=K, backend=backend)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     cache_len: jnp.ndarray,
                     prefer: str = "pallas") -> jnp.ndarray:
    """GQA flash-decode: q (B, Hq, Dh) vs cache (B, S, Hkv, Dh)."""
    if prefer == "jnp":
        return gqa_decode_ref.gqa_decode_reference(q, k, v, cache_len)
    return gqa_decode.gqa_decode_pallas(q, k, v, cache_len,
                                        interpret=not _on_tpu())
