"""Pallas TPU kernels.

  gqa_decode  — GQA flash-decode, one token vs long KV cache (ops.py
                dispatches; gqa_decode_ref.py is the pure-jnp oracle).

The DCQ robust-aggregation kernel moved to ``repro.agg.kernel`` — one
generalized batched order-statistics kernel (k-th / median / MAD /
trimmed / DCQ from a shared VPU bisection core). ``kernels/dcq.py`` and
``kernels/dcq_ref.py`` remain as import shims.
"""
from repro.kernels import ops

__all__ = ["ops"]
