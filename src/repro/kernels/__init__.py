"""Pallas TPU kernels for the two compute hot-spots:
  dcq         — coordinate-wise DCQ robust aggregation (VPU bisection)
  gqa_decode  — GQA flash-decode, one token vs long KV cache
Each has ops.py (platform dispatch) and *_ref.py (pure-jnp oracle).
"""
from repro.kernels import ops

__all__ = ["ops"]
