"""Pallas TPU kernel: GQA flash-decode — one query token vs a long KV cache.

Serving hot-spot (decode_32k: 128 seqs x 32k cache; long_500k via the ring
buffer). Memory-bound: the whole cache streams HBM -> VMEM once; the
kernel's job is to keep that stream dense and avoid materialising
(Hq, S) scores in HBM.

Tiling: grid = (B, Hkv, S/TS). Each program loads a (TS, Dh) K tile and V
tile for one kv head, computes (g, TS) scores for the head's g query
groups on the MXU, and maintains the online-softmax running (max, sum,
acc) in VMEM scratch across the sequential S-grid dimension (TPU grids
iterate the last axis innermost, so scratch carries state between tiles).
The final tile normalises and writes (g, Dh).

cache_len masks ring-buffer slots that are not yet written; softmax is
permutation-invariant so ring order needs no unwinding.

Validated in interpret mode against gqa_decode_ref.py over a
shape/dtype/length sweep (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, ts: int, n_tiles: int):
    t = pl.program_id(2)
    b = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (g, Dh)
    k = k_ref[0, :, 0].astype(jnp.float32)        # (TS, Dh)
    v = v_ref[0, :, 0].astype(jnp.float32)        # (TS, Dh)
    dh = q.shape[-1]
    scale = 1.0 / (dh ** 0.5)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # mask positions beyond the valid cache length
    pos = t * ts + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[b], s, NEG_INF)

    m_prev = m_ref[...]                            # (g, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                         # (g, TS)
    corr = jnp.exp(m_prev - m_new)                 # (g, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(t == n_tiles - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("ts", "interpret"))
def gqa_decode_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      cache_len: jnp.ndarray, ts: int = 512,
                      interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, Dh); k/v: (B, S, Hkv, Dh); cache_len: (B,) int32.

    Returns (B, Hq, Dh). ``ts`` is the KV tile length (S padded to a
    multiple; padded slots are masked by cache_len semantics).
    """
    B, Hq, Dh = q.shape
    _, S, Hkv, _ = k.shape
    g = Hq // Hkv
    ts = min(ts, S)
    pad = (-S) % ts
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = k.shape[1]
    n_tiles = Sp // ts
    qg = q.reshape(B, Hkv, g, Dh)

    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, n_tiles),
        in_specs=[
            pl.BlockSpec((1, 1, g, Dh), lambda b, h, t, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, ts, 1, Dh), lambda b, h, t, *_: (b, t, h, 0)),
            pl.BlockSpec((1, ts, 1, Dh), lambda b, h, t, *_: (b, t, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, Dh),
                               lambda b, h, t, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, Dh), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, ts=ts, n_tiles=n_tiles),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, Dh), q.dtype),
        interpret=interpret,
    )(cache_len.astype(jnp.int32), qg, k, v)
    return out.reshape(B, Hq, Dh)
