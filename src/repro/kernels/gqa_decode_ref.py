"""Pure-jnp oracle for the GQA flash-decode kernel: one query token per
sequence against a long KV cache (the serving hot-spot for decode_32k /
long_500k)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gqa_decode_reference(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         cache_len: jnp.ndarray) -> jnp.ndarray:
    """q: (B, Hq, Dh); k/v: (B, S, Hkv, Dh); cache_len: (B,) valid lengths.

    Returns (B, Hq, Dh) fp32-accumulated attention output.
    """
    B, Hq, Dh = q.shape
    _, S, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = 1.0 / (Dh ** 0.5)
    qg = q.reshape(B, Hkv, g, Dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k.astype(jnp.float32)) * scale
    valid = jnp.arange(S)[None] < cache_len[:, None]          # (B, S)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, Dh)
