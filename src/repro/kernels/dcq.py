"""DEPRECATED shim — the DCQ Pallas kernel is now one op of the
generalized batched order-statistics kernel in ``repro.agg.kernel``
(shared bisection rank-counting core; leading batch axes on the grid).

``dcq_pallas`` keeps its historical signature; import
``repro.agg.ostat_pallas`` for the generalized entry.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.kernels.dcq is deprecated; use repro.agg "
    "(repro.agg.dcq_pallas / repro.agg.ostat_pallas) instead",
    DeprecationWarning, stacklevel=2)

from repro.agg.kernel import N_BISECT, dcq_pallas  # noqa: F401,E402

__all__ = ["dcq_pallas", "N_BISECT"]
