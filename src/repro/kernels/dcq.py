"""Pallas TPU kernel: coordinate-wise DCQ robust aggregation.

The GPU-natural formulation (per-coordinate sort) maps poorly onto the
TPU's vector unit — there is no fast per-lane sort. Instead we compute
order statistics by *bisection rank-counting*: binary-search the value
range per coordinate, counting ranks with full-width VPU comparisons and
reductions over the machine axis. 60 halvings pin the k-th order statistic
to below fp32 resolution. The whole tile lives in VMEM:

  values tile (m, TP)  ->  med, MAD scale, K indicator sums  ->  (TP,)

Grid: one program per TP-coordinate tile; the machine axis is small
(m <= a few thousand) and stays resident. All comparisons are masked-sum
reductions — no data-dependent control flow, MXU not needed (this is a
pure VPU kernel, which is why the paper's center-side aggregation is cheap
on TPU).

Validated in interpret mode against kernels/dcq_ref.py (the pure-jnp
oracle) over a shape/dtype sweep in tests/test_kernels.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_BISECT = 60


def _kth_smallest(vals: jnp.ndarray, k: jnp.ndarray, lo: jnp.ndarray,
                  hi: jnp.ndarray) -> jnp.ndarray:
    """Bisection k-th order statistic (0-indexed) per column.

    vals: (m, tp) f32; k: scalar int; lo/hi: (tp,) bracketing values.
    Returns (tp,) the k-th smallest per column (exact as a value present
    in the column up to fp32 bisection resolution).
    """
    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        # rank of mid: how many values are <= mid
        cnt = jnp.sum((vals <= mid[None, :]).astype(jnp.float32), axis=0)
        go_right = cnt <= k.astype(jnp.float32)   # need larger values
        lo = jnp.where(go_right, mid, lo)
        hi = jnp.where(go_right, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, N_BISECT, body, (lo, hi))
    return hi     # converged upper bracket = smallest value with rank > k


def _median_cols(vals: jnp.ndarray) -> jnp.ndarray:
    """Columnwise median via one or two bisection searches. vals: (m, tp)."""
    m = vals.shape[0]
    lo = jnp.min(vals, axis=0)
    hi = jnp.max(vals, axis=0)
    if m % 2 == 1:
        k = jnp.asarray((m - 1) // 2)
        return _kth_smallest(vals, k, lo, hi)
    k1 = jnp.asarray(m // 2 - 1)
    k2 = jnp.asarray(m // 2)
    a = _kth_smallest(vals, k1, lo, hi)
    b = _kth_smallest(vals, k2, lo, hi)
    return 0.5 * (a + b)


def _dcq_kernel(values_ref, delta_ref, out_ref, *, K: int, psi_sum: float):
    vals = values_ref[...].astype(jnp.float32)            # (m, tp)
    m = vals.shape[0]
    med = _median_cols(vals)                              # (tp,)
    mad = _median_cols(jnp.abs(vals - med[None, :]))
    scale = 1.4826 * mad + 1e-12
    delta = delta_ref[...]                                # (K, 1) f32
    # composite-quantile correction: sum_k sum_j [I(v <= med+s*d_k) - kap_k]
    s = jnp.zeros_like(med)
    for k in range(K):                                    # K static (10)
        thr = med + scale * delta[k, 0]
        kappa = (k + 1.0) / (K + 1.0)
        ind = (vals <= thr[None, :]).astype(jnp.float32)
        s = s + ind.sum(axis=0) - m * kappa
    out_ref[...] = (med - scale * s / (m * psi_sum)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("K", "tile", "interpret"))
def dcq_pallas(values: jnp.ndarray, K: int = 10, tile: int = 512,
               interpret: bool = True) -> jnp.ndarray:
    """DCQ-with-MAD aggregation of (m, p) -> (p,) via the Pallas kernel.

    ``interpret=True`` executes on CPU (this container); on TPU pass
    interpret=False. p is padded to a tile multiple.
    """
    from statistics import NormalDist
    nd = NormalDist()
    m, p = values.shape
    tile = min(tile, p)
    pad = (-p) % tile
    if pad:
        values = jnp.pad(values, ((0, 0), (0, pad)))
    pp = values.shape[1]
    knots = [nd.inv_cdf((k + 1.0) / (K + 1.0)) for k in range(K)]
    delta = jnp.asarray(knots, jnp.float32)[:, None]       # (K, 1)
    psi_sum = sum(math.exp(-0.5 * d * d) for d in knots) \
        / math.sqrt(2.0 * math.pi)
    out = pl.pallas_call(
        functools.partial(_dcq_kernel, K=K, psi_sum=psi_sum),
        grid=(pp // tile,),
        in_specs=[
            pl.BlockSpec((m, tile), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), values.dtype),
        interpret=interpret,
    )(values, delta)
    return out[:p]
