"""DEPRECATED shim — the DCQ Pallas kernel is now one op of the
generalized batched order-statistics kernel in ``repro.agg.kernel``
(shared bisection rank-counting core; leading batch axes on the grid).

``dcq_pallas`` keeps its historical signature; import
``repro.agg.ostat_pallas`` for the generalized entry.
"""
from __future__ import annotations

from repro.agg.kernel import N_BISECT, dcq_pallas  # noqa: F401

__all__ = ["dcq_pallas", "N_BISECT"]
