"""DEPRECATED shim — the pure-jnp MAD-scaled DCQ oracle moved to
``repro.agg.reference.dcq_mad_reference`` (the registry's reference impl
for the ``"dcq_mad"`` aggregator).
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.kernels.dcq_ref is deprecated; use "
    "repro.agg.dcq_mad_reference (the 'dcq_mad' registry reference) "
    "instead",
    DeprecationWarning, stacklevel=2)

from repro.agg.reference import dcq_mad_reference  # noqa: F401,E402

__all__ = ["dcq_mad_reference"]
