"""Pure-jnp oracle for the DCQ robust-aggregation kernel.

Implements the MAD-scaled DCQ used by repro.dist.grad_agg (method="dcq"):
coordinate-wise median over the machine axis, MAD*1.4826 scale,
composite-quantile correction with K standard-normal knots. grad_agg
calls this oracle off-TPU and the Pallas kernel (kernels/dcq.py) on TPU;
the two must agree to fp32 tolerance for every (m, p) shape/dtype in the
sweep tests (tests/test_kernels.py).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.scipy.special import ndtri
from jax.scipy.stats import norm


def dcq_mad_reference(values: jnp.ndarray, K: int = 10) -> jnp.ndarray:
    """values: (m, p) float; returns (p,) DCQ aggregate with MAD scale."""
    values = values.astype(jnp.float32)
    m = values.shape[0]
    med = jnp.median(values, axis=0)                        # (p,)
    mad = jnp.median(jnp.abs(values - med[None]), axis=0)
    scale = 1.4826 * mad + 1e-12
    kappa = jnp.arange(1, K + 1, dtype=jnp.float32) / (K + 1)
    delta = ndtri(kappa)                                    # (K,)
    thr = med[None] + scale[None] * delta[:, None]          # (K, p)
    ind = (values[None] <= thr[:, None]).astype(jnp.float32)  # (K, m, p)
    s = (ind - kappa[:, None, None]).sum(axis=(0, 1))       # (p,)
    denom = m * norm.pdf(delta).sum()
    return med - scale * s / denom
