"""DEPRECATED shim — the pure-jnp MAD-scaled DCQ oracle moved to
``repro.agg.reference.dcq_mad_reference`` (the registry's reference impl
for the ``"dcq_mad"`` aggregator).
"""
from __future__ import annotations

from repro.agg.reference import dcq_mad_reference  # noqa: F401

__all__ = ["dcq_mad_reference"]
