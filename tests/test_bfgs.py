"""BFGS update algebra: rank-1 V operator, secant equation, L-BFGS."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bfgs import (LBFGSMemory, bfgs_dir_product,
                             bfgs_inverse_update, lbfgs_two_loop, make_v)


def _rand_spd(key, p):
    a = jax.random.normal(key, (p, p))
    return a @ a.T + p * jnp.eye(p)


def test_v_op_matches_dense():
    key = jax.random.PRNGKey(0)
    p = 7
    s = jax.random.normal(jax.random.fold_in(key, 1), (p,))
    y = jax.random.normal(jax.random.fold_in(key, 2), (p,))
    x = jax.random.normal(jax.random.fold_in(key, 3), (p,))
    v = make_v(s, y)
    v_dense = jnp.eye(p) - v.rho * jnp.outer(y, s)
    np.testing.assert_allclose(np.asarray(v(x)), np.asarray(v_dense @ x),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(v(x, transpose=True)),
                               np.asarray(v_dense.T @ x), rtol=1e-5)


def test_bfgs_update_satisfies_secant():
    """H^+ y = s (eq. 4.1): the defining quasi-Newton property."""
    key = jax.random.PRNGKey(1)
    p = 6
    h = jnp.linalg.inv(_rand_spd(jax.random.fold_in(key, 1), p))
    s = jax.random.normal(jax.random.fold_in(key, 2), (p,))
    y = jax.random.normal(jax.random.fold_in(key, 3), (p,))
    y = jnp.where(jnp.dot(s, y) > 0, y, -y)  # curvature condition
    h_new = bfgs_inverse_update(h, s, y)
    np.testing.assert_allclose(np.asarray(h_new @ y), np.asarray(s),
                               rtol=1e-4, atol=1e-5)
    # symmetry preserved
    np.testing.assert_allclose(np.asarray(h_new), np.asarray(h_new.T),
                               rtol=1e-5, atol=1e-6)


def test_bfgs_dir_product_matches_dense_update():
    key = jax.random.PRNGKey(2)
    p = 5
    h = jnp.linalg.inv(_rand_spd(jax.random.fold_in(key, 1), p))
    s = jax.random.normal(jax.random.fold_in(key, 2), (p,))
    y = s + 0.1 * jax.random.normal(jax.random.fold_in(key, 3), (p,))
    g = jax.random.normal(jax.random.fold_in(key, 4), (p,))
    v = make_v(s, y)
    h_new = bfgs_inverse_update(h, s, y)
    prod = bfgs_dir_product(lambda x: h @ x, v, g, rho_term=True)
    np.testing.assert_allclose(np.asarray(prod), np.asarray(h_new @ g),
                               rtol=1e-4, atol=1e-5)


def test_lbfgs_two_loop_matches_dense_bfgs():
    key = jax.random.PRNGKey(3)
    p, hist = 8, 4
    mem = LBFGSMemory.init(hist, p)
    h = jnp.eye(p)
    for i in range(3):
        s = jax.random.normal(jax.random.fold_in(key, 10 + i), (p,))
        y = s + 0.2 * jax.random.normal(jax.random.fold_in(key, 20 + i), (p,))
        h = bfgs_inverse_update(h, s, y)
        mem = mem.push(s, y)
    g = jax.random.normal(jax.random.fold_in(key, 99), (p,))
    np.testing.assert_allclose(np.asarray(lbfgs_two_loop(mem, g)),
                               np.asarray(h @ g), rtol=1e-4, atol=1e-4)


def test_lbfgs_empty_memory_is_identity():
    mem = LBFGSMemory.init(4, 6)
    g = jnp.arange(6.0)
    np.testing.assert_allclose(np.asarray(lbfgs_two_loop(mem, g)),
                               np.asarray(g), rtol=1e-6)
