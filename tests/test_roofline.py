"""Roofline machinery unit tests: HLO collective parser, layer
extrapolation, param counting, model-FLOP accounting."""
import pytest

from repro.configs import SHAPES, get_config
from repro.launch import roofline


HLO_SAMPLE = """
HloModule jit_step

ENTRY main {
  %p0 = bf16[16,1024]{1,0} parameter(0)
  %ag = bf16[256,1024]{1,0} all-gather(%p0), replica_groups={...}
  %ar = f32[512]{0} all-reduce(%x), to_apply=%sum
  %rs-start = f32[32]{0} reduce-scatter-start(%y)
  %a2a = (f32[8,4]{1,0}, f32[8,4]{1,0}) all-to-all(%u, %v)
  %cp = u32[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %ag2-start = bf16[64]{0} all-gather-start(%z)
  %ag2-done = bf16[64]{0} all-gather-done(%ag2-start)
  %not-a-collective = f32[999]{0} add(%a, %b)
}
"""


def test_parse_collective_bytes():
    out = roofline.parse_collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 256 * 1024 * 2 + 64 * 2   # ag + ag2-start
    assert out["all-reduce"] == 512 * 4
    assert out["reduce-scatter"] == 32 * 4
    assert out["all-to-all"] == 2 * 8 * 4 * 4
    assert out["collective-permute"] == 128 * 4
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_parse_ignores_done_ops():
    # the -done op must not double count its -start
    text = "%d = bf16[64]{0} all-gather-done(%s)\n"
    assert roofline.parse_collective_bytes(text)["all-gather"] == 0


def test_extrapolate_layers_linear():
    c1 = {"flops": 10.0, "bytes": 100.0, "coll": {"all-gather": 5,
                                                  "total": 5}}
    c2 = {"flops": 14.0, "bytes": 130.0, "coll": {"all-gather": 8,
                                                  "total": 8}}
    full = {"flops": 0.0, "bytes": 0.0, "coll": {"all-gather": 0,
                                                 "total": 0}}
    out = roofline.extrapolate_layers(full, c1, c2, n_layers=11)
    assert out["flops"] == 10.0 + 10 * 4.0
    assert out["bytes"] == 100.0 + 10 * 30.0
    assert out["coll"]["all-gather"] == 5 + 10 * 3


def test_count_params_no_overflow():
    cfg = get_config("mistral-large-123b")
    n = roofline.count_params(cfg)
    assert n["total"] > 100e9          # ~123B, must not wrap negative
    assert n["active"] == n["total"]   # dense


def test_count_params_moe_active():
    cfg = get_config("qwen3-moe-30b-a3b")
    n = roofline.count_params(cfg)
    assert n["total"] > 25e9
    assert n["active"] < 0.2 * n["total"]   # 8 of 128 experts


def test_model_flops_kinds():
    cfg = get_config("glm4-9b")
    train = roofline.model_flops(cfg, SHAPES["train_4k"])
    prefill = roofline.model_flops(cfg, SHAPES["prefill_32k"])
    decode = roofline.model_flops(cfg, SHAPES["decode_32k"])
    assert train == pytest.approx(3 * prefill, rel=1e-6)  # 6ND vs 2ND
    assert decode < prefill / 1000                        # 1 tok vs 32k
