"""Trainer behaviour: robust-DP aggregation in the loop, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint
from repro.configs import get_config
from repro.data.lm import make_batch, synthetic_lm_batches
from repro.dist.grad_agg import GradAggConfig
from repro.models.model import Model
from repro.train.optimizer import AdamW, SGD, apply_updates
from repro.train.trainer import TrainConfig, Trainer, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("xlstm-125m", reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_mean_agg_equals_plain_dataparallel(setup):
    """method=mean + sigma=0 + no attack == single global-batch gradient."""
    cfg, model, params = setup
    batch = make_batch(jax.random.PRNGKey(1), cfg, 8, 32)
    opt = SGD(lr=0.1, momentum=0.0)
    tcfg = TrainConfig(n_machines=4, agg=GradAggConfig(method="mean"))
    step = jax.jit(make_train_step(model, opt, tcfg))
    p1, _, _ = step(params, opt.init(params), batch, jax.random.PRNGKey(2))

    # reference: one global gradient step (same loss = mean over machines)
    def global_loss(p):
        mb = jax.tree_util.tree_map(
            lambda x: x.reshape((4, 2) + x.shape[1:]), batch)
        losses = jax.vmap(lambda b: model.loss(p, b)[0])(mb)
        return losses.mean()
    g = jax.grad(global_loss)(params)
    upd, _ = opt.update(g, opt.init(params), params)
    p2 = apply_updates(params, upd)
    err = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, p2)))
    assert err < 1e-5


def test_training_reduces_loss(setup):
    cfg, model, params = setup
    tcfg = TrainConfig(n_machines=4, agg=GradAggConfig(method="dcq"))
    trainer = Trainer(model, AdamW(lr=3e-3), tcfg)
    batches = synthetic_lm_batches(jax.random.PRNGKey(1), cfg, 30, 8, 32)
    losses = []
    trainer.fit(params, batches, jax.random.PRNGKey(2),
                callback=lambda i, m: losses.append(float(m["loss"])))
    assert losses[-1] < losses[0] - 0.1


def test_byzantine_training_dcq_survives_mean_does_not(setup):
    """25% of machines send -3x gradients: DCQ keeps training, mean
    diverges or stalls far above it."""
    cfg, model, params = setup
    mask = jnp.array([True, False, False, False])
    final = {}
    for method in ["dcq", "mean"]:
        tcfg = TrainConfig(
            n_machines=4,
            agg=GradAggConfig(method=method, attack="scale",
                              attack_factor=-3.0))
        trainer = Trainer(model, AdamW(lr=3e-3), tcfg)
        batches = synthetic_lm_batches(jax.random.PRNGKey(1), cfg, 25, 8, 32)
        losses = []
        trainer.fit(params, batches, jax.random.PRNGKey(2), byz_mask=mask,
                    callback=lambda i, m: losses.append(float(m["loss"])))
        final[method] = losses[-1]
    assert final["dcq"] < final["mean"] - 0.05


def test_dp_noise_training_still_learns(setup):
    cfg, model, params = setup
    tcfg = TrainConfig(n_machines=4,
                       agg=GradAggConfig(method="dcq", dp_sigma=1e-4))
    trainer = Trainer(model, AdamW(lr=3e-3), tcfg)
    batches = synthetic_lm_batches(jax.random.PRNGKey(1), cfg, 30, 8, 32)
    losses = []
    trainer.fit(params, batches, jax.random.PRNGKey(2),
                callback=lambda i, m: losses.append(float(m["loss"])))
    assert losses[-1] < losses[0] - 0.05


def test_microbatch_accumulation_matches(setup):
    cfg, model, params = setup
    batch = make_batch(jax.random.PRNGKey(5), cfg, 8, 32)
    opt = SGD(lr=0.1, momentum=0.0)
    agg = GradAggConfig(method="mean")
    s1 = jax.jit(make_train_step(model, opt,
                                 TrainConfig(n_machines=2, agg=agg)))
    s2 = jax.jit(make_train_step(model, opt,
                                 TrainConfig(n_machines=2, microbatch=2,
                                             agg=agg)))
    p1, _, m1 = s1(params, opt.init(params), batch, jax.random.PRNGKey(6))
    p2, _, m2 = s2(params, opt.init(params), batch, jax.random.PRNGKey(6))
    err = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, p2)))
    assert err < 1e-4


def test_checkpoint_roundtrip(tmp_path, setup):
    cfg, model, params = setup
    opt = AdamW()
    opt_state = opt.init(params)
    path = os.path.join(tmp_path, "ck.npz")
    checkpoint.save(path, params, opt_state, step=7, meta={"arch": cfg.name})
    p2, o2, step, meta = checkpoint.restore(path, params, opt_state)
    assert step == 7 and meta["arch"] == cfg.name
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(opt_state),
                    jax.tree_util.tree_leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected(tmp_path, setup):
    cfg, model, params = setup
    path = os.path.join(tmp_path, "ck.npz")
    checkpoint.save(path, params)
    bad = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape + (1,), x.dtype), params)
    with pytest.raises(ValueError, match="shape mismatch"):
        checkpoint.restore(path, bad)
