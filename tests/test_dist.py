"""Distribution-layer tests. Multi-device cases run in a subprocess with
forced host devices (jax locks the device count at first init, and the
main test process must keep seeing 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.grad_agg import (GradAggConfig, add_dp_noise,
                                 aggregate_machine_axis, corrupt_machines,
                                 robust_aggregate)


def _run_sub(code: str, devices: int = 8) -> str:
    """Run python code with N forced host devices; return stdout."""
    pre = (f"import os\n"
           f"os.environ['XLA_FLAGS'] = "
           f"'--xla_force_host_platform_device_count={devices}'\n"
           f"import sys; sys.path.insert(0, 'src')\n")
    out = subprocess.run([sys.executable, "-c", pre + textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600,
                         cwd=_REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ----------------------------------------------------- single-process

def test_aggregators_on_clean_data_close_to_mean():
    v = jax.random.normal(jax.random.PRNGKey(0), (64, 50))
    mean = v.mean(0)
    for method in ["median", "trimmed", "dcq"]:
        agg = aggregate_machine_axis(v, GradAggConfig(method=method))
        assert float(jnp.abs(agg - mean).max()) < 0.6


def test_byzantine_attack_breaks_mean_not_dcq():
    v = jax.random.normal(jax.random.PRNGKey(1), (40, 30)) + 3.0
    mask = jnp.zeros((40,), bool).at[:4].set(True)
    cfg = GradAggConfig(method="dcq", attack="scale", attack_factor=-3.0)
    bad = corrupt_machines({"g": v}, mask, cfg, jax.random.PRNGKey(2))["g"]
    dcq_est = aggregate_machine_axis(bad, cfg)
    mean_est = bad.mean(0)
    true = v.mean(0)
    assert float(jnp.abs(dcq_est - true).max()) < 0.5
    assert float(jnp.abs(mean_est - true).max()) > 0.5


def test_dp_noise_independent_per_machine():
    g = {"w": jnp.zeros((8, 16))}
    noisy = add_dp_noise(g, 1.0, jax.random.PRNGKey(0))["w"]
    # rows (machines) are distinct draws
    assert float(jnp.abs(noisy[0] - noisy[1]).max()) > 1e-3
    # variance roughly 1
    assert 0.5 < float(noisy.var()) < 2.0


def test_robust_aggregate_full_pipeline_reduces_to_mean():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 8, 4))}
    cfg = GradAggConfig(method="mean", dp_sigma=0.0, attack="none")
    out = robust_aggregate(g, cfg, jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(g["w"].mean(0)), atol=1e-6)


# ------------------------------------------------------- multi-device

def test_sharded_dcq_collective_matches_replicated():
    out = _run_sub("""
        import jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.dist.grad_agg import GradAggConfig, aggregate_machine_axis
        from repro.dist.collectives import sharded_aggregate_leaf
        mesh = jax.make_mesh((8,), ('data',),
                             axis_types=(jax.sharding.AxisType.Auto,))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 13, 7))
        cfg = GradAggConfig(method='dcq')
        ref = aggregate_machine_axis(g, cfg)
        gs = jax.device_put(g, NamedSharding(mesh, P('data')))
        with jax.set_mesh(mesh):
            out = jax.jit(lambda x: sharded_aggregate_leaf(
                x, cfg, mesh, P('data')))(gs)
        print(json.dumps({'err': float(jnp.abs(out - ref).max())}))
    """)
    assert json.loads(out.strip().splitlines()[-1])["err"] < 1e-4


def test_spmd_protocol_matches_reference():
    out = _run_sub("""
        import jax, jax.numpy as jnp, json
        from repro.configs.base import ProtocolConfig
        from repro.core import DPQNProtocol, get_problem
        from repro.data.synthetic import make_shards
        from repro.dist.sharded_protocol import run_sharded
        M, N, P_ = 8, 400, 5
        X, y = make_shards(jax.random.PRNGKey(0), 'logistic', M, N, P_)
        prob = get_problem('logistic')
        cfg = ProtocolConfig(eps=30.0, delta=0.05, noiseless=True)
        mesh = jax.make_mesh((9,), ('machines',),
                             axis_types=(jax.sharding.AxisType.Auto,))
        res = run_sharded(prob, cfg, mesh, jax.random.PRNGKey(1), X, y)
        ref = DPQNProtocol(prob, cfg).run(jax.random.PRNGKey(1), X, y)
        print(json.dumps({
            'cq': float(jnp.abs(res['theta_cq'] - ref.theta_cq).max()),
            'os': float(jnp.abs(res['theta_os'] - ref.theta_os).max()),
            'qn': float(jnp.abs(res['theta_qn'] - ref.theta_qn).max())}))
    """, devices=9)
    d = json.loads(out.strip().splitlines()[-1])
    assert d["cq"] < 1e-5 and d["os"] < 1e-5 and d["qn"] < 1e-5


def test_spmd_protocol_byzantine_robust():
    out = _run_sub("""
        import jax, jax.numpy as jnp, json
        from repro.configs.base import ProtocolConfig
        from repro.core import get_problem
        from repro.data.synthetic import make_shards, target_theta
        from repro.dist.sharded_protocol import run_sharded
        M, N, P_ = 8, 400, 5
        X, y = make_shards(jax.random.PRNGKey(0), 'logistic', M, N, P_)
        prob = get_problem('logistic')
        # noiseless: the attack is still applied on the wire; DP-noise
        # statistics are covered by the m=40 single-host tests.
        cfg = ProtocolConfig(eps=30.0, delta=0.05, noiseless=True)
        mesh = jax.make_mesh((9,), ('machines',),
                             axis_types=(jax.sharding.AxisType.Auto,))
        mask = jnp.zeros((M,), bool).at[0].set(True)
        res = run_sharded(prob, cfg, mesh, jax.random.PRNGKey(1), X, y,
                          byz_mask=mask)
        err = float(jnp.linalg.norm(res['theta_qn'] - target_theta(P_)))
        print(json.dumps({'err': err}))
    """, devices=9)
    assert json.loads(out.strip().splitlines()[-1])["err"] < 0.5


def test_spmd_protocol_omniscient_attack_matches_reference():
    """Omniscient attacks (repro.attacks registry) read honest-row
    statistics over the SHARDED machine axis — the masked reductions must
    lower to collectives and agree with the single-host reference."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, json
        from repro.configs.base import ProtocolConfig
        from repro.core import DPQNProtocol, get_problem
        from repro.data.synthetic import make_shards
        from repro.dist.sharded_protocol import run_sharded
        M, N, P_ = 7, 200, 4
        X, y = make_shards(jax.random.PRNGKey(0), 'logistic', M, N, P_)
        prob = get_problem('logistic')
        cfg = ProtocolConfig(eps=30.0, delta=0.05, noiseless=True)
        mesh = jax.make_mesh((4,), ('machines',),
                             axis_types=(jax.sharding.AxisType.Auto,))
        mask = jnp.zeros((M,), bool).at[0].set(True)
        deltas = {}
        for attack in ('alie', 'ipm'):
            res = run_sharded(prob, cfg, mesh, jax.random.PRNGKey(1), X, y,
                              byz_mask=mask, attack=attack,
                              attack_factor=1.5)
            ref = DPQNProtocol(prob, cfg).run(
                jax.random.PRNGKey(1), X, y, byz_mask=mask, attack=attack,
                attack_factor=1.5)
            deltas[attack] = float(
                jnp.abs(res['theta_qn'] - ref.theta_qn).max())
        print(json.dumps(deltas))
    """, devices=4)
    d = json.loads(out.strip().splitlines()[-1])
    assert d["alie"] < 1e-5 and d["ipm"] < 1e-5
