"""repro.attacks subsystem: registry contracts (mirroring
tests/test_agg.py), historical byte-parity for the four pre-existing wire
attacks, omniscient/round-aware semantics, the needs_key dispatch bugfix,
and the attack-sensitivity preset structure. The hypothesis property
suite lives in tests/test_attacks_properties.py (importorskip-gated)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import attacks
from repro.attacks import (ALIASES, Attack, apply_attack, byzantine_mask,
                           get_attack, register, registered, resolve)

M, P = 9, 6


@pytest.fixture
def stack():
    v = jax.random.normal(jax.random.PRNGKey(0), (M, P)) * 2.0
    mask = jnp.zeros((M,), bool).at[jnp.asarray([1, 4])].set(True)
    return v, mask


# ---------------------------------------------------------------- registry

def test_registry_contents():
    names = registered()
    for expected in ("none", "scale", "signflip", "gauss", "random",
                     "zero", "adaptive_scale", "alie", "ipm"):
        assert expected in names
    assert get_attack("alie").omniscient
    assert get_attack("ipm").omniscient
    assert not get_attack("scale").omniscient
    assert get_attack("gauss").needs_key
    assert get_attack("random").needs_key
    assert not get_attack("alie").needs_key
    assert get_attack("adaptive_scale").round_aware
    # every sweepable attack declares a factor grid; "none" declares none
    assert get_attack("none").factor_grid == ()
    for name in names:
        if name != "none":
            assert get_attack(name).factor_grid, name
    with pytest.raises(KeyError, match="unknown attack"):
        get_attack("nope")


def test_aliases_resolve():
    assert resolve("sign") == "signflip"
    assert resolve("noise") == "gauss"
    assert resolve("scale") == "scale"
    assert get_attack("sign") is get_attack("signflip")
    with pytest.raises(ValueError, match="shadows alias"):
        register(Attack(name="sign", corrupt=lambda v, m, f, k: v))


def test_register_new_attack_is_dispatchable_and_sweepable():
    """Adding an attack is one registry entry: immediately usable from
    apply_attack, accepted by Scenario validation, and expanded by the
    attack-sensitivity preset."""
    register(Attack(
        name="_test_const",
        corrupt=lambda values, mask, factor, key:
            jnp.full_like(values, factor),
        factor_grid=(7.0,)))
    try:
        v = jnp.zeros((4, 3))
        mask = jnp.asarray([True, False, False, False])
        out = apply_attack(v, mask, "_test_const", factor=7.0)
        np.testing.assert_array_equal(np.asarray(out[0]), 7.0)
        np.testing.assert_array_equal(np.asarray(out[1:]), 0.0)
        from repro.sweep import Scenario, attack_sensitivity_scenarios
        s = Scenario(m=4, n=50, p=3, attack="_test_const")
        assert s.attack == "_test_const"
        scens = attack_sensitivity_scenarios()
        assert {s.attack_factor for s in scens
                if s.attack == "_test_const"} == {7.0}
    finally:
        attacks.unregister("_test_const")


def test_scenario_rejects_unregistered_attack():
    from repro.sweep import Scenario
    with pytest.raises(ValueError, match="unknown attack"):
        Scenario(m=4, n=50, p=3, attack="typo")


def test_scenario_canonicalizes_attack_aliases():
    """A Scenario built with a launcher alias stores the canonical
    registry name, so group_key/scenario_id are alias-independent."""
    from repro.sweep import Scenario
    a = Scenario(m=4, n=50, p=3, attack="sign")
    b = Scenario(m=4, n=50, p=3, attack="signflip")
    assert a.attack == "signflip"
    assert a == b and a.scenario_id() == b.scenario_id()


# ------------------------------------------------- historical byte-parity

def test_wire_attacks_match_historical_formulas(stack):
    """The four pre-registry attacks reproduce core/byzantine.py's exact
    expressions (bit-identical: same ops, same key usage)."""
    v, mask = stack
    key = jax.random.PRNGKey(3)
    sel = mask[:, None]
    cases = {
        ("scale", -3.0): jnp.where(sel, -3.0 * v, v),
        ("signflip", 1.0): jnp.where(sel, -v, v),
        ("gauss", -10.0): jnp.where(
            sel, v + 10.0 * jax.random.normal(key, v.shape, v.dtype), v),
        ("random", 10.0): jnp.where(
            sel, 10.0 * jax.random.normal(key, v.shape, v.dtype), v),
    }
    for (name, factor), expect in cases.items():
        got = apply_attack(v, mask, name, factor=factor, key=key)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(expect),
                                      err_msg=name)


def test_apply_attack_none_is_exact_noop(stack):
    v, mask = stack
    assert apply_attack(v, mask, "none") is v


def test_honest_rows_bit_identical(stack):
    v, mask = stack
    key = jax.random.PRNGKey(5)
    honest = np.asarray(~mask)
    for name in registered():
        got = apply_attack(v, mask, name, factor=2.0, key=key)
        np.testing.assert_array_equal(
            np.asarray(got)[honest], np.asarray(v)[honest], err_msg=name)
        assert got.shape == v.shape and got.dtype == v.dtype, name


# ---------------------------------------------------- needs_key (bugfix)

@pytest.mark.parametrize("name", ["gauss", "random", "noise"])
def test_needs_key_raises_clear_error_without_key(stack, name):
    """Regression: key=None used to crash inside jax.random with an opaque
    trace error; dispatch now rejects it with an actionable message."""
    v, mask = stack
    with pytest.raises(ValueError, match="PRNG key"):
        apply_attack(v, mask, name, factor=3.0)
    with pytest.raises(ValueError, match="PRNG key"):
        apply_attack(v, mask, name, factor=3.0, key=None)


def test_keyless_attacks_accept_key_none(stack):
    v, mask = stack
    for name in ("scale", "signflip", "zero", "alie", "ipm",
                 "adaptive_scale"):
        out = apply_attack(v, mask, name, factor=1.5, key=None)
        assert out.shape == v.shape, name


def test_unknown_attack_raises_value_error(stack):
    """apply_attack keeps the historical ValueError contract."""
    v, mask = stack
    with pytest.raises(ValueError, match="unknown attack"):
        apply_attack(v, mask, "nope")


# ------------------------------------------------------- attack semantics

def test_alie_hides_inside_honest_spread(stack):
    v, mask = stack
    got = np.asarray(apply_attack(v, mask, "alie", factor=1.5))
    honest = np.asarray(v)[np.asarray(~mask)]
    expect = honest.mean(0) - 1.5 * honest.std(0)
    for i in np.flatnonzero(np.asarray(mask)):
        np.testing.assert_allclose(got[i], expect, rtol=1e-5, atol=1e-6)


def test_ipm_negates_honest_mean(stack):
    v, mask = stack
    got = np.asarray(apply_attack(v, mask, "ipm", factor=2.0))
    expect = -2.0 * np.asarray(v)[np.asarray(~mask)].mean(0)
    for i in np.flatnonzero(np.asarray(mask)):
        np.testing.assert_allclose(got[i], expect, rtol=1e-5, atol=1e-6)


def test_omniscient_stats_ignore_byzantine_rows(stack):
    """Wild Byzantine inputs must not leak into the honest statistics an
    omniscient attack reconstructs."""
    v, mask = stack
    wild = v.at[1].set(1e6).at[4].set(-1e6)
    a = np.asarray(apply_attack(v, mask, "alie", factor=1.0))
    b = np.asarray(apply_attack(wild, mask, "alie", factor=1.0))
    np.testing.assert_allclose(a[np.asarray(mask)], b[np.asarray(mask)],
                               rtol=1e-5)


def test_zero_attack_drops_rows(stack):
    v, mask = stack
    got = np.asarray(apply_attack(v, mask, "zero", factor=1.0))
    assert not got[np.asarray(mask)].any()


def test_adaptive_scale_ramps_over_rounds(stack):
    """1x (benign) at the first transmission, factor x at the last,
    linear in between."""
    v, mask = stack
    r0 = apply_attack(v, mask, "adaptive_scale", factor=-3.0, round_idx=0)
    np.testing.assert_array_equal(np.asarray(r0), np.asarray(v))
    r4 = np.asarray(
        apply_attack(v, mask, "adaptive_scale", factor=-3.0, round_idx=4))
    np.testing.assert_allclose(r4[np.asarray(mask)],
                               -3.0 * np.asarray(v)[np.asarray(mask)],
                               rtol=1e-6)
    r2 = np.asarray(
        apply_attack(v, mask, "adaptive_scale", factor=-3.0, round_idx=2))
    np.testing.assert_allclose(r2[np.asarray(mask)],
                               -1.0 * np.asarray(v)[np.asarray(mask)],
                               rtol=1e-5)


def test_byzantine_mask_counts():
    mask = byzantine_mask(jax.random.PRNGKey(0), 20, 0.15)
    assert mask.shape == (20,) and int(mask.sum()) == 3


def test_apply_attack_jits_with_traced_factor(stack):
    """Factors ride a vmap axis in the sweep executor; every registered
    attack must trace with a dynamic factor."""
    v, mask = stack
    key = jax.random.PRNGKey(2)
    for name in registered():
        f = jax.jit(lambda vv, fac, name=name: apply_attack(
            vv, mask, name, factor=fac, key=key))
        out = jax.vmap(lambda fac: f(v, fac))(jnp.asarray([1.0, 3.0]))
        assert out.shape == (2,) + v.shape, name


# ----------------------------------------------------- consumers / wiring

def test_corrupt_machines_dispatches_through_registry():
    from repro.dist.grad_agg import GradAggConfig, corrupt_machines
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (6, 4, 3)),
             "b": jax.random.normal(jax.random.PRNGKey(2), (6, 3))}
    mask = jnp.zeros((6,), bool).at[0].set(True)
    key = jax.random.PRNGKey(3)
    for attack in ("alie", "ipm", "zero", "sign", "noise"):
        cfg = GradAggConfig(attack=attack)
        out = corrupt_machines(grads, mask, cfg, key)
        for leaf_name in ("w", "b"):
            np.testing.assert_array_equal(
                np.asarray(out[leaf_name][1:]),
                np.asarray(grads[leaf_name][1:]), err_msg=attack)
    with pytest.raises(ValueError, match="unknown attack"):
        corrupt_machines(grads, mask, GradAggConfig(attack="typo"), key)


def test_corrupt_machines_applies_ramping_attack_at_full_strength():
    """Regression: the training path has no round structure, so a
    round-aware ramping attack must hit at terminal strength there — not
    silently degenerate to its benign round-0 coefficient (which would
    report honest-execution results as robustness results)."""
    from repro.dist.grad_agg import GradAggConfig, corrupt_machines
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (6, 4))}
    mask = jnp.zeros((6,), bool).at[0].set(True)
    key = jax.random.PRNGKey(3)
    out = corrupt_machines(
        grads, mask, GradAggConfig(attack="adaptive_scale",
                                   attack_factor=-3.0), key)
    np.testing.assert_allclose(np.asarray(out["w"][0]),
                               -3.0 * np.asarray(grads["w"][0]), rtol=1e-6)
    # the ramp clamps at full strength past the protocol's rounds (the
    # GD baseline threads round_idx = t over T > 5 rounds)
    v, m2 = grads["w"], mask
    r9 = apply_attack(v, m2, "adaptive_scale", factor=-3.0, round_idx=9)
    np.testing.assert_allclose(np.asarray(r9[0]),
                               -3.0 * np.asarray(v[0]), rtol=1e-6)


def test_byzantine_shim_serves_pinned_imports(stack):
    """core/byzantine.py is a thin import shim over repro.attacks, like
    core/robust_agg.py is over repro.agg."""
    from repro.core import byzantine as byz
    v, mask = stack
    assert byz.apply_attack is attacks.apply_attack
    np.testing.assert_array_equal(
        np.asarray(byz.apply_attack(v, mask, "scale", -3.0)),
        np.asarray(apply_attack(v, mask, "scale", factor=-3.0)))
    assert byz.byzantine_mask is byzantine_mask
    for fn in ("scaling_attack", "sign_flip_attack", "gaussian_attack",
               "random_value_attack"):
        assert getattr(byz, fn) is getattr(attacks, fn)


def test_protocol_runs_omniscient_and_round_aware_attacks():
    """Algorithm 1 end-to-end under the new threat models: compiles,
    returns finite estimators, and the robust aggregator keeps the
    corrupted run in the same ballpark as the clean one."""
    from repro.configs.base import ProtocolConfig
    from repro.core import DPQNProtocol, get_problem
    from repro.data.synthetic import make_shards, target_theta
    m, n, p = 8, 300, 4
    X, y = make_shards(jax.random.PRNGKey(0), "logistic", m, n, p)
    prob = get_problem("logistic")
    cfg = ProtocolConfig(noiseless=True)
    mask = jnp.zeros((m,), bool).at[0].set(True)
    proto = DPQNProtocol(prob, cfg)
    clean = proto.run(jax.random.PRNGKey(1), X, y)
    err_clean = float(jnp.linalg.norm(clean.theta_qn - target_theta(p)))
    for attack in ("alie", "ipm", "adaptive_scale", "zero"):
        res = proto.run(jax.random.PRNGKey(1), X, y, byz_mask=mask,
                        attack=attack, attack_factor=1.5)
        err = float(jnp.linalg.norm(res.theta_qn - target_theta(p)))
        assert np.isfinite(err), attack
        assert err < err_clean + 1.0, attack


def test_train_launcher_exposes_registry_attacks():
    """The launcher's ACTUAL parser accepts every registered attack plus
    the historical aliases, and still rejects typos."""
    from repro.launch.train import build_parser
    ap = build_parser()
    for name in list(registered()) + list(ALIASES):
        assert ap.parse_args(["--attack", name]).attack == name
    with pytest.raises(SystemExit):
        ap.parse_args(["--attack", "typo"])


# ------------------------------------------- attack-sensitivity preset

def test_attack_sensitivity_preset_structure():
    """Every registered attack with a factor grid x its declared factors
    x {dcq, median, trimmed} x byz_frac {0.1, 0.2}; one jit group per
    (attack, aggregator)."""
    from repro.sweep import build_preset, group_scenarios
    from repro.sweep.presets import ATTACK_AGGREGATORS
    scens = build_preset("attack-sensitivity")
    sweepable = [n for n in registered() if get_attack(n).factor_grid]
    assert {s.attack for s in scens} == set(sweepable)
    assert {s.aggregator for s in scens} == set(ATTACK_AGGREGATORS)
    assert {s.byz_frac for s in scens} == {0.1, 0.2}
    for name in sweepable:
        factors = {s.attack_factor for s in scens if s.attack == name}
        assert factors == set(get_attack(name).factor_grid), name
    groups = group_scenarios(scens)
    assert len(groups) == len(sweepable) * len(ATTACK_AGGREGATORS)
    assert len({(s.attack, s.aggregator) for s in scens}) == len(groups)


def test_every_preset_validates_against_both_registries():
    """Import-time guard: building a preset constructs every Scenario,
    whose __post_init__ validates attack AND aggregator names against
    their registries — a stale name in any preset fails here before CI
    ever compiles anything."""
    from repro.sweep import PRESETS, build_preset
    for name in PRESETS:
        scens = build_preset(name)
        assert scens, name
        for s in scens:
            assert s.attack in registered(), (name, s.attack)


def test_attack_sensitivity_compiles_once_per_group():
    """Compile-counter contract on the registry path: a reduced
    every-attack x dcq grid traces exactly once per (attack, aggregator)
    jit group, with factors/byz_frac riding the vmap axis."""
    from repro.sweep import SweepExecutor, attack_sensitivity_scenarios
    scens = attack_sensitivity_scenarios(
        aggregators=("dcq",), byz_fracs=(0.25,), m=4, n=80, p=3, reps=1)
    executor = SweepExecutor()
    art = executor.run(scens, store_thetas=False)
    n_attacks = len([n for n in registered() if get_attack(n).factor_grid])
    assert len(executor.trace_counts) == n_attacks
    assert all(c == 1 for c in executor.trace_counts.values())
    assert len(art["scenarios"]) == len(scens)
    for rec in art["scenarios"].values():
        assert np.isfinite(rec["metrics"]["mrse_qn"])
