"""Hypothesis property tests for the repro.attacks subsystem: structural
invariants of every registered attack — all-False masks are the identity,
honest rows are bit-identical after corruption, shape/dtype preservation,
and the signflip/scale(-1) equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.attacks import apply_attack, registered  # noqa: E402

ATTACKS = registered()

_settings = settings(max_examples=15, deadline=None)


def _stack(m, p, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), (m, p)) * 3.0


def _mask(m, idx):
    sel = [i % m for i in idx]
    return jnp.zeros((m,), bool).at[jnp.asarray(sel)].set(True) if sel \
        else jnp.zeros((m,), bool)


@_settings
@given(m=st.integers(2, 30), p=st.integers(1, 40),
       attack=st.sampled_from(ATTACKS), factor=st.floats(-10.0, 10.0),
       seed=st.integers(0, 2**16))
def test_all_false_mask_is_identity(m, p, attack, factor, seed):
    """With no Byzantine machine selected, every registered attack is a
    bit-exact no-op."""
    v = _stack(m, p, seed)
    out = apply_attack(v, jnp.zeros((m,), bool), attack, factor=factor,
                       key=jax.random.PRNGKey(seed + 1))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(v))


@_settings
@given(m=st.integers(2, 30), p=st.integers(1, 40),
       attack=st.sampled_from(ATTACKS), factor=st.floats(-10.0, 10.0),
       idx=st.lists(st.integers(0, 63), max_size=8),
       seed=st.integers(0, 2**16))
def test_honest_rows_bit_identical_and_shape_dtype(m, p, attack, factor,
                                                   idx, seed):
    """Corruption never touches honest rows (whatever the attack, factor
    or mask) and preserves the transmitted array's shape and dtype."""
    v = _stack(m, p, seed)
    mask = _mask(m, idx)
    out = apply_attack(v, mask, attack, factor=factor,
                       key=jax.random.PRNGKey(seed + 1))
    assert out.shape == v.shape and out.dtype == v.dtype
    honest = np.asarray(~mask)
    np.testing.assert_array_equal(np.asarray(out)[honest],
                                  np.asarray(v)[honest])


@_settings
@given(m=st.integers(2, 30), p=st.integers(1, 40),
       idx=st.lists(st.integers(0, 63), min_size=1, max_size=8),
       seed=st.integers(0, 2**16))
def test_signflip_equals_scale_minus_one(m, p, idx, seed):
    """signflip and scale(factor=-1) are the same attack, bitwise (both
    flip the IEEE sign bit of the Byzantine rows)."""
    v = _stack(m, p, seed)
    mask = _mask(m, idx)
    np.testing.assert_array_equal(
        np.asarray(apply_attack(v, mask, "signflip", factor=1.0)),
        np.asarray(apply_attack(v, mask, "scale", factor=-1.0)))


@_settings
@given(m=st.integers(3, 30), p=st.integers(1, 40),
       z=st.floats(0.0, 5.0), seed=st.integers(0, 2**16))
def test_alie_rows_stay_inside_honest_range_when_z_small(m, p, z, seed):
    """ALIE with z=0 transmits exactly the honest mean; the corrupted rows
    always lie within z honest standard deviations of it."""
    v = _stack(m, p, seed)
    mask = jnp.zeros((m,), bool).at[0].set(True)
    out = np.asarray(apply_attack(v, mask, "alie", factor=z))
    honest = np.asarray(v)[1:]
    mean, std = honest.mean(0), honest.std(0)
    np.testing.assert_allclose(out[0], mean - z * std, rtol=1e-4,
                               atol=1e-5)
