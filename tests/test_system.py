"""End-to-end system behaviour: full protocol on synthetic data reproduces
the paper's qualitative claims (MRSE ordering, Byzantine robustness)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ProtocolConfig
from repro.core import DPQNProtocol, get_problem, monte_carlo_mrse
from repro.data.synthetic import make_shards, target_theta

M, N, P = 40, 1000, 8


@pytest.fixture(scope="module")
def shards():
    return make_shards(jax.random.PRNGKey(0), "logistic", M, N, P)


def _err(v):
    return float(jnp.linalg.norm(v - target_theta(P)))


def test_mrse_ordering_cq_os_qn(shards):
    """Figs 1-5: theta_cq > theta_os >= theta_qn in MRSE (on average)."""
    X, y = shards
    cfg = ProtocolConfig(eps=30.0, delta=0.05)
    prob = get_problem("logistic")
    # one jit(vmap) Monte-Carlo batch replaces the former eager rep loop
    keys = jnp.stack([jax.random.PRNGKey(100 + k) for k in range(5)])
    arrs = DPQNProtocol(prob, cfg).run_monte_carlo(keys, X, y)
    t = target_theta(P)
    e_cq = monte_carlo_mrse(arrs.theta_cq, t)
    e_os = monte_carlo_mrse(arrs.theta_os, t)
    e_qn = monte_carlo_mrse(arrs.theta_qn, t)
    assert e_os < e_cq
    assert e_qn < e_cq
    # qn should not be (much) worse than os
    assert e_qn < 1.25 * e_os


def test_byzantine_robustness_end_to_end(shards):
    """alpha=10% scaling attack barely moves the DCQ-aggregated estimator."""
    X, y = shards
    cfg = ProtocolConfig(eps=30.0, delta=0.05)
    prob = get_problem("logistic")
    mask = jnp.zeros((M,), bool).at[:M // 10].set(True)
    r_clean = DPQNProtocol(prob, cfg).run(jax.random.PRNGKey(7), X, y)
    r_byz = DPQNProtocol(prob, cfg).run(jax.random.PRNGKey(7), X, y,
                                        byz_mask=mask)
    assert _err(r_byz.theta_qn) < 2.0 * _err(r_clean.theta_qn) + 0.05


def test_mean_aggregation_destroyed_by_byzantine(shards):
    """The non-robust mean aggregator is wrecked by the same attack."""
    X, y = shards
    prob = get_problem("logistic")
    mask = jnp.zeros((M,), bool).at[:M // 10].set(True)
    cfg_mean = ProtocolConfig(eps=30.0, delta=0.05, aggregator="mean",
                              noiseless=True)
    cfg_dcq = ProtocolConfig(eps=30.0, delta=0.05, aggregator="dcq",
                             noiseless=True)
    r_mean = DPQNProtocol(prob, cfg_mean).run(jax.random.PRNGKey(8), X, y,
                                              byz_mask=mask)
    r_dcq = DPQNProtocol(prob, cfg_dcq).run(jax.random.PRNGKey(8), X, y,
                                            byz_mask=mask)
    assert _err(r_dcq.theta_qn) < _err(r_mean.theta_qn)


def test_privacy_accounting_five_rounds(shards):
    X, y = shards
    cfg = ProtocolConfig(eps=30.0, delta=0.05)
    r = DPQNProtocol(get_problem("logistic"), cfg).run(
        jax.random.PRNGKey(9), X, y)
    eb, db = r.accountant.total_basic()
    assert abs(eb - 30.0) < 1e-6
    assert abs(db - 0.05) < 1e-6
    ea, _ = r.accountant.total_advanced()
    assert ea <= eb + 1e-9
