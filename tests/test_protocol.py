"""Algorithm 1 end-to-end: estimator quality, orderings, Byzantine, DP."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ProtocolConfig
from repro.core import DPQNProtocol, get_problem, monte_carlo_mrse
from repro.core.byzantine import byzantine_mask
from repro.core.local import newton_solve
from repro.data.synthetic import make_shards, target_theta

M, N, P = 60, 800, 6


@pytest.fixture(scope="module")
def logistic_shards():
    return make_shards(jax.random.PRNGKey(0), "logistic", M, N, P)


@pytest.fixture(scope="module")
def problem():
    return get_problem("logistic")


def _err(v, p=P):
    return float(jnp.linalg.norm(v - target_theta(p)))


def test_noiseless_protocol_near_global_mle(logistic_shards, problem):
    X, y = logistic_shards
    cfg = ProtocolConfig(noiseless=True)
    res = DPQNProtocol(problem, cfg).run(jax.random.PRNGKey(1), X, y)
    tg = newton_solve(problem, jnp.zeros(P), X.reshape(-1, P), y.reshape(-1))
    # all three stages sit within the aggregation-noise floor of the
    # global MLE; absolute error near the statistical floor.
    for v in (res.theta_cq, res.theta_os, res.theta_qn):
        assert float(jnp.linalg.norm(v - tg)) < 0.05
    assert _err(res.theta_qn) < 0.15


def test_newton_step_contracts_from_bad_init(logistic_shards, problem):
    """The one-stage/qN iterations must pull a deliberately perturbed initial
    estimate back towards the global MLE (Thms 4.2/4.3 contraction)."""
    X, y = logistic_shards
    cfg = ProtocolConfig(noiseless=True)
    tg = newton_solve(problem, jnp.zeros(P), X.reshape(-1, P), y.reshape(-1))
    bad = tg + 0.3 * jnp.ones((P,)) / np.sqrt(P)
    res = DPQNProtocol(problem, cfg).run(jax.random.PRNGKey(1), X, y,
                                         theta_cq_override=bad)
    d_bad = float(jnp.linalg.norm(bad - tg))
    d_os = float(jnp.linalg.norm(res.theta_os - tg))
    d_qn = float(jnp.linalg.norm(res.theta_qn - tg))
    assert d_os < 0.35 * d_bad
    assert d_qn < 0.15 * d_bad
    assert d_qn < d_os  # the BFGS second iteration refines further


def test_private_protocol_reasonable_error(logistic_shards, problem):
    X, y = logistic_shards
    cfg = ProtocolConfig(eps=30.0, delta=0.05)
    res = DPQNProtocol(problem, cfg).run(jax.random.PRNGKey(2), X, y)
    assert _err(res.theta_qn) < 0.5
    eb, db = res.accountant.total_basic()
    assert abs(eb - 30.0) < 1e-6 and abs(db - 0.05) < 1e-6


def test_more_budget_less_error(logistic_shards, problem):
    X, y = logistic_shards
    errs = []
    for eps in (4.0, 50.0):
        # average over keys to kill noise-draw luck: one compiled
        # Monte-Carlo batch instead of an eager Python loop
        proto = DPQNProtocol(problem, ProtocolConfig(eps=eps, delta=0.05))
        keys = jnp.stack([jax.random.PRNGKey(k) for k in range(3)])
        arrs = proto.run_monte_carlo(keys, X, y)
        errs.append(monte_carlo_mrse(arrs.theta_qn, target_theta(P)))
    assert errs[1] < errs[0]


def test_byzantine_robustness(logistic_shards, problem):
    """10% scaling attack: DCQ protocol stays close; mean aggregation breaks."""
    X, y = logistic_shards
    mask = byzantine_mask(jax.random.PRNGKey(3), M, 0.15)
    cfg = ProtocolConfig(eps=30.0, delta=0.05)
    kw = dict(byz_mask=mask, attack="scale", attack_factor=-10.0)
    res = DPQNProtocol(problem, cfg).run(jax.random.PRNGKey(4), X, y, **kw)
    cfg_mean = dataclasses.replace(cfg, aggregator="mean")
    res_mean = DPQNProtocol(problem, cfg_mean).run(jax.random.PRNGKey(4),
                                                   X, y, **kw)
    assert _err(res.theta_qn) < 0.5
    assert _err(res_mean.theta_qn) > 1.5 * _err(res.theta_qn)


def test_byzantine_iterations_help(logistic_shards, problem):
    """Paper Fig 1 (alpha=10%): os/qn improve notably over the initial cq."""
    X, y = logistic_shards
    mask = byzantine_mask(jax.random.PRNGKey(5), M, 0.1)
    cfg = ProtocolConfig(eps=30.0, delta=0.05)
    keys = jnp.stack([jax.random.PRNGKey(10 + k) for k in range(3)])
    arrs = DPQNProtocol(problem, cfg).run_monte_carlo(keys, X, y,
                                                      byz_mask=mask)
    t = target_theta(P)
    assert monte_carlo_mrse(arrs.theta_qn, t) < monte_carlo_mrse(arrs.theta_cq, t)


def test_median_and_trimmed_aggregators_work(logistic_shards, problem):
    X, y = logistic_shards
    for agg in ("median", "trimmed"):
        cfg = ProtocolConfig(eps=30.0, delta=0.05, aggregator=agg)
        res = DPQNProtocol(problem, cfg).run(jax.random.PRNGKey(6), X, y)
        assert _err(res.theta_qn) < 0.6, agg


def test_untrusted_center_mode(logistic_shards, problem):
    """§4.3: median everywhere but the gradient round; still consistent."""
    X, y = logistic_shards
    cfg = ProtocolConfig(eps=30.0, delta=0.05, center_trust="untrusted")
    res = DPQNProtocol(problem, cfg).run(jax.random.PRNGKey(7), X, y)
    assert _err(res.theta_qn) < 0.6
    # the extra variance transmission is accounted
    assert any("R2b" in r.name for r in res.accountant.records)


def test_poisson_problem(problem):
    X, y = make_shards(jax.random.PRNGKey(8), "poisson", 40, 600, 5)
    prob = get_problem("poisson")
    cfg = ProtocolConfig(eps=30.0, delta=0.05)
    res = DPQNProtocol(prob, cfg).run(jax.random.PRNGKey(9), X, y)
    assert _err(res.theta_qn, 5) < 0.5


def test_noise_sd_reported(logistic_shards, problem):
    X, y = logistic_shards
    cfg = ProtocolConfig(eps=20.0, delta=0.05)
    res = DPQNProtocol(problem, cfg).run(jax.random.PRNGKey(11), X, y)
    for k in ("s1", "s2", "s3", "s4", "s5"):
        assert res.noise_sd[k] > 0
