"""Edge cases of the dist layer not covered by the seed contracts:
degenerate trims, no-op masks, and the sigma=0 exact-identity path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.robust_agg import trimmed_mean_agg
from repro.dist.grad_agg import (GradAggConfig, add_dp_noise,
                                 corrupt_machines)


def test_trimmed_mean_zero_rows_trimmed_equals_mean():
    """A trim fraction that floors to zero rows per side must reduce to
    the plain mean, not drop anything."""
    v = jax.random.normal(jax.random.PRNGKey(0), (8, 5))
    out = trimmed_mean_agg(v, beta=0.05)          # int(0.05*8) == 0
    np.testing.assert_allclose(np.asarray(out), np.asarray(v.mean(0)),
                               atol=1e-6)


def test_trimmed_mean_full_trim_rejected():
    v = jnp.ones((4, 3))
    with pytest.raises(ValueError, match="too large"):
        trimmed_mean_agg(v, beta=1.0)


def test_corrupt_machines_all_false_mask_is_noop():
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (6, 4, 2)),
         "b": jax.random.normal(jax.random.PRNGKey(2), (6, 4))}
    mask = jnp.zeros((6,), bool)
    cfg = GradAggConfig(method="dcq", attack="scale", attack_factor=-3.0)
    out = corrupt_machines(g, mask, cfg, jax.random.PRNGKey(3))
    for k in g:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(g[k]))


def test_corrupt_machines_attack_none_returns_input_object():
    g = {"w": jnp.ones((4, 3))}
    cfg = GradAggConfig(method="mean", attack="none")
    mask = jnp.array([True, False, False, False])
    assert corrupt_machines(g, mask, cfg, jax.random.PRNGKey(0)) is g


def test_add_dp_noise_sigma_zero_exact_identity():
    g = {"w": jax.random.normal(jax.random.PRNGKey(4), (5, 7)),
         "b": jnp.arange(10.0).reshape(5, 2)}
    out = add_dp_noise(g, 0.0, jax.random.PRNGKey(5))
    assert out is g                                # no recompute, no copy
    for k in g:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(g[k]))
