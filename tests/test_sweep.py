"""Scenario-sweep engine: grid expansion, jit-group keying (compile
counters), artifact schema round-trip, resume-from-partial, and
sweep-vs-direct Monte-Carlo agreement."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DPQNProtocol, get_problem
from repro.sweep import artifact as artifact_mod
from repro.sweep import (Scenario, ScenarioGrid, SweepExecutor,
                         build_preset, fast_variant, group_scenarios,
                         run_scenarios, scenario_from_json, smoke_scenarios)

M, N, P = 6, 400, 4


def tiny(eps=20.0, **kw):
    base = dict(problem="logistic", m=M, n=N, p=P, eps=eps, delta=0.05,
                reps=2, data_seed=0)
    base.update(kw)
    return Scenario(**base)


# ------------------------------------------------------------------- grid

def test_grid_expansion_counts():
    grid = ScenarioGrid(problems=("logistic", "poisson"),
                        attacks=("scale", "signflip", "none"),
                        aggregators=("dcq", "median"),
                        eps_grid=(10.0, 30.0),
                        m_grid=(6, 12), byz_fracs=(0.0, 0.1))
    scens = grid.expand()
    assert grid.size() == len(scens) == 2 * 3 * 2 * 2 * 2 * 2
    assert len({s.scenario_id() for s in scens}) == len(scens)


def test_grouping_splits_static_merges_dynamic():
    """eps / byz_frac / attack_factor / seeds ride the vmap axis of one
    group; loss, attack, aggregator, trust, shapes split groups."""
    grid = ScenarioGrid(problems=("logistic", "poisson"),
                        attacks=("scale", "signflip"),
                        eps_grid=(10.0, 30.0), byz_fracs=(0.0, 0.1),
                        m_grid=(6,), n=N, p=P, reps=2)
    groups = group_scenarios(grid.expand())
    assert len(groups) == 4                      # 2 losses x 2 attacks
    assert all(len(v) == 4 for v in groups.values())   # 2 eps x 2 byz
    # static field split: different aggregator -> different group
    a = tiny(aggregator="dcq")
    b = tiny(aggregator="median")
    assert a.group_key() != b.group_key()
    # dynamic field merge: different eps/byz/data_seed -> same group
    assert tiny(eps=4.0).group_key() == tiny(eps=50.0).group_key()
    assert tiny(byz_frac=0.5).group_key() == tiny().group_key()
    assert tiny(data_seed=7).group_key() == tiny().group_key()


def test_smoke_preset_shape():
    """Acceptance: >=8 scenarios covering >=2 losses x >=2 attacks x
    >=2 aggregators, and every jit group batches >1 scenario."""
    scens = smoke_scenarios()
    assert len(scens) >= 8
    assert len({s.problem for s in scens}) >= 2
    assert len({s.attack for s in scens}) >= 2
    assert len({s.aggregator for s in scens}) >= 2
    # one registry-path group (omniscient alie x dcq) rides the CI grid,
    # so every PR compiles and executes the repro.attacks dispatch
    assert any(s.attack == "alie" and s.aggregator == "dcq"
               for s in scens)
    groups = group_scenarios(scens)
    assert all(len(v) >= 2 for v in groups.values())


def test_scenario_json_round_trip():
    s = tiny(byz_frac=0.1, rep_seeds=(3, 4), gammas=(0.5,) * 5)
    restored = scenario_from_json(json.loads(json.dumps(s.to_json())))
    assert restored == s
    assert restored.scenario_id() == s.scenario_id()


def test_scenario_validation():
    with pytest.raises(ValueError, match="rep_seeds"):
        tiny(reps=3, rep_seeds=(1, 2))
    with pytest.raises(ValueError, match="pair"):
        tiny(dataset="digits")
    with pytest.raises(KeyError, match="unknown preset"):
        build_preset("nope")


def test_fast_variant_truncates_reps_and_seeds():
    scens = [tiny(reps=4, rep_seeds=(1, 2, 3, 4)), tiny(reps=1,
                                                        rep_seeds=(9,))]
    fast = fast_variant(scens, reps=2)
    assert fast[0].reps == 2 and fast[0].rep_seeds == (1, 2)
    assert fast[1].reps == 1 and fast[1].rep_seeds == (9,)


# --------------------------------------------------------------- executor

@pytest.fixture(scope="module")
def two_eps_artifact():
    """A 2-point eps grid through one executor, reused across tests."""
    executor = SweepExecutor()
    scens = [tiny(eps=20.0, rep_seeds=(0, 1)), tiny(eps=40.0,
                                                    rep_seeds=(2, 3))]
    art = executor.run(scens)
    return executor, scens, art


def test_one_compile_per_jit_group(two_eps_artifact):
    """The compile-counter contract: a whole group traces exactly once,
    and a SECOND run over the same group does not retrace."""
    executor, scens, _ = two_eps_artifact
    (gkey,) = {s.group_key() for s in scens}
    assert executor.trace_counts[gkey] == 1
    executor.run([tiny(eps=50.0, rep_seeds=(7, 8)),
                  tiny(eps=4.0, byz_frac=1 / M, rep_seeds=(5, 6))])
    assert executor.trace_counts[gkey] == 1      # cache hit, no retrace


def test_sweep_matches_direct_monte_carlo(two_eps_artifact):
    """Sweep-engine results agree with direct run_monte_carlo per key to
    1e-5 on a 2-point grid (host-calibrated sigma_base keeps the noise
    draws identical to the compile-once static path)."""
    _, scens, art = two_eps_artifact
    X, y = __import__("repro.data.synthetic", fromlist=["make_shards"]
                      ).make_shards(jax.random.PRNGKey(0), "logistic",
                                    M, N, P)
    prob = get_problem("logistic")
    for s in scens:
        proto = DPQNProtocol(prob, s.protocol_config())
        keys = jnp.stack([jax.random.PRNGKey(k) for k in s.rep_seeds])
        direct = proto.run_monte_carlo(keys, X, y)
        rec = art["scenarios"][s.scenario_id()]
        np.testing.assert_allclose(
            np.asarray(rec["thetas_qn"], np.float32),
            np.asarray(direct.theta_qn), atol=1e-5,
            err_msg=f"eps={s.eps}")
        from repro.core import monte_carlo_mrse
        from repro.data.synthetic import target_theta
        assert rec["metrics"]["mrse_qn"] == pytest.approx(
            monte_carlo_mrse(direct.theta_qn, target_theta(P)), abs=1e-5)


def test_spend_ledger_recorded(two_eps_artifact):
    _, scens, art = two_eps_artifact
    for s in scens:
        spend = art["scenarios"][s.scenario_id()]["spend"]
        assert spend["eps_total"] == s.eps
        assert spend["n_transmissions"] == 5
        assert spend["eps_per_round"] == pytest.approx(s.eps / 5)
        assert len(spend["sigmas"]) == 5
        assert all(v >= 0 for v in spend["sigmas"])


def test_mixed_attack_grid_compiles_once_per_group():
    executor = SweepExecutor()
    grid = ScenarioGrid(problems=("logistic",),
                        attacks=("scale", "signflip"),
                        eps_grid=(10.0, 30.0), m_grid=(M,), n=N, p=P,
                        reps=2, byz_fracs=(1 / M,))
    executor.run(grid.expand())
    assert len(executor.trace_counts) == 2       # one per attack
    assert all(c == 1 for c in executor.trace_counts.values())


def test_untrusted_center_scenarios_run():
    art = run_scenarios([tiny(center_trust="untrusted", eps=20.0),
                         tiny(center_trust="untrusted", eps=40.0)])
    for rec in art["scenarios"].values():
        assert rec["spend"]["n_transmissions"] == 6
        assert len(rec["spend"]["sigmas"]) == 6
        # untrusted mode transmits SIX p-vectors; the comm record tracks it
        assert rec["comm"]["n_transmissions"] == 6
        assert rec["comm"]["bytes_per_machine"] == 4 * 6 * P


def test_untrusted_preset_driven_by_registry():
    """The untrusted preset sweeps center_trust x EVERY registered
    aggregator — a new registry entry appears in the grid automatically."""
    from repro.agg import registered
    from repro.sweep import untrusted_scenarios
    scens = untrusted_scenarios()
    assert {s.aggregator for s in scens} == set(registered())
    assert {s.center_trust for s in scens} == {"trusted", "untrusted"}
    groups = group_scenarios(scens)
    assert len(groups) == 2 * len(registered())   # one per (agg, trust)


# --------------------------------------------------------------- chunking

def test_chunked_group_matches_unchunked(two_eps_artifact):
    """chunk_size bounds replicates-per-launch; per-key results match the
    one-batch path (up to compiled-batch-shape fp reassociation) and the
    group still compiles exactly once (padded final chunk)."""
    _, scens, art = two_eps_artifact
    chunked = SweepExecutor(chunk_size=1)
    art_c = chunked.run(scens)
    (gkey,) = {s.group_key() for s in scens}
    assert chunked.trace_counts[gkey] == 1
    for s in scens:
        a = np.asarray(art["scenarios"][s.scenario_id()]["thetas_qn"])
        b = np.asarray(art_c["scenarios"][s.scenario_id()]["thetas_qn"])
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
        t = art_c["scenarios"][s.scenario_id()]["timing"]
        assert t["n_chunks"] == 2 and t["group_size"] == 1


def test_chunked_writes_artifact_per_chunk(tmp_path, monkeypatch):
    """The artifact lands on disk after EVERY chunk (resumable mid-group),
    each snapshot schema-valid."""
    path = str(tmp_path / "chunked.json")
    saves = []
    real_save = artifact_mod.save

    def counting_save(art, p):
        real_save(art, p)
        saves.append(len(art["scenarios"]))
    monkeypatch.setattr(artifact_mod, "save", counting_save)
    scens = [tiny(eps=float(e), rep_seeds=(e, e + 1)) for e in (10, 20, 30)]
    SweepExecutor(chunk_size=2).run(scens, artifact_path=path)
    assert saves == [2, 3]          # chunk 1 (2 scens), chunk 2 (1 scen)
    artifact_mod.validate(artifact_mod.load(path))


def test_chunk_size_validation():
    with pytest.raises(ValueError, match="chunk_size"):
        SweepExecutor(chunk_size=0)


# ------------------------------------------------------------ comm record

def test_comm_record_rides_artifact(two_eps_artifact):
    """Schema v2: transmission cost rides the same record as MRSE."""
    _, scens, art = two_eps_artifact
    for s in scens:
        comm = art["scenarios"][s.scenario_id()]["comm"]
        assert comm["bytes_per_round"] == 4 * P
        assert comm["bytes_per_machine"] == 4 * 5 * P
        assert comm["n_transmissions"] == 5
        assert comm["eps_per_round"] == pytest.approx(s.eps / 5)
        # the paper's budget argument: Newton's Hessian round dwarfs qN
        assert comm["newton_bytes_per_machine"] > comm["bytes_per_machine"]


def test_artifact_v3_rejects_missing_comm_and_accountant(two_eps_artifact):
    _, _, art = two_eps_artifact
    import json as _json
    bad = _json.loads(_json.dumps(art))
    next(iter(bad["scenarios"].values())).pop("comm")
    with pytest.raises(ValueError, match="missing 'comm'"):
        artifact_mod.validate(bad)
    bad = _json.loads(_json.dumps(art))
    next(iter(bad["scenarios"].values()))["spend"].pop("accountant")
    with pytest.raises(ValueError, match="missing 'accountant'"):
        artifact_mod.validate(bad)
    assert art["schema_version"] == 3
    # a v2 artifact (pre-accountant) fails validation, so resume restarts
    bad = _json.loads(_json.dumps(art))
    bad["schema_version"] = 2
    with pytest.raises(ValueError, match="schema_version"):
        artifact_mod.validate(bad)
    # flat rows expose the byte + accounting columns for plotting
    row = artifact_mod.rows(art)[0]
    assert "bytes_per_machine" in row and "bytes_per_round" in row
    assert row["accountant"] == "basic"
    assert row["sigma_ratio_vs_basic"] == 1.0


# --------------------------------------------------------------- artifact

def test_artifact_round_trip(tmp_path, two_eps_artifact):
    _, _, art = two_eps_artifact
    path = tmp_path / "sweep.json"
    artifact_mod.save(art, str(path))
    loaded = artifact_mod.load(str(path))
    assert loaded == json.loads(json.dumps(art))   # JSON-faithful
    artifact_mod.validate(loaded)
    csv_path = tmp_path / "sweep.csv"
    artifact_mod.to_csv(loaded, str(csv_path))
    lines = csv_path.read_text().strip().splitlines()
    assert len(lines) == 1 + len(art["scenarios"])
    assert "mrse_qn" in lines[0] and "eps_total" in lines[0]


def test_artifact_validation_rejects_bad_schema(two_eps_artifact):
    _, _, art = two_eps_artifact
    bad = json.loads(json.dumps(art))
    bad["schema_version"] = 999
    with pytest.raises(ValueError, match="schema_version"):
        artifact_mod.validate(bad)
    bad = json.loads(json.dumps(art))
    next(iter(bad["scenarios"].values())).pop("metrics")
    with pytest.raises(ValueError, match="missing 'metrics'"):
        artifact_mod.validate(bad)
    with pytest.raises(ValueError, match="kind"):
        artifact_mod.validate({"schema_version": 1})


def test_resume_from_partial(tmp_path):
    """An interrupted sweep resumes: completed scenarios are skipped (no
    retrace of their group), pending ones run, artifact ends complete."""
    path = str(tmp_path / "partial.json")
    a = tiny(eps=10.0, rep_seeds=(0, 1))
    b = tiny(eps=30.0, rep_seeds=(2, 3))
    c = tiny(eps=30.0, aggregator="median", rep_seeds=(4, 5))
    first = SweepExecutor()
    first.run([a], artifact_path=path)
    assert set(artifact_mod.load(path)["scenarios"]) == {a.scenario_id()}

    resumed = SweepExecutor()
    art = resumed.run([a, b, c], artifact_path=path, resume=True)
    assert set(art["scenarios"]) == {s.scenario_id() for s in (a, b, c)}
    # a's record survived verbatim from the partial artifact
    assert art["scenarios"][a.scenario_id()]["timing"]["group_size"] == 1
    # only b (dcq group) and c (median group) actually ran
    assert sorted(resumed.trace_counts.values()) == [1, 1]
    artifact_mod.validate(artifact_mod.load(path))
    # no-resume reruns everything
    fresh = SweepExecutor()
    fresh.run([a, b], artifact_path=path, resume=False)
    assert sum(fresh.trace_counts.values()) == 1   # one shared dcq group


def test_resume_reproduces_same_results(tmp_path):
    """Derived replicate keys are a pure function of the scenario, so a
    resumed run and a fresh run produce identical numbers."""
    s = tiny(eps=25.0)                # no explicit rep_seeds: derived keys
    art1 = run_scenarios([s])
    art2 = run_scenarios([s])
    np.testing.assert_array_equal(
        np.asarray(art1["scenarios"][s.scenario_id()]["thetas_qn"]),
        np.asarray(art2["scenarios"][s.scenario_id()]["thetas_qn"]))


# ---------------------------------------------------------------- sharded

def test_sharded_sweep_matches_single_host():
    """The sweep executor with a mesh routes every scenario through the
    shard_map machine map (dist/sharded_protocol.py) and agrees with the
    single-host executor. Runs in a subprocess with forced host devices
    (the main process must keep seeing one device)."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    pre = ("import os\n"
           "os.environ['XLA_FLAGS'] = "
           "'--xla_force_host_platform_device_count=4'\n"
           "import sys; sys.path.insert(0, 'src')\n")
    code = textwrap.dedent("""
        import jax, numpy as np
        from repro.compat import make_mesh
        from repro.sweep import Scenario, SweepExecutor

        scens = [Scenario(problem="logistic", m=7, n=100, p=4, eps=e,
                          reps=2, noiseless=True) for e in (10.0, 30.0)]
        mesh = make_mesh((4,), ("machines",))
        sharded = SweepExecutor(mesh=mesh).run(scens)
        single = SweepExecutor().run(scens)
        for s in scens:
            a = np.asarray(sharded["scenarios"][s.scenario_id()]["thetas_qn"])
            b = np.asarray(single["scenarios"][s.scenario_id()]["thetas_qn"])
            np.testing.assert_allclose(a, b, atol=1e-5)
        print("SHARDED_OK", sharded["meta"]["n_devices"])
    """)
    out = subprocess.run([sys.executable, "-c", pre + code],
                         capture_output=True, text=True, timeout=600,
                         cwd=repo)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED_OK 4" in out.stdout


def test_sharded_sweep_rejects_uneven_machines():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    pre = ("import os\n"
           "os.environ['XLA_FLAGS'] = "
           "'--xla_force_host_platform_device_count=4'\n"
           "import sys; sys.path.insert(0, 'src')\n")
    code = textwrap.dedent("""
        from repro.compat import make_mesh
        from repro.sweep import Scenario, SweepExecutor
        mesh = make_mesh((4,), ("machines",))
        try:
            SweepExecutor(mesh=mesh).run(
                [Scenario(m=5, n=50, p=3, reps=1, noiseless=True)])
        except ValueError as e:
            assert "shard evenly" in str(e), e
            print("UNEVEN_REJECTED")
    """)
    out = subprocess.run([sys.executable, "-c", pre + code],
                         capture_output=True, text=True, timeout=600,
                         cwd=repo)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "UNEVEN_REJECTED" in out.stdout


# ----------------------------------------------------------------- digits

def test_digits_scenario_metrics():
    scens = [Scenario(problem="logistic", dataset="digits", pair=(6, 9),
                      m=4, n=120, p=5, eps=e, gammas=(0.5,) * 5,
                      attack_factor=3.0, reps=2, data_seed=0)
             for e in (5.0, 30.0)]
    executor = SweepExecutor()
    art = executor.run(scens, store_thetas=False)
    assert all(c == 1 for c in executor.trace_counts.values())
    for s in scens:
        acc = art["scenarios"][s.scenario_id()]["metrics"]["accuracy"]
        assert 0.4 <= acc <= 1.0
    # more budget should not hurt a separable two-Gaussian problem much
    accs = [art["scenarios"][s.scenario_id()]["metrics"]["accuracy"]
            for s in scens]
    assert accs[1] >= accs[0] - 0.05
