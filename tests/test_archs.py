"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED variant of the same family, runs one forward/train step and one
decode step on CPU — output shapes asserted, no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import ShapeConfig
from repro.data.lm import make_batch
from repro.dist.grad_agg import GradAggConfig
from repro.models.model import Model
from repro.train.optimizer import AdamW
from repro.train.trainer import TrainConfig, make_train_step

SMOKE = ShapeConfig("smoke", seq_len=64, global_batch=4, kind="train")


@pytest.fixture(scope="module")
def models():
    return {}


def _model_and_params(arch, models):
    if arch not in models:
        cfg = get_config(arch, reduced=True)
        m = Model(cfg)
        models[arch] = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return models[arch]


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    assert cfg.citation
    spec = {
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == spec


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_bounds(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch, models):
    cfg, model, params = _model_and_params(arch, models)
    batch = make_batch(jax.random.PRNGKey(1), cfg, SMOKE.global_batch,
                       SMOKE.seq_len)
    logits, aux = model.forward(params, batch)
    S = SMOKE.seq_len + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (SMOKE.global_batch, S, cfg.vocab)
    assert not jnp.isnan(logits).any()
    loss, parts = model.loss(params, batch)
    assert jnp.isfinite(loss)
    if cfg.family == "moe":
        assert jnp.isfinite(parts["aux"])


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, models):
    cfg, model, params = _model_and_params(arch, models)
    batch = make_batch(jax.random.PRNGKey(2), cfg, SMOKE.global_batch,
                       SMOKE.seq_len)
    opt = AdamW(lr=1e-3)
    tcfg = TrainConfig(n_machines=2,
                       agg=GradAggConfig(method="dcq", dp_sigma=1e-5))
    step = jax.jit(make_train_step(model, opt, tcfg))
    params2, opt_state, metrics = step(params, opt.init(params), batch,
                                       jax.random.PRNGKey(3))
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually changed
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, params2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch, models):
    cfg, model, params = _model_and_params(arch, models)
    B = 2
    cache = model.init_cache(B, 16)
    tok = jnp.zeros((B, 1), jnp.int32)
    if cfg.family == "audio":
        tok = jnp.zeros((B, 1, cfg.n_codebooks), jnp.int32)
    logits, cache = jax.jit(model.decode_step)(params, cache,
                                               {"tokens": tok})
    assert logits.shape == (B, 1, cfg.vocab)
    assert not jnp.isnan(logits).any()
    assert int(cache["pos"]) == 1


def test_sliding_window_variant_reduces_cache():
    cfg = get_config("glm4-9b", reduced=True).with_sliding_window(8)
    model = Model(cfg)
    cache = model.init_cache(2, 64)
    assert cache["attn"]["k"].shape[2] == 8      # ring buffer = window
