"""repro.privacy registry: accountant contracts, byte-parity of the
"basic" default against the historical calibration, sigma orderings at
the paper's §5 budget, composition monotonicity, schema-v3 spend
ledgers for every registered accountant, and the serve-path conversion.
"""
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import dp
from repro.core.protocol import (ProtocolConfig, accountant_round_budget,
                                 calibrate_sigma_base)
from repro.privacy import (get_accountant, multiplier_ratio, registered,
                           resolve)
from repro.sweep import Scenario, run_scenarios
from repro.sweep import artifact as artifact_mod

# the paper's §5 operating point: total budget (5, 1e-5) over the six
# transmissions of untrusted-center Algorithm 1
EPS, DELTA, K = 5.0, 1e-5, 6


# ------------------------------------------------------------- registry

def test_registry_contains_the_four_accountants():
    assert set(registered()) == {"basic", "advanced", "rdp", "subexp"}
    assert resolve(None) == "basic"
    assert resolve("rdp") == "rdp"
    with pytest.raises(KeyError, match="basic"):
        get_accountant("typo")


def test_every_accountant_certifies_its_own_composition():
    """compose(per_round(eps, delta, k), k) must come back <= the total
    budget it was split from — the registry's defining contract."""
    for name in registered():
        acct = get_accountant(name)
        eps_r, delta_r = acct.per_round(EPS, DELTA, K)
        assert eps_r > 0 and 0 < delta_r < 1
        eps_back, delta_back = acct.compose(eps_r, delta_r, K)
        assert eps_back <= EPS * (1 + 1e-9), name
        assert delta_back <= DELTA * (1 + 1e-9), name


def test_exact_basic_ratio_is_the_literal_one():
    """basic/subexp short-circuit to 1.0 with no float math at all, and
    advanced's best-of falls back to the basic candidate at small k, so
    every one of them leaves the historical sigmas untouched."""
    assert multiplier_ratio("basic", EPS, DELTA, K) == 1.0
    assert multiplier_ratio("subexp", EPS, DELTA, K) == 1.0
    assert get_accountant("basic").exact_basic
    assert get_accountant("subexp").exact_basic
    # KOV's sqrt(k) regime needs k >~ 2 ln(1/delta): at the paper's k=6
    # the inverted advanced budget IS the even split (x/x == 1.0 exactly)
    assert multiplier_ratio("advanced", EPS, DELTA, K) == 1.0


def test_ratio_refuses_traced_budgets():
    with pytest.raises(TypeError, match="host-side"):
        jax.jit(lambda e: multiplier_ratio("rdp", e, DELTA, K))(EPS)


# ----------------------------------------- byte parity of the default

def test_basic_sigma_base_is_byte_identical():
    """The accountant parameter must not perturb the default path: same
    floats, bit for bit, with and without it (the CI smoke-golden gate
    asserts the same thing end-to-end)."""
    for trust in ("trusted", "untrusted"):
        cfg = ProtocolConfig(eps=EPS, delta=DELTA, center_trust=trust)
        legacy = calibrate_sigma_base(cfg, p=10, n=1000)
        for out in (calibrate_sigma_base(cfg, p=10, n=1000,
                                         accountant="basic"),
                    calibrate_sigma_base(cfg, p=10, n=1000,
                                         accountant="subexp")):
            assert out == legacy            # exact float equality


def test_tree_sigmas_basic_byte_identical_rdp_strictly_smaller():
    tree = {"w": jnp.zeros((4, 3)), "b": jnp.zeros((3,))}
    base = dp.calibrate_tree_sigmas(tree, n=500, eps=EPS, delta=DELTA)
    again = dp.calibrate_tree_sigmas(tree, n=500, eps=EPS, delta=DELTA,
                                     accountant="basic")
    assert jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: bool(jnp.all(a == b)),
        {k: base[k] for k in base}, {k: again[k] for k in again}))
    tight = dp.calibrate_tree_sigmas(tree, n=500, eps=EPS, delta=DELTA,
                                     accountant="rdp")
    for name in base:
        for s_b, s_r in zip(jax.tree_util.tree_leaves(base[name]),
                            jax.tree_util.tree_leaves(tight[name])):
            assert bool(jnp.all(s_r < s_b)), name


# ------------------------------------------- sigma ordering at §5 budget

def test_rdp_strictly_beats_basic_on_every_transmission():
    cfg = ProtocolConfig(eps=EPS, delta=DELTA, center_trust="untrusted")
    base = calibrate_sigma_base(cfg, p=10, n=1000)
    assert len(base) == K
    tight = calibrate_sigma_base(cfg, p=10, n=1000, accountant="rdp")
    for s_b, s_r in zip(base, tight):
        assert s_r < s_b
    # the measured tightening at this budget: ~2.65x less noise
    ratio = multiplier_ratio("rdp", EPS, DELTA, K)
    assert 0.3 < ratio < 0.45
    adv = calibrate_sigma_base(cfg, p=10, n=1000, accountant="advanced")
    for s_b, s_a in zip(base, adv):
        assert s_a <= s_b                    # never worse than basic


def test_advanced_strictly_beats_basic_at_large_k():
    """KOV Cor 4.1 wins once k >~ 2 ln(1/delta); document the crossover
    the README table quotes (k=6 ties, k=60 strictly better)."""
    assert multiplier_ratio("advanced", 1.0, 1e-6, 60) < 1.0
    eps_r, delta_r = get_accountant("advanced").per_round(1.0, 1e-6, 60)
    assert eps_r > 1.0 / 60                  # a larger per-round share...
    sig_adv = dp.noise_multiplier(eps_r, delta_r)
    sig_basic = dp.noise_multiplier(1.0 / 60, 1e-6 / 60)
    assert sig_adv < sig_basic               # ...means less noise


def test_compose_monotonicity_rdp_advanced_basic():
    """Composing each accountant's own per-round budget back up must
    order eps_rdp <= eps_advanced <= eps_basic at the §5 setting."""
    totals = {}
    for name in ("basic", "advanced", "rdp"):
        acct = get_accountant(name)
        eps_r, delta_r = acct.per_round(EPS, DELTA, K)
        totals[name] = acct.compose(eps_r, delta_r, K)[0]
    assert totals["rdp"] <= totals["advanced"] * (1 + 1e-9)
    assert totals["advanced"] <= totals["basic"] * (1 + 1e-9)
    assert totals["basic"] == pytest.approx(EPS)


def test_accountant_round_budget_matches_registry():
    cfg = ProtocolConfig(eps=EPS, delta=DELTA, center_trust="untrusted",
                         accountant="rdp")
    eps_r, delta_r = accountant_round_budget(cfg)
    want = get_accountant("rdp").per_round(EPS, DELTA, K)
    assert (eps_r, delta_r) == want
    basic_cfg = ProtocolConfig(eps=EPS, delta=DELTA)
    assert accountant_round_budget(basic_cfg) == (EPS / 5, DELTA / 5)


# ------------------------------------- schema-v3 ledger, every accountant

M, N, P = 6, 400, 4


@pytest.mark.slow
def test_spend_ledger_round_trips_for_every_accountant(tmp_path):
    scens = [Scenario(problem="logistic", m=M, n=N, p=P, eps=20.0,
                      delta=0.05, reps=1, data_seed=0, accountant=a)
             for a in registered()]
    assert len({s.scenario_id() for s in scens}) == len(scens)
    art = run_scenarios(scens)
    path = tmp_path / "acct.json"
    artifact_mod.save(art, str(path))
    loaded = artifact_mod.load(str(path))
    for s in scens:
        spend = loaded["scenarios"][s.scenario_id()]["spend"]
        assert spend["accountant"] == s.accountant
        assert len(spend["sigmas"]) == spend["n_transmissions"] == 5
        ratio = spend["sigma_ratio_vs_basic"]
        if s.accountant == "rdp":
            assert ratio < 1.0
        else:                       # basic, subexp, advanced at k=5
            assert ratio == 1.0
        if s.accountant == "subexp":
            assert len(spend["failure_probs"]) == 5
            assert all(f > 0 for f in spend["failure_probs"])
            assert spend["failure_prob_total"] == pytest.approx(
                min(1.0, sum(spend["failure_probs"])))
        row = [r for r in artifact_mod.rows(loaded)
               if r["scenario_id"] == s.scenario_id()][0]
        assert row["accountant"] == s.accountant


def test_tree_ledger_records_accountant_and_failure_prob():
    tree = {"w": jnp.zeros((4, 3)), "b": jnp.zeros((3,))}
    recs = dp.tree_spend_ledger(tree, n=500, eps=EPS, delta=DELTA,
                                accountant="subexp")
    assert recs and all(r["accountant"] == "subexp" for r in recs)
    assert all(r["failure_prob"] > 0 for r in recs)
    plain = dp.tree_spend_ledger(tree, n=500, eps=EPS, delta=DELTA)
    assert all(r["accountant"] == "basic" for r in plain)
    assert all("failure_prob" not in r for r in plain)
    # rdp's standalone per-round eps is LARGER than the even split (it
    # pays for composing tightly), but the sigma it buys is smaller
    tight = dp.tree_spend_ledger(tree, n=500, eps=EPS, delta=DELTA,
                                 accountant="rdp")
    assert tight[0]["eps"] > plain[0]["eps"]
    assert tight[0]["sigma"] < plain[0]["sigma"]


# ------------------------------------------------------------ serve path

def test_serve_accountant_scales_sigma_and_annotates_ledger():
    from repro.serve import AggregationService, FlushPolicy, ServeConfig

    def theta():                 # fresh per service: the step donates it
        return {"w": jnp.zeros((3,))}

    kw = dict(method="median", capacity=4, eps=1.0, delta=1e-5,
              ingest_block=2)
    basic = AggregationService(theta(), ServeConfig(**kw),
                               policy=FlushPolicy(min_fill=1))
    tight = AggregationService(theta(), ServeConfig(accountant="rdp",
                                                    **kw),
                               policy=FlushPolicy(min_fill=1))
    s_b = jax.tree_util.tree_leaves(basic._sigma)[0]
    s_r = jax.tree_util.tree_leaves(tight._sigma)[0]
    assert float(s_r) < float(s_b)          # k=1 tight conversion wins
    hp = AggregationService(theta(), ServeConfig(accountant="subexp",
                                                 **kw),
                            policy=FlushPolicy(min_fill=1))
    s_h = jax.tree_util.tree_leaves(hp._sigma)[0]
    assert float(s_h) == float(s_b)         # exact_basic: untouched
    hp.submit(jax.tree_util.tree_map(jnp.ones_like, theta()))
    hp.flush()
    assert hp.ledger and hp.ledger[0]["accountant"] == "subexp"
    assert hp.ledger[0]["failure_prob"] > 0
    basic.submit(jax.tree_util.tree_map(jnp.ones_like, theta()))
    basic.flush()
    assert basic.ledger[0]["accountant"] == "basic"
    assert "failure_prob" not in basic.ledger[0]
    with pytest.raises(KeyError):
        AggregationService(theta(), ServeConfig(accountant="nope", **kw))


# ----------------------------------------------- golden-key stability

def test_scenario_ids_stable_for_basic_distinct_for_others():
    base = Scenario(problem="logistic", m=M, n=N, p=P, eps=10.0)
    explicit = Scenario(problem="logistic", m=M, n=N, p=P, eps=10.0,
                        accountant="basic")
    assert base.scenario_id() == explicit.scenario_id()
    assert "accountant" not in dict(base.canonical())
    tight = Scenario(problem="logistic", m=M, n=N, p=P, eps=10.0,
                     accountant="rdp")
    assert tight.scenario_id() != base.scenario_id()
    assert "-rdp-" in tight.scenario_id()
    assert base.group_key() != tight.group_key()   # separate jit groups
    with pytest.raises(ValueError, match="accountant"):
        Scenario(problem="logistic", m=M, n=N, p=P, accountant="typo")


# ----------------------------- total_advanced silent-fallback regression

def test_total_advanced_heterogeneous_fallback_is_annotated():
    """Heterogeneous per-round budgets used to fall back to basic
    composition SILENTLY — the ledger now records the downgrade and
    warns exactly once per accountant instance."""
    a = dp.PrivacyAccountant()
    a.spend("r1", 1.0, 1e-4, 0.5)
    a.spend("r2", 2.0, 1e-4, 0.5)           # different eps: heterogeneous
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        total = a.total_advanced()
        again = a.total_advanced()          # second call: no second warn
    assert total == a.total_basic() == again
    runtime = [w for w in caught if issubclass(w.category, RuntimeWarning)]
    assert len(runtime) == 1
    assert "heterogeneous" in str(runtime[0].message)
    assert a.notes and "heterogeneous" in a.notes[0]
    assert "note:" in a.summary()
    # homogeneous spends: advanced composition, no note, no warning
    b = dp.PrivacyAccountant()
    for i in range(3):
        b.spend(f"r{i}", 1.0, 1e-4, 0.5)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        b.total_advanced()
    assert not caught and not b.notes
