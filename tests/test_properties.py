"""Hypothesis property tests for the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dp
from repro.core.bfgs import bfgs_inverse_update
from repro.core.dcq import dcq, d_k
from repro.core.robust_agg import median_agg, trimmed_mean_agg

_settings = settings(max_examples=25, deadline=None)


@_settings
@given(st.integers(min_value=1, max_value=60))
def test_dk_monotone_decreasing_in_k(K):
    """More quantile levels never hurt efficiency: D_K decreasing, >= pi/3."""
    assert d_k(K) >= np.pi / 3 - 1e-6
    if K > 1:
        assert d_k(K) <= d_k(K - 1) + 1e-9


@_settings
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.integers(min_value=5, max_value=200),
       st.floats(min_value=0.05, max_value=10.0))
def test_dcq_translation_and_scale_equivariance(seed, m, scale):
    """DCQ(a*Y + b) = a*DCQ(Y) + b when the scale argument transforms too."""
    key = jax.random.PRNGKey(seed)
    vals = jax.random.normal(key, (m, 2))
    base = dcq(vals, jnp.full((2,), 1.0), K=7)
    shifted = dcq(scale * vals + 3.0, jnp.full((2,), scale), K=7)
    np.testing.assert_allclose(np.asarray(shifted),
                               np.asarray(scale * base + 3.0),
                               rtol=1e-4, atol=1e-4)


@_settings
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.integers(min_value=11, max_value=101))
def test_dcq_bounded_by_sample_range(seed, m):
    """Robustness invariant: the estimate stays within a widened data range."""
    key = jax.random.PRNGKey(seed)
    vals = 10.0 * jax.random.normal(key, (m, 1))
    est = dcq(vals, jnp.full((1,), 10.0), K=10)
    lo, hi = float(vals.min()), float(vals.max())
    width = hi - lo
    assert lo - 0.5 * width <= float(est[0]) <= hi + 0.5 * width


@_settings
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_median_breakdown_point(seed):
    """Corrupting <50% of machines by huge values cannot move the median
    beyond the clean sample range."""
    key = jax.random.PRNGKey(seed)
    m = 51
    vals = jax.random.normal(key, (m, 3))
    n_bad = 25
    corrupted = vals.at[:n_bad].set(1e6)
    med = median_agg(corrupted)
    assert np.all(np.asarray(med) <= np.asarray(vals.max(0)) + 1e-6)


@_settings
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.floats(min_value=0.1, max_value=0.4))
def test_trimmed_mean_kills_extreme_outliers(seed, beta):
    key = jax.random.PRNGKey(seed)
    m = 100
    vals = jax.random.normal(key, (m, 2))
    n_bad = int(beta * m / 2)  # strictly fewer than trimmed from each side
    corrupted = vals.at[:max(n_bad - 1, 0)].set(1e8)
    tm = trimmed_mean_agg(corrupted, beta=beta)
    assert np.all(np.abs(np.asarray(tm)) < 10.0)


@_settings
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.integers(min_value=2, max_value=12))
def test_bfgs_update_preserves_spd(seed, p):
    """BFGS keeps H^{-1} symmetric positive definite when s^T y > 0."""
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(jax.random.fold_in(key, 0), (p, p))
    h = jnp.linalg.inv(a @ a.T + p * jnp.eye(p))
    s = jax.random.normal(jax.random.fold_in(key, 1), (p,))
    y = jax.random.normal(jax.random.fold_in(key, 2), (p,))
    y = jnp.where(jnp.dot(s, y) > 0, y, -y) + 0.1 * s
    h_new = bfgs_inverse_update(h, s, y)
    evals = np.linalg.eigvalsh(np.asarray(h_new, np.float64))
    assert evals.min() > -1e-5
    # secant equation
    np.testing.assert_allclose(np.asarray(h_new @ y), np.asarray(s),
                               rtol=2e-3, atol=2e-3)


@_settings
@given(st.floats(min_value=0.05, max_value=5.0),
       st.floats(min_value=1e-6, max_value=0.1),
       st.integers(min_value=1, max_value=20))
def test_advanced_composition_never_worse_than_basic(eps, delta, k):
    e_adv, d_adv = dp.compose_advanced(eps, delta, k, slack=1e-3)
    assert e_adv <= k * eps + 1e-9
    assert d_adv >= k * delta - 1e-9 or d_adv >= 0


@_settings
@given(st.integers(min_value=100, max_value=10 ** 6),
       st.floats(min_value=0.5, max_value=5.0))
def test_noise_scales_inversely_with_n(n, gamma):
    """All five round calibrations must shrink as local sample size grows."""
    args = dict(p=10, gamma=gamma, eps=1.0, delta=0.01)
    for fn in (lambda n: dp.s1_theta(n=n, lambda_s=0.2, **args),
               lambda n: dp.s2_grad(n=n, **args)):
        assert fn(2 * n) < fn(n)
