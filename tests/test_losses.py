"""Closed-form loss derivatives must agree with autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.losses import PROBLEMS, get_problem
from repro.data.synthetic import linear_data, logistic_data, poisson_data

_DATA = {"logistic": logistic_data, "poisson": poisson_data,
         "linear": linear_data, "huber": linear_data}


@pytest.mark.parametrize("name", list(PROBLEMS))
def test_grad_matches_autodiff(name):
    prob = get_problem(name)
    X, y = _DATA[name](jax.random.PRNGKey(0), 200, 5)
    theta = 0.3 * jnp.ones((5,))
    g_closed = prob.grad(theta, X, y)
    g_auto = jax.grad(lambda t: prob.loss(t, X, y))(theta)
    np.testing.assert_allclose(np.asarray(g_closed), np.asarray(g_auto),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", ["logistic", "poisson", "linear"])
def test_hessian_matches_autodiff(name):
    prob = get_problem(name)
    X, y = _DATA[name](jax.random.PRNGKey(1), 200, 4)
    theta = 0.2 * jnp.ones((4,))
    h_closed = prob.hessian(theta, X, y)
    h_auto = jax.hessian(lambda t: prob.loss(t, X, y))(theta)
    np.testing.assert_allclose(np.asarray(h_closed), np.asarray(h_auto),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", ["logistic", "poisson", "linear"])
def test_per_sample_quantities_consistent(name):
    prob = get_problem(name)
    X, y = _DATA[name](jax.random.PRNGKey(2), 64, 3)
    theta = 0.1 * jnp.ones((3,))
    g = prob.per_sample_grads(theta, X, y)
    np.testing.assert_allclose(np.asarray(g.mean(0)),
                               np.asarray(prob.grad(theta, X, y)), rtol=1e-5)
    h = prob.per_sample_hessians(theta, X, y)
    np.testing.assert_allclose(np.asarray(h.mean(0)),
                               np.asarray(prob.hessian(theta, X, y)),
                               rtol=1e-5, atol=1e-6)


def test_losses_are_convex_along_lines():
    # spot-check convexity: f(mid) <= (f(a)+f(b))/2 along random segments
    for name in ("logistic", "poisson", "linear", "huber"):
        prob = get_problem(name)
        X, y = _DATA[name](jax.random.PRNGKey(3), 100, 4)
        key = jax.random.PRNGKey(4)
        for i in range(5):
            ka, kb = jax.random.split(jax.random.fold_in(key, i))
            a = jax.random.normal(ka, (4,))
            b = jax.random.normal(kb, (4,))
            fa, fb = prob.loss(a, X, y), prob.loss(b, X, y)
            fm = prob.loss(0.5 * (a + b), X, y)
            assert float(fm) <= float(0.5 * (fa + fb)) + 1e-5
