"""The streaming aggregation service: masked-aggregation byte contract,
ring-buffer/flush-policy semantics, and the compile-once service loop.

The load-bearing contract (repro.agg.masked): aggregating a
fixed-capacity buffer's valid prefix through ``aggregate_masked`` is
byte-identical to running the SAME masked entry on the dense unpadded
prefix — for every registered aggregator, at every fill, under one
trace per capacity. ``median`` is additionally bit-equal to the
registry reference at every fill; all rules agree with the reference
values to float tolerance (XLA's reduce trees make byte-equality
against the raw reference impossible for sum-based rules at partial
fill — only the summation ORDER differs).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import agg
from repro.core import transport
from repro.serve import (AggregationService, FlushPolicy, RingBuffer,
                         ServeConfig)

C, P = 12, 5
FILLS = (1, 2, 5, 6, 11, 12)


def _vals(seed=0, rows=C, p=P):
    return jax.random.normal(jax.random.PRNGKey(seed), (rows, p))


def _scale_for(method):
    return jnp.full((P,), 0.7) if agg.get_aggregator(method).needs_scale \
        else None


# ------------------------------------------------- the fill-invariance law

@pytest.mark.parametrize("method", sorted(agg.registered()))
def test_every_registered_aggregator_is_servable(method):
    assert agg.has_masked(method)


@pytest.mark.parametrize("method", sorted(agg.registered()))
def test_masked_byte_identical_to_dense_unpadded(method):
    """Half-full (and any-full) buffer == dense unpadded batch, byte for
    byte, jit vs jit, for EVERY registered aggregator."""
    vals = _vals()
    sc = _scale_for(method)
    f = jax.jit(lambda v, fill: agg.aggregate_masked(
        v, fill, method=method, scale=sc))
    for k in FILLS:
        buffered = f(vals, jnp.int32(k))
        dense = f(vals[:k], jnp.int32(k))
        np.testing.assert_array_equal(
            np.asarray(buffered), np.asarray(dense),
            err_msg=f"{method} diverges at fill={k}")


@pytest.mark.parametrize("method", sorted(agg.registered()))
def test_masked_values_match_reference(method):
    """The masked path computes the same statistic as the registry
    reference on the valid prefix (float tolerance: XLA chooses
    different — equally valid — summation orders per row count)."""
    vals = _vals(3)
    sc = _scale_for(method)
    f = jax.jit(lambda v, fill: agg.aggregate_masked(
        v, fill, method=method, scale=sc))
    for k in FILLS:
        got = np.asarray(f(vals, jnp.int32(k)))
        want = np.asarray(jax.jit(lambda v: agg.aggregate(
            v, method=method, scale=sc, backend="reference"))(vals[:k]))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5,
                                   err_msg=f"{method} at fill={k}")


def test_masked_median_bitwise_equals_reference():
    """Order statistics dodge the summation-order caveat: the parity-
    balanced padding makes masked median EXACTLY the reference median of
    the prefix, at every fill."""
    vals = _vals(7)
    f = jax.jit(lambda v, fill: agg.aggregate_masked(v, fill,
                                                     method="median"))
    ref = jax.jit(lambda v: jnp.median(v, axis=0))
    for k in range(1, C + 1):
        np.testing.assert_array_equal(
            np.asarray(f(vals, jnp.int32(k))), np.asarray(ref(vals[:k])),
            err_msg=f"median != reference at fill={k}")


def test_masked_one_trace_across_fills():
    """Every fill level reuses ONE executable — fill is a traced scalar,
    never a shape."""
    traces = {"n": 0}

    def run(v, fill):
        traces["n"] += 1
        return agg.aggregate_masked(v, fill, method="dcq_mad")

    f = jax.jit(run)
    vals = _vals(1)
    for k in FILLS:
        f(vals, jnp.int32(k)).block_until_ready()
    assert traces["n"] == 1


def test_wire_aggregate_fill_routes_pytrees():
    """transport.wire_aggregate(fill=...) == the masked entry per leaf,
    byte for byte (the serving step's actual call path)."""
    key = jax.random.PRNGKey(5)
    tree = {"w": jax.random.normal(key, (C, 3, 2)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (C,))}
    wired = jax.jit(lambda t, fill: transport.wire_aggregate(
        t, "median", fill=fill))
    direct = jax.jit(lambda x, fill: agg.aggregate_masked(
        x, fill, method="median"))
    for k in (1, 6, C):
        out = wired(tree, jnp.int32(k))
        for name in ("w", "b"):
            np.testing.assert_array_equal(
                np.asarray(out[name]),
                np.asarray(direct(tree[name], jnp.int32(k))))


def test_masked_errors():
    vals = _vals()
    with pytest.raises(ValueError, match="scale"):
        agg.aggregate_masked(vals, jnp.int32(3), method="dcq")
    with pytest.raises(ValueError, match="trim"):
        jax.jit(lambda v, f: agg.aggregate_masked(
            v, f, method="trimmed", trim_beta=0.5))(vals, jnp.int32(3))


# ----------------------------------------------------------- ring buffer

def test_ring_buffer_prefix_and_wrap():
    buf = RingBuffer(jax.ShapeDtypeStruct((P,), jnp.float32), capacity=4)
    rows = _vals(2, rows=6)
    for i in range(4):
        assert buf.push(rows[i]) == i
    assert buf.fill == 4 and buf.full
    # ring semantics: the 5th write wraps onto slot 0
    assert buf.push(rows[4]) == 0
    assert buf.fill == 4
    got = np.asarray(buf.arrays)
    np.testing.assert_array_equal(got[0], np.asarray(rows[4]))
    np.testing.assert_array_equal(got[1:], np.asarray(rows[1:4]))


def test_ring_buffer_block_write_needs_room():
    buf = RingBuffer(jax.ShapeDtypeStruct((P,), jnp.float32),
                     capacity=8, block=4)
    rows = _vals(4, rows=8)
    buf.push_block(rows, 0)
    buf.push_block(rows, 4)
    assert buf.full
    with pytest.raises(ValueError, match="room"):
        buf.push_block(rows, 0)
    np.testing.assert_array_equal(np.asarray(buf.arrays),
                                  np.asarray(rows))


def test_ring_buffer_compiles_each_writer_once():
    buf = RingBuffer(jax.ShapeDtypeStruct((P,), jnp.float32),
                     capacity=8, block=2)
    rows = _vals(5, rows=8)
    buf.push(rows[0])
    buf.push(rows[1])
    buf.push_block(rows, 2)
    buf.push_block(rows, 4)
    assert buf.trace_counts == {"write": 1, "write_block": 1}
    buf.reset()
    assert buf.fill == 0
    buf.push(rows[7])
    assert buf.trace_counts == {"write": 1, "write_block": 1}


# ----------------------------------------------------------- flush policy

def test_flush_policy_triggers():
    pol = FlushPolicy(capacity_frac=0.5, max_delay_s=1.0, min_fill=3)
    assert pol.capacity_trigger(12) == 6
    assert not pol.should_flush(2, 12)            # below min_fill
    assert not pol.should_flush(2, 12, age_s=5.0)  # min_fill floors age too
    assert not pol.should_flush(5, 12)
    assert pol.should_flush(6, 12)                # capacity trigger
    assert pol.should_flush(3, 12, age_s=1.0)     # deadline trigger
    assert not pol.should_flush(3, 12, age_s=0.5)
    none = FlushPolicy(capacity_frac=None)
    assert none.capacity_trigger(12) is None
    assert not none.should_flush(12, 12)          # explicit flushes only


def test_flush_policy_validation():
    for bad in (dict(capacity_frac=0.0), dict(capacity_frac=1.5),
                dict(max_delay_s=-1.0), dict(min_fill=0),
                dict(backpressure="drop")):
        with pytest.raises(ValueError):
            FlushPolicy(**bad)


# ------------------------------------------------------------ the service

def test_service_multi_flush_single_trace():
    """An entire multi-round run — block ingest, row ingest, partial and
    full flushes — retraces nothing: exactly one step trace, one trace
    per buffer writer."""
    cfg = ServeConfig(method="dcq_mad", capacity=C, ingest_block=4,
                      lr=0.5, seed=2)
    svc = AggregationService(jnp.zeros(P), cfg)
    key = jax.random.PRNGKey(0)
    for r in range(3):
        assert svc.submit_many(
            jax.random.normal(jax.random.fold_in(key, r), (C, P))) == C
    # a partial round through the row path + explicit flush
    for row in _vals(9, rows=5):
        svc.submit(row)
    assert svc.flush() is not None
    assert svc.round_idx == 4
    assert [h["fill"] for h in svc.history] == [C, C, C, 5]
    assert svc.trace_counts == {"step": 1, "write": 1, "write_block": 1}


def test_service_round_matches_dense_aggregation():
    """One served round == the dense masked aggregate, byte for byte,
    and theta moves by exactly -lr * aggregate."""
    cfg = ServeConfig(method="median", capacity=C, lr=0.25, seed=0)
    svc = AggregationService(jnp.zeros(P), cfg)
    ups = _vals(11)
    svc.submit_many(ups)
    want = jax.jit(lambda v, f: agg.aggregate_masked(
        v, f, method="median"))(ups, jnp.int32(C))
    np.testing.assert_array_equal(np.asarray(svc.theta),
                                  np.asarray(-0.25 * want))


def test_service_ledger_records_every_round():
    tree = {"w": jnp.zeros((3, 2)), "b": jnp.zeros(3)}
    cfg = ServeConfig(method="median", capacity=6, eps=0.5, delta=1e-6,
                      dp_n=200, seed=1)
    svc = AggregationService(tree, cfg)
    ups = {"w": _vals(0, rows=6, p=1).reshape(6, 1, 1)
           * jnp.ones((6, 3, 2)), "b": _vals(1, rows=6, p=3)}
    for r in range(3):
        svc.submit_many(ups)
    assert svc.round_idx == 3
    # one spend-ledger record per leaf per round, eps/delta attached
    assert len(svc.ledger) == 3 * 2
    assert {e["transmission"] for e in svc.ledger} == \
        {f"serve round {r}" for r in range(3)}
    assert all(e["eps"] == 0.5 and e["sigma"] > 0 and e["noise"]
               for e in svc.ledger)
    # and one composition entry per round on the accountant
    eps_tot, delta_tot = svc.accountant.total_basic()
    assert eps_tot == pytest.approx(1.5)
    assert delta_tot == pytest.approx(3e-6)


def test_service_noiseless_ledger_still_records():
    svc = AggregationService(jnp.zeros(P), ServeConfig(capacity=4))
    svc.submit_many(_vals(2, rows=4))
    assert len(svc.ledger) == 1
    assert svc.ledger[0]["eps"] == 0.0 and not svc.ledger[0]["noise"]


def test_service_deadline_flush_via_poll():
    pol = FlushPolicy(capacity_frac=None, max_delay_s=0.2, min_fill=2)
    svc = AggregationService(jnp.zeros(P),
                             ServeConfig(capacity=C), policy=pol)
    rows = _vals(0, rows=3)
    svc.submit(rows[0])
    time.sleep(0.25)
    assert svc.poll() is None            # min_fill floors the deadline
    # the next arrival sees the overdue deadline: ingest itself flushes
    svc.submit(rows[1])
    assert svc.round_idx == 1 and svc.history[-1]["fill"] == 2
    svc = AggregationService(jnp.zeros(P),
                             ServeConfig(capacity=C), policy=pol)
    svc.submit(rows[0])
    svc.submit(rows[1])
    assert svc.round_idx == 0            # age < deadline at ingest
    time.sleep(0.25)
    assert svc.poll() is not None        # deadline fires on the partial fleet
    assert svc.history[-1]["fill"] == 2
    assert svc.poll() is None            # empty buffer: nothing to serve


def test_service_backpressure_reject():
    pol = FlushPolicy(capacity_frac=None, backpressure="reject")
    svc = AggregationService(jnp.zeros(P),
                             ServeConfig(capacity=4), policy=pol)
    assert svc.submit_many(_vals(3, rows=6)) == 4
    assert svc.rejected == 2 and svc.fill == 4
    assert svc.flush() is not None


def test_service_backpressure_overwrite():
    pol = FlushPolicy(capacity_frac=None, backpressure="overwrite")
    svc = AggregationService(jnp.zeros(P),
                             ServeConfig(capacity=4), policy=pol)
    rows = _vals(6, rows=6)
    for row in rows:
        assert svc.submit(row)
    assert svc.rejected == 0 and svc.fill == 4
    # ring wrapped: slots now hold rows [4, 5, 2, 3]
    got = np.asarray(svc.buffer.arrays)
    np.testing.assert_array_equal(got, np.asarray(
        jnp.stack([rows[4], rows[5], rows[2], rows[3]])))


def test_service_min_fill_blocks_explicit_flush():
    pol = FlushPolicy(capacity_frac=None, min_fill=3)
    svc = AggregationService(jnp.zeros(P),
                             ServeConfig(capacity=C), policy=pol)
    svc.submit(_vals(0, rows=1)[0])
    assert svc.flush() is None and svc.round_idx == 0
    svc.submit_many(_vals(1, rows=2))
    assert svc.flush() is not None and svc.round_idx == 1
