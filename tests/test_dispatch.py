"""Tests for the measured backend-dispatch table and the autotuner.

Covers: table round-trip and int-param validation, shape-bucketed
lookup, the three-tier ``backend=None`` policy (table hit / unmeasured
reference fallback / no-table heuristic), autotune determinism under a
stubbed clock, Pallas-vs-reference parity at mid-p and large-p for every
registered Pallas aggregator, the sort-free masked bisect backend's
parity + fill-invariance, and the ``dcq_pallas`` interpret default fix.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import pytest

from repro import agg
from repro.agg import autotune as at
from repro.agg import dispatch
from repro.agg.dispatch import Decision, DispatchTable, bucket_of
from repro.agg.kernel import clamp_block, dcq_pallas, ostat_pallas

pytestmark = []


@pytest.fixture(autouse=True)
def _clean_dispatch_state(monkeypatch):
    """Every test sees no env override, no injected table, cold cache."""
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    dispatch.set_table(None)
    dispatch.clear_cache()
    yield
    dispatch.set_table(None)
    dispatch.clear_cache()


def _table(platform="cpu"):
    t = DispatchTable(platform)
    t.record("median", 320, 8, 10, "reference", 0.001)
    t.record("median", 320, 8, 10, "pallas", 0.005,
             tile=10, inner=1, n_bisect=60)
    t.record("median", 1, 8, 262144, "pallas", 0.002,
             tile=2048, inner=4, n_bisect=32)
    t.record("median", 1, 8, 262144, "reference", 0.009)
    t.record("masked:median", 1, 256, 4096, "bisect", 0.001)
    t.record("masked:median", 1, 256, 4096, "sort", 0.004)
    return t


# ---------------------------------------------------------------------------
# table round-trip + validation
# ---------------------------------------------------------------------------
def test_table_round_trip(tmp_path):
    t = _table()
    path = t.save(tmp_path / "cpu.json")
    back = DispatchTable.load(path)
    assert back.platform == "cpu"
    assert back.to_json() == t.to_json()
    # JSON on disk is the documented schema
    payload = json.loads(path.read_text())
    assert payload["schema"] == dispatch.SCHEMA
    assert set(payload) == {"schema", "platform", "meta", "entries"}


def test_from_json_rejects_wrong_schema():
    with pytest.raises(ValueError, match="schema"):
        DispatchTable.from_json({"schema": "bogus/v9", "platform": "cpu"})


def test_record_rejects_non_int_params():
    t = DispatchTable("cpu")
    with pytest.raises(TypeError, match="non-int"):
        t.record("median", 1, 8, 10, "pallas", 0.001, tile=512.0)


def test_from_json_rejects_non_int_params():
    payload = _table().to_json()
    key = "median|" + bucket_of(1, 8, 262144)
    payload["entries"][key]["backends"]["pallas"]["params"]["tile"] = 2048.0
    with pytest.raises(ValueError, match="non-int"):
        DispatchTable.from_json(payload)


def test_best_recomputed_per_record():
    t = DispatchTable("cpu")
    t.record("mean", 1, 8, 10, "pallas", 0.005, tile=10, inner=1)
    assert t.best("mean", 1, 8, 10)[0] == "pallas"
    t.record("mean", 1, 8, 10, "reference", 0.001)
    assert t.best("mean", 1, 8, 10) == ("reference", {})


# ---------------------------------------------------------------------------
# shape-bucketed lookup
# ---------------------------------------------------------------------------
def test_bucket_of_floor_log2():
    assert bucket_of(320, 8, 10) == "B8:m3:p3"
    assert bucket_of(1, 8, 262144) == "B0:m3:p18"
    # degenerate axes clamp to bucket 0
    assert bucket_of(0, 1, 1) == "B0:m0:p0"


def test_lookup_covers_power_of_two_neighbourhood():
    t = _table()
    # (300, 9, 11) shares the (320, 8, 10) bucket: B8:m3:p3
    assert t.best("median", 300, 9, 11) == ("reference", {})
    # crossing a power of two leaves the bucket
    assert t.best("median", 300, 9, 16) is None


# ---------------------------------------------------------------------------
# decide(): the three-tier backend=None policy
# ---------------------------------------------------------------------------
def test_decide_table_hit_returns_measured_best():
    dispatch.set_table(_table(), platform="cpu")
    d = dispatch.decide("median", 1, 8, 262144, platform="cpu")
    assert d == Decision("pallas", {"tile": 2048, "inner": 4,
                                    "n_bisect": 32}, True, "table")


def test_decide_unmeasured_bucket_falls_back_to_reference():
    dispatch.set_table(_table(), platform="cpu")
    d = dispatch.decide("median", 1, 8, 999999, platform="cpu")
    assert d.backend == "reference"
    assert d.source == "fallback-unmeasured"
    assert not d.measured
    # masked ops fall back to the contractual sort form instead
    dm = dispatch.decide("masked:dcq", 1, 256, 7, platform="cpu")
    assert (dm.backend, dm.source) == ("sort", "fallback-unmeasured")


def test_decide_no_table_uses_platform_heuristic():
    d = dispatch.decide("median", 1, 8, 10, platform="tpu")
    assert (d.backend, d.source) == ("pallas", "fallback-no-table")
    d = dispatch.decide("median", 1, 8, 10, platform="nosuch")
    assert d.backend == "reference"
    d = dispatch.decide("masked:median", 1, 256, 10, platform="nosuch")
    assert d.backend == "sort"


def test_env_var_override_loads_custom_table(tmp_path, monkeypatch):
    t = _table()
    t.record("mean", 1, 8, 10, "reference", 0.001)
    path = t.save(tmp_path / "tuned.json")
    monkeypatch.setenv(dispatch.ENV_VAR, str(path))
    dispatch.clear_cache()
    d = dispatch.decide("mean", 1, 8, 10, platform="cpu")
    assert d.source == "table"


def test_platform_mismatch_table_is_ignored(tmp_path, monkeypatch):
    path = _table(platform="cpu").save(tmp_path / "t.json")
    monkeypatch.setenv(dispatch.ENV_VAR, str(path))
    dispatch.clear_cache()
    # a cpu table must not steer a (hypothetical) tpu run
    d = dispatch.decide("median", 320, 8, 10, platform="tpu")
    assert d.source == "fallback-no-table"


# ---------------------------------------------------------------------------
# aggregate()/aggregate_batched() route backend=None through the table
# ---------------------------------------------------------------------------
def test_aggregate_batched_uses_table_decision():
    plat = jax.default_backend()
    v = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 64))
    ref = agg.aggregate_batched(v, method="median", backend="reference")

    t = DispatchTable(plat)
    t.record("median", 2, 8, 64, "pallas", 0.001,
             tile=64, inner=1, n_bisect=60)
    t.record("median", 2, 8, 64, "reference", 0.009)
    dispatch.set_table(t, platform=plat)
    auto = agg.aggregate_batched(v, method="median")     # backend=None
    assert jnp.max(jnp.abs(auto - ref)) == 0.0

    # unmeasured bucket: table present -> reference fallback, still exact
    v2 = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 4096))
    auto2 = agg.aggregate_batched(v2, method="median")
    ref2 = agg.aggregate_batched(v2, method="median", backend="reference")
    assert jnp.array_equal(auto2, ref2)


def test_aggregate_masked_uses_table_decision():
    plat = jax.default_backend()
    buf = jax.random.normal(jax.random.PRNGKey(2), (64, 33))
    fill = jnp.int32(41)
    srt = agg.aggregate_masked(buf, fill, method="median", backend="sort")

    t = DispatchTable(plat)
    t.record("masked:median", 1, 64, 33, "bisect", 0.001)
    t.record("masked:median", 1, 64, 33, "sort", 0.009)
    dispatch.set_table(t, platform=plat)
    auto = agg.aggregate_masked(buf, fill, method="median")
    assert float(jnp.max(jnp.abs(auto - srt))) < 1e-5


def test_wire_aggregate_masked_backend_passthrough():
    from repro.core.transport import wire_aggregate
    buf = jax.random.normal(jax.random.PRNGKey(9), (32, 11))
    fill = jnp.int32(21)
    srt = wire_aggregate(buf, "median", fill=fill, backend="sort")
    bis = wire_aggregate(buf, "median", fill=fill, backend="bisect")
    assert float(jnp.max(jnp.abs(srt - bis))) < 1e-5
    # pytree leaves route the same backend choice
    tree = {"w": buf, "b": buf[:, :3]}
    out = wire_aggregate(tree, "median", fill=fill, backend="bisect")
    assert set(out) == {"w", "b"}


def test_forced_bisect_without_form_raises():
    buf = jnp.zeros((8, 3))
    with pytest.raises(ValueError, match="sort-free"):
        agg.aggregate_masked(buf, jnp.int32(4), method="trimmed",
                             backend="bisect", trim_beta=0.2)


# ---------------------------------------------------------------------------
# autotune determinism under a stubbed clock
# ---------------------------------------------------------------------------
class _StubClock:
    """perf_counter stand-in advancing a fixed tick per call."""

    def __init__(self, tick=0.001):
        self.t, self.tick = 0.0, tick

    def __call__(self):
        self.t += self.tick
        return self.t


def test_autotune_deterministic_under_fixed_clock():
    runs = []
    for _ in range(2):
        t = at.autotune(ops=["median"], shapes=((2, 8, 32),), platform="cpu",
                        reps=1, timer=_StubClock(), include_masked=False,
                        verbose=False)
        runs.append(json.dumps(t.to_json(), sort_keys=True))
    assert runs[0] == runs[1]
    payload = json.loads(runs[0])
    entry = payload["entries"]["median|" + bucket_of(2, 8, 32)]
    assert set(entry["backends"]) == {"reference", "pallas"}
    assert entry["best"] in entry["backends"]
    params = entry["backends"]["pallas"]["params"]
    assert all(isinstance(params[k], int) for k in params)


def test_autotune_masked_records_both_backends():
    t = at.autotune(ops=[], shapes=((1, 8, 16),), platform="cpu", reps=1,
                    timer=_StubClock(), masked_capacity=16, verbose=False)
    entry = t.entries["masked:median|" + bucket_of(1, 16, 16)]
    assert set(entry["backends"]) >= {"sort", "bisect"}


def test_pallas_candidates_respect_clamp():
    for tile, inner, nb in at._pallas_candidates("median", 8, 4096):
        ct, ci = clamp_block(8, 4096, tile, inner)
        assert (ct, ci) == (tile, inner)
        assert all(isinstance(x, int) for x in (tile, inner, nb))


# ---------------------------------------------------------------------------
# kernel parity at mid-p / large-p for every registered Pallas aggregator
# ---------------------------------------------------------------------------
_PALLAS_OPS = [n for n in agg.registered() if agg.has_pallas(n)]


@pytest.mark.parametrize("op", _PALLAS_OPS)
def test_pallas_matches_reference_mid_p(op):
    a = agg.get_aggregator(op)
    v = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4096)) * 3.0
    scale = (jnp.abs(jax.random.normal(jax.random.PRNGKey(1),
                                       (2, 4096))) + 0.1
             if a.needs_scale else None)
    ref = a.reference(v, scale=scale, K=10, trim_beta=0.2, axis=-2)
    out = ostat_pallas(v, op, scale, K=10, trim_beta=0.2,
                       tile=1024, inner=2, n_bisect=60)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


def test_pallas_matches_reference_large_p():
    # one model-gradient-sized problem; tile*inner caps the VMEM block
    v = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 262144))
    ref = agg.get_aggregator("median").reference(
        v, scale=None, K=10, trim_beta=0.2, axis=-2)
    out = ostat_pallas(v, "median", None, tile=2048, inner=4, n_bisect=60)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


def test_clamp_block_bounds_vmem():
    from repro.agg.kernel import VMEM_BUDGET_BYTES
    for p in (10, 4096, 262144, 1 << 22):
        for tile in (256, 2048, 1 << 20):
            for inner in (1, 4, 64):
                ct, ci = clamp_block(8, p, tile, inner)
                assert 8 * ct * ci * 4 <= max(VMEM_BUDGET_BYTES,
                                              8 * ct * 4)
                assert ct >= 1 and ci >= 1


def test_tuned_n_bisect_changes_cost_not_result():
    v = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 128))
    full = ostat_pallas(v, "median", None, n_bisect=60)
    short = ostat_pallas(v, "median", None, n_bisect=32)
    # 32 halvings of a ~[-4, 4] range is ~1e-9 resolution: same answer
    assert float(jnp.max(jnp.abs(full - short))) < 1e-6


# ---------------------------------------------------------------------------
# masked bisect backend: parity + fill-invariance
# ---------------------------------------------------------------------------
_BISECT_RULES = [n for n in agg.registered()
                 if agg.get_aggregator(n).masked_bisect is not None]


@pytest.mark.parametrize("rule", _BISECT_RULES)
@pytest.mark.parametrize("fill", [1, 7, 40, 64])
def test_masked_bisect_matches_sort(rule, fill):
    a = agg.get_aggregator(rule)
    buf = jax.random.normal(jax.random.PRNGKey(3), (64, 33)) * 2.0
    scale = (jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (33,))) + 0.1
             if a.needs_scale else None)
    srt = agg.aggregate_masked(buf, jnp.int32(fill), method=rule,
                               scale=scale, backend="sort")
    bis = agg.aggregate_masked(buf, jnp.int32(fill), method=rule,
                               scale=scale, backend="bisect")
    assert float(jnp.max(jnp.abs(srt - bis))) < 1e-4


@pytest.mark.parametrize("rule", _BISECT_RULES)
def test_masked_bisect_fill_invariance(rule):
    a = agg.get_aggregator(rule)
    fill = 41
    buf = jax.random.normal(jax.random.PRNGKey(5), (64, 17))
    scale = (jnp.abs(jax.random.normal(jax.random.PRNGKey(6), (17,))) + 0.1
             if a.needs_scale else None)
    garbage = buf.at[fill:].set(jnp.inf)    # stale tail must never be read
    f = jnp.int32(fill)
    clean = agg.aggregate_masked(buf, f, method=rule, scale=scale,
                                 backend="bisect")
    dirty = agg.aggregate_masked(garbage, f, method=rule, scale=scale,
                                 backend="bisect")
    assert jnp.array_equal(clean, dirty), (
        "bisect masked form read past fill")


# ---------------------------------------------------------------------------
# satellites: dcq_pallas interpret default, committed cpu table sanity
# ---------------------------------------------------------------------------
def test_dcq_pallas_interpret_default_auto_selects():
    import inspect
    sig = inspect.signature(dcq_pallas)
    assert sig.parameters["interpret"].default is None, (
        "dcq_pallas must auto-select interpret mode off-TPU, "
        "not hardcode True")
    # and it actually runs under the auto default on this platform
    v = jax.random.normal(jax.random.PRNGKey(7), (8, 32))
    out = dcq_pallas(v, K=10)
    assert out.shape == (32,)


def test_committed_cpu_table_loads_and_serves():
    path = dispatch.TABLE_DIR / "cpu.json"
    assert path.is_file(), "committed CPU dispatch table is missing"
    t = DispatchTable.load(path)
    assert t.platform == "cpu"
    # the sweep regime bucket must be measured (it gates BENCH_agg)
    assert t.best("median", 320, 8, 10) is not None
    # every recorded param is an int (jit static-arg hygiene)
    for entry in t.entries.values():
        for rec in entry["backends"].values():
            for v in rec.get("params", {}).values():
                assert isinstance(v, int)
