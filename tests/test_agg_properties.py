"""Hypothesis property tests for the repro.agg subsystem: Pallas-vs-
reference agreement for every registered aggregator over arbitrary
shapes (m-parity included) and the batched grid path, plus structural
invariants of the bisection kernel (affine equivariance, tie handling)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import agg  # noqa: E402
from repro.agg import (aggregate, aggregate_batched,  # noqa: E402
                       get_aggregator, registered)

PALLAS_AGGS = tuple(n for n in registered() if agg.has_pallas(n))

_settings = settings(max_examples=15, deadline=None)


def _scale_for(method, shape, seed=7):
    if get_aggregator(method).needs_scale:
        return jnp.abs(jax.random.normal(jax.random.PRNGKey(seed),
                                         shape)) + 0.1
    return None


@_settings
@given(m=st.integers(3, 40), p=st.integers(1, 70),
       method=st.sampled_from(PALLAS_AGGS))
def test_pallas_reference_agreement_property(m, p, method):
    """For every registered Pallas aggregator, any (m, p) shape agrees
    with the reference oracle."""
    v = jax.random.normal(jax.random.PRNGKey(m * 97 + p), (m, p)) * 3.0
    scale = _scale_for(method, (p,))
    ref = aggregate(v, method, scale=scale, backend="reference")
    pal = aggregate(v, method, scale=scale, backend="pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               atol=1e-4, rtol=1e-3)


@_settings
@given(b=st.integers(1, 6), m=st.integers(3, 25), p=st.integers(1, 50),
       method=st.sampled_from(PALLAS_AGGS))
def test_batched_grid_agreement_property(b, m, p, method):
    """The batched grid path agrees with the reference for any batch."""
    v = jax.random.normal(jax.random.PRNGKey(b * 131 + m * 7 + p),
                          (b, m, p)) * 2.0
    scale = _scale_for(method, (b, p))
    ref = aggregate_batched(v, method, scale=scale, backend="reference")
    pal = aggregate_batched(v, method, scale=scale, backend="pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               atol=1e-4, rtol=1e-3)


@_settings
@given(m=st.integers(3, 40), shift=st.floats(-50.0, 50.0),
       scale=st.floats(0.01, 30.0))
def test_kernel_affine_equivariance(m, shift, scale):
    """dcq_mad(a*x + b) = a*dcq_mad(x) + b for a > 0 (kernel path)."""
    v = jax.random.normal(jax.random.PRNGKey(m * 13), (m, 24))
    base = aggregate(v, "dcq_mad", backend="pallas")
    trans = aggregate(scale * v + shift, "dcq_mad", backend="pallas")
    np.testing.assert_allclose(
        np.asarray(trans), np.asarray(scale * base + shift),
        atol=5e-3 * max(1.0, scale, abs(shift)), rtol=1e-3)


@_settings
@given(m=st.integers(5, 60), beta=st.floats(0.05, 0.4))
def test_trimmed_kernel_tie_robustness(m, beta):
    """The sort-free trimmed mean (masked sums + tie correction) matches
    the sorted reference even with heavy duplication in the data."""
    key = jax.random.PRNGKey(m)
    v = jnp.round(jax.random.normal(key, (m, 12)) * 2.0)   # many exact ties
    if 2 * int(beta * m) >= m:
        return
    ref = aggregate(v, "trimmed", trim_beta=beta, backend="reference")
    pal = aggregate(v, "trimmed", trim_beta=beta, backend="pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
