"""The PR4/PR5-era shims warn — exactly once — and name their registry
replacement; ``import repro.core`` itself stays warning-free (the legacy
names resolve lazily, PEP 562)."""
import importlib
import sys
import warnings

import pytest

# shim module -> the replacement its warning must name
SHIMS = {
    "repro.core.robust_agg": "repro.agg",
    "repro.core.dcq": "repro.agg",
    "repro.core.byzantine": "repro.attacks",
    "repro.kernels.dcq": "repro.agg",
    "repro.kernels.dcq_ref": "repro.agg",
}


def _deprecations(records):
    return [r for r in records
            if issubclass(r.category, DeprecationWarning)]


@pytest.mark.parametrize("mod,replacement", sorted(SHIMS.items()))
def test_shim_warns_once_naming_replacement(mod, replacement):
    sys.modules.pop(mod, None)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        importlib.import_module(mod)
    dep = _deprecations(w)
    assert len(dep) == 1, f"{mod}: expected exactly one warning, got " \
        f"{[str(x.message) for x in dep]}"
    msg = str(dep[0].message)
    assert "deprecated" in msg and replacement in msg
    # the cached re-import is silent: the warning fires once per process
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        importlib.import_module(mod)
    assert not _deprecations(w2)


def test_import_repro_core_is_warning_free():
    """The package import must not load the shims as a side effect."""
    sys.modules.pop("repro.core", None)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        importlib.import_module("repro.core")
    assert not _deprecations(w)


def test_legacy_names_still_resolve_through_repro_core():
    """Pinned call sites (`repro.core.aggregate`, `repro.core.byzantine`)
    keep working — through the lazy shim path."""
    import repro.core
    from repro.core.robust_agg import aggregate as direct
    assert repro.core.aggregate is direct
    assert hasattr(repro.core.byzantine, "byzantine_mask")
    with pytest.raises(AttributeError):
        repro.core.not_a_name
