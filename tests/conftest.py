import os

# Tests see the single real CPU device; ONLY launch/dryrun.py forces 512
# placeholder devices (and does so in a subprocess / before jax init).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
