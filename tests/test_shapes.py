"""input_specs / sharding-rule unit tests (no compilation, no devices)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.shapes import adapt_config, input_specs
from repro.models.sharding import cache_spec, data_spec, param_spec


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_exist_for_all_pairs(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    assert "tokens" in specs
    if shape.kind == "train":
        assert "labels" in specs
        if cfg.family == "vlm":
            assert specs["patch_embeds"].shape[1] == cfg.n_patches
            # text tokens + patches = assigned seq_len
            assert (specs["tokens"].shape[1] + cfg.n_patches
                    == shape.seq_len)
        elif cfg.family == "audio":
            assert specs["tokens"].shape == (shape.global_batch,
                                             shape.seq_len,
                                             cfg.n_codebooks)
        else:
            assert specs["tokens"].shape == (shape.global_batch,
                                             shape.seq_len)
    else:
        if shape.kind == "decode":
            assert "cache" in specs
            assert specs["tokens"].shape[1] == 1


def test_long_500k_gets_sliding_window_for_attention_archs():
    shape = SHAPES["long_500k"]
    for arch in ["glm4-9b", "mistral-large-123b", "qwen3-moe-30b-a3b"]:
        cfg = adapt_config(get_config(arch), shape)
        assert cfg.sliding_window == 4096
    # ssm/hybrid stay native
    assert adapt_config(get_config("xlstm-125m"), shape).sliding_window == 0


def test_long_500k_cache_is_bounded():
    """The 500k decode cache must NOT scale with seq_len for any arch."""
    shape = SHAPES["long_500k"]
    for arch in ARCHS:
        cfg = adapt_config(get_config(arch), shape)
        specs = input_specs(cfg, shape)
        leaves = jax.tree_util.tree_leaves(specs["cache"])
        total = sum(int(jnp.prod(jnp.array(leaf.shape))) * leaf.dtype.itemsize
                    for leaf in leaves)
        # < 40 GiB global (i.e. window- or state-bounded, not 500k-bounded)
        assert total < 40 * 2**30, (arch, total)


class _FakeMesh:
    """Minimal mesh stand-in: .shape mapping axis->size."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = _FakeMesh({"data": 16, "model": 16})
MESH_MP = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_param_spec_rules():
    assert param_spec(("layers", "attn", "w_q"), (88, 4096, 4096),
                      MESH) == P(None, None, "model")
    assert param_spec(("layers", "attn", "w_o"), (88, 4096, 4096),
                      MESH) == P(None, "model", None)
    assert param_spec(("layers", "moe", "w_gate"), (48, 128, 2048, 768),
                      MESH) == P(None, "model", None, None)
    assert param_spec(("norm_f",), (4096,), MESH) == P(None)
    assert param_spec(("embed",), (151552, 4096), MESH) == P("model", None)


def test_param_spec_divisibility_fallback():
    # 24 heads * 64 dh = 1536 divisible; but a 23-dim axis is not
    assert param_spec(("layers", "attn", "w_q"), (2, 64, 23),
                      MESH) == P(None, None, None)


def test_param_spec_fsdp_adds_data_axis():
    s = param_spec(("layers", "attn", "w_q"), (88, 4096, 4096), MESH,
                   fsdp=True)
    assert s == P(None, "data", "model")


def test_data_spec_batch_rules():
    assert data_spec((256, 4096), MESH) == P("data", None)
    assert data_spec((256, 4096), MESH_MP) == P(("pod", "data"), None)
    # batch=1 not divisible -> replicated
    assert data_spec((1, 524288), MESH) == P(None, None)
    # batch=32 divisible by pod*data=32
    assert data_spec((32, 128), MESH_MP) == P(("pod", "data"), None)


def test_cache_spec_rules():
    # (L, B, S, Hkv, dh): kv=8 not div by 16 -> dh=128 sharded
    assert cache_spec(("attn", "k"), (88, 128, 32768, 8, 128), MESH) \
        == P(None, "data", None, None, "model")
    # kv=32 divisible -> heads sharded
    assert cache_spec(("attn", "k"), (13, 128, 32768, 32, 112), MESH) \
        == P(None, "data", None, "model", None)
    # ssm state (L, B, H, N, dh): H on model
    assert cache_spec(("ssm", "state"), (81, 128, 112, 64, 64), MESH) \
        == P(None, "data", "model", None, None)
