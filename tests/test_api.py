"""The ``repro.api`` stability contract.

``repro.api`` is the repository's public surface: the snapshot below is
the promise. Extending it is fine (add the name HERE too); renaming or
removing anything, or breaking an entry-point signature, fails this
test and therefore CI — that is the point.
"""
import inspect

import jax
import jax.numpy as jnp
import pytest

import repro.api as api

# -- the public-surface snapshot: edit deliberately, never incidentally --
API_SNAPSHOT = {
    # entry points
    "run_protocol", "run_monte_carlo", "run_sweep", "serve",
    # registry views
    "registered_aggregators", "registered_attacks",
    # the types those entry points consume / return
    "ProtocolConfig", "ProtocolResult", "DPQNProtocol",
    "MEstimationProblem", "get_problem",
    "AggregationService", "ServeConfig", "FlushPolicy", "RingBuffer",
}

# every keyword a signature promises; positional order is part of it for
# the leading data arguments.
SIGNATURES = {
    "run_protocol": ["X", "y", "problem", "cfg", "key", "seed"],
    "run_monte_carlo": ["X", "y", "reps", "problem", "cfg", "keys", "seed"],
    "run_sweep": ["scenarios", "fast", "artifact_path"],
    "serve": ["theta", "cfg", "policy", "sharding"],
}


def test_public_surface_snapshot():
    assert set(api.__all__) == API_SNAPSHOT
    missing = [n for n in api.__all__ if not hasattr(api, n)]
    assert not missing, f"__all__ names missing from module: {missing}"


def test_entry_point_signatures_stable():
    for name, params in SIGNATURES.items():
        sig = inspect.signature(getattr(api, name))
        got = [p for p in sig.parameters
               if sig.parameters[p].kind is not inspect.Parameter.VAR_KEYWORD]
        assert got == params, f"{name} signature drifted: {got}"


def test_registry_views():
    aggs = api.registered_aggregators()
    assert {"mean", "median", "trimmed", "geomedian", "dcq",
            "dcq_mad"} <= set(aggs)
    assert {"none", "scale", "signflip"} <= set(api.registered_attacks())


def test_serve_facade_runs():
    svc = api.serve(jnp.zeros(4), method="median", capacity=6)
    svc.submit_many(jax.random.normal(jax.random.PRNGKey(0), (6, 4)))
    assert svc.round_idx == 1
    # cfg and field kwargs are mutually exclusive
    with pytest.raises(ValueError):
        api.serve(jnp.zeros(4), cfg=api.ServeConfig(), method="median")


def test_run_protocol_facade():
    from repro.data.synthetic import make_shards
    X, y = make_shards(jax.random.PRNGKey(0), "logistic", 6, 40, 4)
    res = api.run_protocol(X, y, cfg=api.ProtocolConfig(noiseless=True))
    assert res.theta_qn.shape == (4,)
    arr = api.run_monte_carlo(X, y, reps=2,
                              cfg=api.ProtocolConfig(noiseless=True))
    assert arr.theta_qn.shape == (2, 4)
