"""repro.agg subsystem: registry contracts, Pallas-vs-reference agreement
for EVERY registered aggregator (shape/dtype/m-parity sweep, batched grid
path, fused pass), and dispatch semantics. The hypothesis property suite
lives in tests/test_agg_properties.py (importorskip-gated)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import agg
from repro.agg import (Aggregator, aggregate, aggregate_batched,
                       get_aggregator, median_deviation_variance,
                       median_mad_dcq, ostat_pallas, register, registered)

#: registered aggregators that have a Pallas kernel form
PALLAS_AGGS = tuple(n for n in registered() if agg.has_pallas(n))


def _scale_for(method, shape, seed=7):
    if get_aggregator(method).needs_scale:
        return jnp.abs(jax.random.normal(jax.random.PRNGKey(seed),
                                         shape)) + 0.1
    return None


# ---------------------------------------------------------------- registry

def test_registry_contents():
    names = registered()
    for expected in ("mean", "median", "trimmed", "geomedian", "dcq",
                     "dcq_mad"):
        assert expected in names
    assert get_aggregator("dcq").needs_scale
    assert not get_aggregator("geomedian").coordinatewise
    assert get_aggregator("geomedian").pallas is None
    assert get_aggregator("geomedian").batching == "vmap"
    with pytest.raises(KeyError, match="unknown aggregator"):
        get_aggregator("nope")


def test_register_new_aggregator_is_dispatchable_and_sweepable():
    """Adding an aggregator is one registry entry: immediately usable from
    aggregate() and accepted by the sweep's Scenario validation."""
    register(Aggregator(
        name="_test_midrange",
        reference=lambda values, *, scale=None, K=10, trim_beta=0.2, axis=0:
            0.5 * (values.min(axis=axis) + values.max(axis=axis))))
    try:
        v = jnp.asarray([[1.0, 4.0], [3.0, 0.0], [2.0, 2.0]])
        out = aggregate(v, "_test_midrange")
        np.testing.assert_allclose(np.asarray(out), [2.0, 2.0])
        from repro.sweep import Scenario
        s = Scenario(m=4, n=50, p=3, aggregator="_test_midrange")
        assert s.aggregator == "_test_midrange"
    finally:
        from repro.agg.registry import _REGISTRY
        _REGISTRY.pop("_test_midrange")


def test_scenario_rejects_unregistered_aggregator():
    from repro.sweep import Scenario
    with pytest.raises(ValueError, match="unknown aggregator"):
        Scenario(m=4, n=50, p=3, aggregator="typo")


# ------------------------------------- Pallas vs reference: exhaustive sweep

@pytest.mark.parametrize("method", PALLAS_AGGS)
@pytest.mark.parametrize("m", [5, 8, 16, 33])   # odd/even m-parity included
@pytest.mark.parametrize("p", [16, 100, 513])
def test_pallas_matches_reference_shape_sweep(method, m, p):
    v = jax.random.normal(jax.random.PRNGKey(m * 1000 + p), (m, p)) * 2.5
    scale = _scale_for(method, (p,))
    ref = aggregate(v, method, scale=scale, backend="reference")
    pal = aggregate(v, method, scale=scale, backend="pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               atol=5e-5, rtol=1e-4)


@pytest.mark.parametrize("method", PALLAS_AGGS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_reference_dtypes(method, dtype):
    v = (jax.random.normal(jax.random.PRNGKey(0), (17, 64)) * 3).astype(dtype)
    scale = _scale_for(method, (64,))
    out = aggregate(v, method, scale=scale, backend="pallas")
    ref = aggregate(v.astype(jnp.float32), method, scale=scale,
                    backend="reference")
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=0.05, rtol=0.05)


@pytest.mark.parametrize("method", PALLAS_AGGS)
@pytest.mark.parametrize("batch", [(3,), (2, 4)])
def test_pallas_batched_grid_path(method, batch):
    """Leading batch axes map onto the Pallas grid: one fused launch must
    agree with the reference batched via native axis=-2 reductions."""
    v = jax.random.normal(jax.random.PRNGKey(11), batch + (9, 37)) * 2.0
    scale = _scale_for(method, batch + (37,))
    ref = aggregate_batched(v, method, scale=scale, backend="reference")
    pal = aggregate_batched(v, method, scale=scale, backend="pallas")
    assert pal.shape == batch + (37,)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               atol=5e-5, rtol=1e-4)


def test_batched_matches_per_slice_loop():
    """The batched grid path equals the per-slice (per-scenario) calls it
    replaces."""
    v = jax.random.normal(jax.random.PRNGKey(3), (5, 12, 33))
    pal = aggregate_batched(v, "dcq_mad", backend="pallas")
    for b in range(5):
        one = aggregate(v[b], "dcq_mad", backend="pallas")
        np.testing.assert_allclose(np.asarray(pal[b]), np.asarray(one),
                                   atol=1e-5)


def test_geomedian_batched_vmap_rule():
    v = jax.random.normal(jax.random.PRNGKey(5), (4, 11, 6))
    out = aggregate_batched(v, "geomedian")
    for b in range(4):
        np.testing.assert_allclose(
            np.asarray(out[b]), np.asarray(aggregate(v[b], "geomedian")),
            atol=1e-5)


# ------------------------------------------------------ fused single pass

def test_fused_median_mad_dcq_matches_separate():
    v = jax.random.normal(jax.random.PRNGKey(9), (2, 15, 40)) * 4.0
    for backend in ("reference", "pallas"):
        med, mad, d = median_mad_dcq(v, backend=backend)
        np.testing.assert_allclose(
            np.asarray(med),
            np.asarray(aggregate_batched(v, "median", backend="reference")),
            atol=5e-5)
        np.testing.assert_allclose(
            np.asarray(d),
            np.asarray(aggregate_batched(v, "dcq_mad",
                                         backend="reference")),
            atol=5e-5, rtol=1e-4)
        # raw MAD: median absolute deviation around the median
        ref_mad = jnp.median(
            jnp.abs(v - jnp.median(v, axis=-2, keepdims=True)), axis=-2)
        np.testing.assert_allclose(np.asarray(mad), np.asarray(ref_mad),
                                   atol=5e-5)


def test_median_deviation_variance_matches_inline_formula():
    """The named helper reproduces the untrusted-center plug-in that was
    previously inlined six ways in core/protocol.py."""
    v = jax.random.normal(jax.random.PRNGKey(2), (21, 8))
    n = 400
    expect = jnp.maximum(
        jnp.median((v - jnp.median(v, 0)) ** 2, 0) * n, 1e-12)
    np.testing.assert_array_equal(
        np.asarray(median_deviation_variance(v, n)), np.asarray(expect))


# ------------------------------------------------------- dispatch semantics

def test_aggregate_needs_scale_errors():
    v = jnp.ones((5, 3))
    with pytest.raises(ValueError, match="scale"):
        aggregate(v, "dcq")
    with pytest.raises(ValueError, match="scale"):
        aggregate_batched(v[None], "dcq")


def test_aggregate_axis_argument():
    v = jax.random.normal(jax.random.PRNGKey(4), (3, 101, 2))
    a = aggregate(jnp.moveaxis(v, 1, 0), "median")
    b = aggregate(v, "median", axis=1)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_aggregate_scalar_machine_axis():
    """1-D input (m,) -> scalar, both backends (protocol's s1 median)."""
    v = jnp.asarray([3.0, 1.0, 2.0, 5.0, 4.0])
    for backend in ("reference", "pallas"):
        out = aggregate(v, "median", backend=backend)
        assert out.shape == ()
        np.testing.assert_allclose(float(out), 3.0, atol=1e-5)


def test_trimmed_too_large_raises_both_backends():
    v = jnp.ones((4, 3))
    for backend in ("reference", "pallas"):
        with pytest.raises(ValueError, match="too large"):
            aggregate(v, "trimmed", trim_beta=1.0, backend=backend)


def test_ostat_kth_statistic():
    v = jax.random.normal(jax.random.PRNGKey(8), (2, 19, 24))
    srt = jnp.sort(v, axis=-2)
    for k in (0, 7, 18):
        out = ostat_pallas(v, "kth", kth=k)
        np.testing.assert_allclose(np.asarray(out), np.asarray(srt[:, k]),
                                   atol=5e-5)


def test_deprecation_shims_still_serve_pinned_imports():
    from repro.core.dcq import dcq as dcq_shim
    from repro.core.robust_agg import aggregate as agg_shim
    from repro.kernels.dcq import dcq_pallas as pallas_shim
    from repro.kernels.dcq_ref import dcq_mad_reference as ref_shim
    v = jax.random.normal(jax.random.PRNGKey(1), (9, 16))
    np.testing.assert_allclose(
        np.asarray(agg_shim(v, method="median")),
        np.asarray(jnp.median(v, axis=0)))
    np.testing.assert_allclose(
        np.asarray(dcq_shim(v, jnp.ones((16,)))),
        np.asarray(agg.dcq(v, jnp.ones((16,)))))
    np.testing.assert_allclose(np.asarray(pallas_shim(v, tile=16)),
                               np.asarray(ref_shim(v)), atol=5e-5)
    with pytest.raises(ValueError, match="unknown aggregator"):
        agg_shim(v, method="nope")


def test_byzantine_resistance_kernel():
    """A minority of wild rows must not move the kernel aggregates much."""
    key = jax.random.PRNGKey(1)
    v = jax.random.normal(key, (40, 32)) + 2.0
    v_bad = v.at[:4].multiply(-30.0)
    for method in ("median", "trimmed", "dcq_mad"):
        clean = aggregate(v, method, backend="pallas")
        atk = aggregate(v_bad, method, backend="pallas")
        assert float(jnp.abs(atk - clean).max()) < 0.6, method
    assert float(jnp.abs(v_bad.mean(0) - v.mean(0)).max()) > 1.0
