"""DCQ estimator: correctness, efficiency (ARE ~ 0.955 claim), robustness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dcq import (dcq, d_k, are_dcq, dcq_with_sigma,
                            quantile_levels)
from repro.core.robust_agg import (geometric_median_agg, median_agg,
                                   trimmed_mean_agg)


def test_quantile_levels():
    k = quantile_levels(10)
    np.testing.assert_allclose(np.asarray(k), np.arange(1, 11) / 11, rtol=1e-6)


def test_dk_limit_is_pi_over_3():
    # K -> inf: D_K -> pi/3 (ARE -> 3/pi ~ 0.955). Paper §1.2(2).
    assert abs(d_k(200) - np.pi / 3) < 0.01
    assert abs(are_dcq(200) - 3 / np.pi) < 0.01


def test_dk_k10_close_to_paper_value():
    # at the paper's K=10 the ARE is already ~0.94
    assert 0.92 < are_dcq(10) < 0.96


def test_dcq_unbiased_normal():
    key = jax.random.PRNGKey(0)
    m, p = 4001, 3
    mu = jnp.array([1.0, -2.0, 0.5])
    sd = 2.0
    vals = mu + sd * jax.random.normal(key, (m, p))
    est = dcq(vals, jnp.full((p,), sd), K=10)
    np.testing.assert_allclose(np.asarray(est), np.asarray(mu), atol=0.15)


def test_dcq_variance_reduction_vs_median():
    """Empirical ARE of DCQ should beat the median's 0.637 decisively."""
    key = jax.random.PRNGKey(1)
    reps, m = 400, 501
    vals = jax.random.normal(key, (reps, m))
    scale = jnp.ones((reps,))
    est_dcq = jax.vmap(lambda v, s: dcq(v[:, None], s[None], K=10)[0])(vals, scale)
    est_med = jnp.median(vals, axis=1)
    est_mean = jnp.mean(vals, axis=1)
    var_ratio_dcq = float(jnp.var(est_mean) / jnp.var(est_dcq))
    var_ratio_med = float(jnp.var(est_mean) / jnp.var(est_med))
    assert var_ratio_dcq > 0.85          # ~0.94 expected at K=10
    assert var_ratio_med < 0.75          # ~0.64 expected
    assert var_ratio_dcq > var_ratio_med + 0.1


def test_dcq_with_sigma_matches_dk():
    est, sd = dcq_with_sigma(jnp.zeros((100, 2)) + 1.0, jnp.ones((2,)), K=10)
    expect = np.sqrt(d_k(10)) / np.sqrt(100)
    np.testing.assert_allclose(np.asarray(sd), expect, rtol=1e-5)


def test_dcq_robust_to_byzantine_scaling():
    """10% of machines send -3x values (paper's attack): DCQ barely moves."""
    key = jax.random.PRNGKey(2)
    m = 500
    vals = 5.0 + jax.random.normal(key, (m, 1))
    n_byz = 50
    vals = vals.at[:n_byz].set(-3.0 * vals[:n_byz])
    est = dcq(vals, jnp.ones((1,)), K=10)
    assert abs(float(est[0]) - 5.0) < 0.35
    # mean is destroyed
    assert abs(float(vals.mean()) - 5.0) > 1.5


def test_trimmed_mean_and_geomedian():
    key = jax.random.PRNGKey(3)
    vals = 2.0 + jax.random.normal(key, (200, 4))
    vals = vals.at[:20].set(100.0)
    tm = trimmed_mean_agg(vals, beta=0.3)
    gm = geometric_median_agg(vals)
    md = median_agg(vals)
    for est in (tm, gm, md):
        np.testing.assert_allclose(np.asarray(est), 2.0, atol=0.5)


def test_dcq_axis_argument():
    key = jax.random.PRNGKey(4)
    vals = jax.random.normal(key, (3, 101, 2))
    a = dcq(jnp.moveaxis(vals, 1, 0), jnp.ones((3, 2)), K=5)
    b = dcq(vals, jnp.ones((3, 2)), K=5, axis=1)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)
