"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU) + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.dcq import dcq_pallas
from repro.kernels.dcq_ref import dcq_mad_reference
from repro.kernels.gqa_decode import gqa_decode_pallas
from repro.kernels.gqa_decode_ref import gqa_decode_reference
from repro.kernels import ops


# ------------------------------------------------------------------ DCQ

@pytest.mark.parametrize("m", [5, 9, 16, 33, 64])
@pytest.mark.parametrize("p", [16, 100, 513])
def test_dcq_kernel_shape_sweep(m, p):
    v = jax.random.normal(jax.random.PRNGKey(m * 1000 + p), (m, p)) * 2.5
    out = dcq_pallas(v, tile=128)
    ref = dcq_mad_reference(v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dcq_kernel_dtypes(dtype):
    v = (jax.random.normal(jax.random.PRNGKey(0), (17, 64)) * 3).astype(dtype)
    out = dcq_pallas(v, tile=64)
    ref = dcq_mad_reference(v.astype(jnp.float32))
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=0.05, rtol=0.05)


def test_dcq_kernel_byzantine_resistance():
    """A minority of wild rows must not move the kernel's aggregate much."""
    key = jax.random.PRNGKey(1)
    v = jax.random.normal(key, (40, 32)) + 2.0
    v_bad = v.at[:4].multiply(-30.0)
    clean = dcq_pallas(v, tile=32)
    atk = dcq_pallas(v_bad, tile=32)
    assert float(jnp.abs(atk - clean).max()) < 0.5
    # the mean is destroyed by the same attack
    assert float(jnp.abs(v_bad.mean(0) - v.mean(0)).max()) > 1.0


@settings(max_examples=20, deadline=None)
@given(m=st.integers(3, 40), p=st.integers(1, 70),
       shift=st.floats(-100.0, 100.0), scale=st.floats(0.01, 50.0))
def test_dcq_kernel_affine_property(m, p, shift, scale):
    """DCQ is affine-equivariant: dcq(a*x + b) = a*dcq(x) + b (a > 0)."""
    v = jax.random.normal(jax.random.PRNGKey(m * 97 + p), (m, p))
    base = dcq_pallas(v, tile=64)
    trans = dcq_pallas(scale * v + shift, tile=64)
    np.testing.assert_allclose(np.asarray(trans),
                               np.asarray(scale * base + shift),
                               atol=5e-3 * max(1.0, scale, abs(shift)),
                               rtol=1e-3)


# ----------------------------------------------------------- GQA decode

@pytest.mark.parametrize("B,S,Hq,Hkv,Dh,ts", [
    (2, 128, 8, 2, 64, 32),
    (3, 96, 4, 4, 128, 64),
    (1, 1024, 16, 2, 128, 256),
    (4, 33, 8, 1, 64, 16),      # ragged S vs tile
])
def test_gqa_decode_shape_sweep(B, S, Hq, Hkv, Dh, ts):
    kq, kk, kv, kl = jax.random.split(jax.random.PRNGKey(B * S), 4)
    q = jax.random.normal(kq, (B, Hq, Dh))
    k = jax.random.normal(kk, (B, S, Hkv, Dh))
    v = jax.random.normal(kv, (B, S, Hkv, Dh))
    clen = jax.random.randint(kl, (B,), 1, S + 1)
    out = gqa_decode_pallas(q, k, v, clen, ts=ts)
    ref = gqa_decode_reference(q, k, v, clen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gqa_decode_dtypes(dtype):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(kq, (2, 8, 64)).astype(dtype)
    k = jax.random.normal(kk, (2, 64, 2, 64)).astype(dtype)
    v = jax.random.normal(kv, (2, 64, 2, 64)).astype(dtype)
    clen = jnp.array([64, 30], jnp.int32)
    out = gqa_decode_pallas(q, k, v, clen, ts=32)
    ref = gqa_decode_reference(q.astype(jnp.float32),
                               k.astype(jnp.float32),
                               v.astype(jnp.float32), clen)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=0.05, rtol=0.05)


def test_gqa_decode_matches_model_path():
    """The kernel agrees with the model's flash.decode_attention path."""
    from repro.models import flash
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(9), 3)
    B, S, Hq, Hkv, Dh = 2, 256, 8, 2, 64
    q = jax.random.normal(kq, (B, 1, Hq, Dh))
    k = jax.random.normal(kk, (B, S, Hkv, Dh))
    v = jax.random.normal(kv, (B, S, Hkv, Dh))
    clen = jnp.array([S, S // 2], jnp.int32)
    model_out = flash.decode_attention(q, k, v, clen)[:, 0]
    kern_out = gqa_decode_pallas(q[:, 0], k, v, clen, ts=64)
    np.testing.assert_allclose(np.asarray(kern_out),
                               np.asarray(model_out), atol=2e-5, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(S=st.integers(8, 200), clen0=st.integers(1, 200))
def test_gqa_decode_length_invariance(S, clen0):
    """Entries past cache_len never affect the output."""
    clen = min(clen0, S)
    kq, kk, kv, kg = jax.random.split(jax.random.PRNGKey(S * 31 + clen), 4)
    q = jax.random.normal(kq, (1, 4, 64))
    k = jax.random.normal(kk, (1, S, 2, 64))
    v = jax.random.normal(kv, (1, S, 2, 64))
    garbage = 100.0 * jax.random.normal(kg, (1, S, 2, 64))
    mask = (jnp.arange(S) < clen)[None, :, None, None]
    k2 = jnp.where(mask, k, garbage)
    v2 = jnp.where(mask, v, garbage)
    cl = jnp.array([clen], jnp.int32)
    a = gqa_decode_pallas(q, k, v, cl, ts=32)
    b = gqa_decode_pallas(q, k2, v2, cl, ts=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)


def test_ops_wrappers_dispatch():
    v = jax.random.normal(jax.random.PRNGKey(3), (9, 32))
    np.testing.assert_allclose(
        np.asarray(ops.dcq_aggregate(v)),
        np.asarray(ops.dcq_aggregate(v, prefer="jnp")), atol=5e-5)
