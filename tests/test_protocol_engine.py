"""The compile-once protocol engine: pure core jit-compatibility, no-retrace
behaviour, Monte-Carlo/sequential agreement, ledger reconstruction, and the
untrusted-center privacy-budget regression."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ProtocolConfig
from repro.core import (DPQNProtocol, get_problem, n_transmissions,
                        protocol_rounds, round_budget, transmission_names)
from repro.data.synthetic import make_shards

M, N, P = 12, 300, 5


@pytest.fixture(scope="module")
def shards():
    return make_shards(jax.random.PRNGKey(0), "logistic", M, N, P)


@pytest.fixture(scope="module")
def problem():
    return get_problem("logistic")


def test_protocol_rounds_is_jit_compatible(shards, problem):
    """The pure core wraps directly in jax.jit with static problem/cfg —
    no trace-time float() or Python-side accountant mutation."""
    X, y = shards
    cfg = ProtocolConfig(eps=30.0, delta=0.05)
    f = jax.jit(functools.partial(protocol_rounds, problem=problem, cfg=cfg))
    arrs = f(jax.random.PRNGKey(0), X, y)
    assert arrs.theta_qn.shape == (P,)
    assert arrs.sigmas.shape == (n_transmissions(cfg),)
    # the spend ledger composes back to the configured budget
    assert abs(float(arrs.ledger_eps.sum()) - cfg.eps) < 1e-4
    assert abs(float(arrs.ledger_delta.sum()) - cfg.delta) < 1e-6


def test_second_call_does_not_retrace(shards, problem):
    X, y = shards
    proto = DPQNProtocol(problem, ProtocolConfig(eps=30.0, delta=0.05))
    proto.run(jax.random.PRNGKey(0), X, y)
    assert proto.trace_count == 1
    proto.run(jax.random.PRNGKey(1), X, y)
    assert proto.trace_count == 1          # same shapes: cache hit, no retrace
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    proto.run_monte_carlo(keys, X, y)
    assert proto.trace_count == 2          # the vmapped engine traces once...
    proto.run_monte_carlo(keys, X, y)
    assert proto.trace_count == 2          # ...and only once


def test_jaxpr_stable_across_calls(shards, problem):
    """jax.make_jaxpr gives the identical program for two different keys —
    the trace does not depend on concrete array values."""
    X, y = shards
    cfg = ProtocolConfig(eps=30.0, delta=0.05)
    f = functools.partial(protocol_rounds, problem=problem, cfg=cfg)
    j1 = jax.make_jaxpr(f)(jax.random.PRNGKey(0), X, y)
    j2 = jax.make_jaxpr(f)(jax.random.PRNGKey(1), X, y)
    assert str(j1) == str(j2)


def test_monte_carlo_matches_sequential_noiseless(shards, problem):
    """vmapped replicates agree with per-replicate run() to 1e-5 when no DP
    noise enters (the only per-replicate difference is the PRNG key)."""
    X, y = shards
    cfg = ProtocolConfig(noiseless=True)
    proto = DPQNProtocol(problem, cfg)
    keys = jnp.stack([jax.random.PRNGKey(k) for k in range(3)])
    arrs = proto.run_monte_carlo(keys, X, y)
    for r in range(3):
        res = proto.run(keys[r], X, y)
        for field in ("theta_cq", "theta_os", "theta_qn"):
            np.testing.assert_allclose(
                np.asarray(getattr(arrs, field)[r]),
                np.asarray(getattr(res, field)), atol=1e-5,
                err_msg=f"{field} rep {r}")


def test_monte_carlo_matches_sequential_private(shards, problem):
    """With DP noise the key is consumed identically in both paths, so the
    match is exact-per-key, not just statistical."""
    X, y = shards
    cfg = ProtocolConfig(eps=30.0, delta=0.05)
    proto = DPQNProtocol(problem, cfg)
    keys = jnp.stack([jax.random.PRNGKey(k) for k in range(2)])
    arrs = proto.run_monte_carlo(keys, X, y)
    for r in range(2):
        res = proto.run(keys[r], X, y)
        np.testing.assert_allclose(np.asarray(arrs.theta_qn[r]),
                                   np.asarray(res.theta_qn), atol=1e-5)


def test_accountant_reconstruction_matches_eager(shards, problem):
    """The shell-reconstructed accountant (jit path) matches the one built
    from an eager (jit=False) execution of the same pure core."""
    X, y = shards
    cfg = ProtocolConfig(eps=20.0, delta=0.05)
    res_j = DPQNProtocol(problem, cfg).run(jax.random.PRNGKey(3), X, y)
    res_e = DPQNProtocol(problem, cfg, jit=False).run(
        jax.random.PRNGKey(3), X, y)
    rj, re_ = res_j.accountant.records, res_e.accountant.records
    assert [r.name for r in rj] == [r.name for r in re_] \
        == list(transmission_names(cfg))
    for a, b in zip(rj, re_):
        assert a.eps == b.eps and a.delta == b.delta
        np.testing.assert_allclose(a.sigma, b.sigma, rtol=1e-6)
        np.testing.assert_allclose(a.failure_prob, b.failure_prob, rtol=1e-6)
    assert res_j.noise_sd.keys() == res_e.noise_sd.keys()
    for k in res_j.noise_sd:
        np.testing.assert_allclose(res_j.noise_sd[k], res_e.noise_sd[k],
                                   rtol=1e-6)


def test_untrusted_center_budget_not_overspent(shards, problem):
    """Regression: untrusted mode performs SIX DP transmissions (the extra
    "R2b var" round); the per-round budget must be eps/6, not eps/5, so
    basic composition never exceeds the configured (eps, delta)."""
    X, y = shards
    cfg = ProtocolConfig(eps=30.0, delta=0.05, center_trust="untrusted")
    assert n_transmissions(cfg) == 6
    eps_r, delta_r = round_budget(cfg)
    assert abs(eps_r - 5.0) < 1e-12 and abs(delta_r - 0.05 / 6) < 1e-12
    res = DPQNProtocol(problem, cfg).run(jax.random.PRNGKey(5), X, y)
    eb, db = res.accountant.total_basic()
    assert eb <= cfg.eps + 1e-9
    assert db <= cfg.delta + 1e-9
    # and it spends the WHOLE budget, not less
    assert abs(eb - cfg.eps) < 1e-9
    assert len(res.accountant.records) == 6
    assert res.noise_sd["s6"] > 0


def test_nonstandard_n_rounds_rejected():
    """n_rounds is Algorithm 1's fixed round count, not a free knob: a
    value that desynchronises the budget split from the actual
    transmissions is rejected loudly instead of silently ignored."""
    with pytest.raises(ValueError, match="n_rounds"):
        transmission_names(ProtocolConfig(n_rounds=10))


def test_trusted_center_budget_exact(shards, problem):
    X, y = shards
    cfg = ProtocolConfig(eps=30.0, delta=0.05)
    assert n_transmissions(cfg) == 5
    res = DPQNProtocol(problem, cfg).run(jax.random.PRNGKey(6), X, y)
    eb, db = res.accountant.total_basic()
    assert abs(eb - 30.0) < 1e-9 and abs(db - 0.05) < 1e-9


def test_monte_carlo_ledger_batched(shards, problem):
    """The spend ledger rides through vmap: one row per replicate, all equal
    in eps/delta, enabling whole-sweep accounting without host sync."""
    X, y = shards
    cfg = ProtocolConfig(eps=30.0, delta=0.05)
    keys = jax.random.split(jax.random.PRNGKey(8), 4)
    arrs = DPQNProtocol(problem, cfg).run_monte_carlo(keys, X, y)
    assert arrs.ledger_eps.shape == (4, 5)
    np.testing.assert_allclose(np.asarray(arrs.ledger_eps.sum(-1)), 30.0,
                               rtol=1e-6)
    assert arrs.sigmas.shape == (4, 5)
    # noise calibration is key-independent: identical across replicates
    np.testing.assert_allclose(np.asarray(arrs.sigmas.std(0)), 0.0, atol=1e-7)
