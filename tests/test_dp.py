"""DP layer: mechanism calibration, tail sensitivities, composition."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dp


def test_gaussian_sigma_lemma21():
    s = dp.gaussian_sigma(sensitivity=1.0, eps=1.0, delta=1e-5)
    assert abs(s - math.sqrt(2 * math.log(1.25e5))) < 1e-9


def test_noise_multiplier():
    assert abs(dp.noise_multiplier(2.0, 0.01)
               - math.sqrt(2 * math.log(100)) / 2.0) < 1e-12


def test_subgauss_vs_subexp_sqrt_logn_gap():
    """Remark 4.4: sub-Gaussian buys a sqrt(log n) factor."""
    p, n, g = 10, 4000, 2.0
    ratio = (dp.mean_sensitivity_subexp(p, n, g)
             / dp.mean_sensitivity_subgauss(p, n, g))
    assert abs(ratio - math.sqrt(math.log(n))) < 1e-9
    s_se = dp.s1_theta(p, n, g, 1.0, 0.01, 0.25, "subexp")
    s_sg = dp.s1_theta(p, n, g, 1.0, 0.01, 0.25, "subgauss")
    assert abs(s_se / s_sg - math.sqrt(math.log(n))) < 1e-9


def test_failure_probs_decrease_with_gamma_and_n():
    f1 = dp.mean_dp_failure_prob_subexp(10, 1000, 1.0, 1.0, 1.0)
    f2 = dp.mean_dp_failure_prob_subexp(10, 1000, 3.0, 1.0, 1.0)
    f3 = dp.mean_dp_failure_prob_subexp(10, 100000, 1.0, 1.0, 1.0)
    assert f2 < f1 and f3 < f1


def test_compose_basic():
    e, d = dp.compose_basic([(1.0, 0.01)] * 5)
    assert e == 5.0 and abs(d - 0.05) < 1e-12


def test_compose_advanced_beats_basic_small_eps():
    """Cor 4.1: for small eps the advanced bound is < k*eps."""
    e_adv, d_adv = dp.compose_advanced(0.1, 1e-4, 50, slack=1e-3)
    assert e_adv < 50 * 0.1
    # and never worse than basic
    e2, _ = dp.compose_advanced(5.0, 1e-4, 3, slack=1e-3)
    assert e2 <= 15.0 + 1e-9


def test_accountant_tracks_five_rounds():
    a = dp.PrivacyAccountant()
    for i in range(5):
        a.spend(f"r{i}", 6.0, 0.01, 0.1, failure_prob=1e-4)
    eb, db = a.total_basic()
    assert abs(eb - 30.0) < 1e-9 and abs(db - 0.05) < 1e-9
    ea, da = a.total_advanced()
    assert ea <= 30.0 + 1e-9
    assert abs(a.total_failure_prob() - 5e-4) < 1e-12
    assert "advanced" in a.summary()


def test_add_noise_statistics():
    key = jax.random.PRNGKey(0)
    x = jnp.zeros((200_00,))
    y = dp.add_noise(key, x, 2.0)
    assert abs(float(y.std()) - 2.0) < 0.05


def test_mechanism_achieves_dp_empirically():
    """Crude (eps, delta) audit on a 1-d count query with sensitivity 1:
    P[M(X) in S] <= e^eps P[M(X') in S] + delta for threshold sets."""
    eps, delta = 1.0, 1e-3
    s = dp.gaussian_sigma(1.0, eps, delta)
    key = jax.random.PRNGKey(1)
    n = 200_000
    noise = np.asarray(s * jax.random.normal(key, (n,)))
    a = 0.0 + noise          # M(X)
    b = 1.0 + noise          # M(X')
    ts = np.linspace(-3, 6, 40)
    for t in ts:
        pa = (a >= t).mean()
        pb = (b >= t).mean()
        assert pa <= math.exp(eps) * pb + delta + 0.005
        assert pb <= math.exp(eps) * pa + delta + 0.005


def test_variance_sensitivity_thm46():
    assert dp.variance_sensitivity(1000, 1.0) == (4 * math.log(1000) + 1) / 1000
    with pytest.raises(ValueError):
        dp.variance_sensitivity(1000, 0.5)
    s6 = dp.s6_variance(10, 1000, 1.0, 1.0, 0.05)
    assert s6 > 0
