"""Tests for the repro.analyze static analyzer.

Each rule is exercised against a seeded-violation fixture (must fire) and
a clean twin (must stay silent); suppression syntax round-trips; the JSON
report matches the documented schema; and the shipped tree self-checks
clean so CI can gate on ``python -m repro.analyze``.
"""
from __future__ import annotations

import subprocess
import sys
from pathlib import Path

import jax
import pytest

from repro.analyze import Finding, Rule, analyze_paths, get_rule, register, registered, unregister
from repro.analyze.cli import main as cli_main
from repro.analyze.engine import SCHEMA
from repro.analyze.suppress import parse as parse_suppressions
from repro.core.keys import STREAMS, stream_key

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "analyze"

RULE_FIXTURES = [
    ("key-reuse", "key_reuse_bad.py", "key_reuse_ok.py"),
    ("wire-boundary", "wire_boundary_bad.py", "wire_boundary_ok.py"),
    ("ledger-pairing", "ledger_pairing_bad.py", "ledger_pairing_ok.py"),
    ("jit-purity", "jit_purity_bad.py", "jit_purity_ok.py"),
    ("pallas-static", "pallas_static_bad.py", "pallas_static_ok.py"),
    ("retrace-hazard", "retrace_hazard_bad.py", "retrace_hazard_ok.py"),
]


def run_rule(rule: str, fixture: str):
    return analyze_paths(
        [str(FIXTURES / fixture)], rules=[rule], include_fixtures=True
    )


# ---------------------------------------------------------------------------
# per-rule fixtures: seeded violations caught, clean twins silent
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rule,bad,ok", RULE_FIXTURES)
def test_rule_catches_seeded_violation(rule, bad, ok):
    report = run_rule(rule, bad)
    assert report.findings, f"{rule} missed every violation in {bad}"
    assert all(f.rule == rule for f in report.findings)
    assert report.exit_code == 1


@pytest.mark.parametrize("rule,bad,ok", RULE_FIXTURES)
def test_rule_silent_on_clean_twin(rule, bad, ok):
    report = run_rule(rule, ok)
    assert report.findings == [], (
        f"{rule} false-positives on {ok}: "
        f"{[(f.line, f.message) for f in report.findings]}"
    )
    assert report.exit_code == 0


def test_key_reuse_flags_arithmetic_seed():
    report = run_rule("key-reuse", "key_reuse_bad.py")
    assert any("arithmetic seed" in f.message for f in report.findings)


def test_jit_purity_flags_each_sync_kind():
    messages = " | ".join(
        f.message for f in run_rule("jit-purity", "jit_purity_bad.py").findings
    )
    for marker in (".item()", "numpy", "float(", "branch on a traced value"):
        assert marker in messages, f"jit-purity missed {marker!r}"


def test_pallas_static_flags_grid_and_interpret():
    messages = " | ".join(
        f.message
        for f in run_rule("pallas-static", "pallas_static_bad.py").findings
    )
    assert "grid" in messages
    assert "interpret=True" in messages


def test_retrace_hazard_flags_each_hazard_class():
    messages = " | ".join(
        f.message
        for f in run_rule("retrace-hazard", "retrace_hazard_bad.py").findings
    )
    for marker in ("float(...)", "float-valued expression", "unhashable list"):
        assert marker in messages, f"retrace-hazard missed {marker!r}"


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------
def test_suppression_with_reason_silences_finding():
    report = run_rule("key-reuse", "suppressed.py")
    suppressed = [f for f in report.suppressed if f.rule == "key-reuse"]
    assert len(suppressed) == 1
    assert "parity" in suppressed[0].reason


def test_bare_suppression_is_itself_a_finding():
    report = run_rule("key-reuse", "suppressed.py")
    sup = [f for f in report.findings if f.rule == "suppression"]
    assert len(sup) == 2  # missing reason + unknown rule
    assert any("reason" in f.message for f in sup)
    assert any("unknown rule" in f.message for f in sup)
    # the reuse under the bare marker stays an active finding
    assert any(f.rule == "key-reuse" for f in report.findings)


def test_suppression_parse_round_trip():
    src = (
        "x = 1\n"
        "# repro: allow(key-reuse) — deliberate, see EXPERIMENTS.md.\n"
        "y = 2\n"
        '"""not a comment: # repro: allow(jit-purity) — docstring."""\n'
        "# repro: allow-file(wire-boundary) — whole-file waiver.\n"
    )
    sups = parse_suppressions(src)
    assert len(sups) == 2  # the docstring mention must NOT parse
    by_kind = {s.kind: s for s in sups}
    assert by_kind["allow"].rules == ("key-reuse",)
    assert by_kind["allow"].reason == "deliberate, see EXPERIMENTS.md."
    assert by_kind["allow-file"].rules == ("wire-boundary",)


def test_unused_suppression_flags_stale_waivers():
    # full rule set: the waived rules run, find nothing, so both the
    # inline allow and the file-wide allow-file are stale
    report = analyze_paths(
        [str(FIXTURES / "unused_suppression_bad.py")], include_fixtures=True
    )
    stale = [f for f in report.findings if f.rule == "unused-suppression"]
    assert len(stale) == 2
    assert {f.line for f in stale} == {5, 10}
    assert all("stale waiver" in f.message for f in stale)
    assert report.exit_code == 1


def test_unused_suppression_silent_on_earned_and_self_waived():
    report = analyze_paths(
        [str(FIXTURES / "unused_suppression_ok.py")], include_fixtures=True
    )
    assert report.findings == [], [
        (f.rule, f.line, f.message) for f in report.findings
    ]
    # the earned waiver silenced a real finding; the prophylactic one
    # self-waived via allow(<rule>, unused-suppression)
    assert any(f.rule == "key-reuse" for f in report.suppressed)
    assert any(f.rule == "unused-suppression" for f in report.suppressed)


def test_unused_suppression_respects_rule_subset():
    # key-reuse did not run, so its waiver cannot be judged stale; the
    # wire-boundary allow-file still can (its rule ran and found nothing)
    report = analyze_paths(
        [str(FIXTURES / "unused_suppression_bad.py")],
        rules=["wire-boundary", "unused-suppression"],
        include_fixtures=True,
    )
    assert [f.rule for f in report.findings] == ["unused-suppression"]
    assert report.findings[0].line == 5
    # and without unused-suppression in the set, nothing fires at all
    report = analyze_paths(
        [str(FIXTURES / "unused_suppression_bad.py")],
        rules=["key-reuse", "wire-boundary"],
        include_fixtures=True,
    )
    assert report.findings == []


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_round_trip():
    assert set(registered()) >= {
        "key-reuse",
        "wire-boundary",
        "ledger-pairing",
        "jit-purity",
        "pallas-static",
        "retrace-hazard",
    }
    rule = Rule(
        name="test-noop",
        check=lambda mod, graph: [],
        doc="noop rule for the registry test",
    )
    register(rule)
    try:
        assert get_rule("test-noop") is rule
        with pytest.raises(ValueError):
            register(rule)
    finally:
        unregister("test-noop")
    with pytest.raises(KeyError):
        get_rule("test-noop")


# ---------------------------------------------------------------------------
# JSON schema + CLI
# ---------------------------------------------------------------------------
def test_json_report_schema():
    report = run_rule("key-reuse", "key_reuse_bad.py")
    payload = report.to_json()
    assert payload["schema"] == SCHEMA == "repro.analyze/v1"
    assert set(payload) == {
        "schema",
        "roots",
        "files",
        "rules",
        "findings",
        "suppressed",
        "counts",
    }
    assert payload["counts"]["findings"] == len(payload["findings"]) > 0
    assert payload["counts"]["per_rule"]["key-reuse"] == len(payload["findings"])
    finding = payload["findings"][0]
    assert set(finding) >= {"rule", "path", "line", "col", "message"}
    assert isinstance(finding["line"], int)


def test_finding_to_dict_includes_reason_when_suppressed():
    f = Finding(
        rule="key-reuse",
        path="x.py",
        line=1,
        col=0,
        message="m",
        suppressed=True,
        reason="why",
    )
    d = f.to_dict()
    assert d["reason"] == "why"


def test_cli_exit_codes(tmp_path, capsys):
    bad = FIXTURES / "key_reuse_bad.py"
    out = tmp_path / "report.json"
    rc = cli_main(
        [str(bad), "--rules", "key-reuse", "--include-fixtures",
         "--json", str(out), "--quiet"]
    )
    assert rc == 1
    assert out.exists()
    rc = cli_main(
        [str(FIXTURES / "key_reuse_ok.py"), "--rules", "key-reuse",
         "--include-fixtures", "--quiet"]
    )
    assert rc == 0


def test_cli_list_rules(capsys):
    rc = cli_main(["--list-rules"])
    assert rc == 0
    out = capsys.readouterr().out
    for name in ("key-reuse", "pallas-static"):
        assert name in out


# ---------------------------------------------------------------------------
# self-check: the shipped tree is clean (this is what CI gates on)
# ---------------------------------------------------------------------------
def test_shipped_tree_is_clean():
    report = analyze_paths(
        [str(REPO / "src"), str(REPO / "benchmarks"), str(REPO / "examples")]
    )
    assert report.findings == [], (
        "analyzer must be clean on the shipped tree:\n"
        + "\n".join(
            f"{f.path}:{f.line} [{f.rule}] {f.message}"
            for f in report.findings
        )
    )
    assert report.exit_code == 0
    # every suppression in the tree carries a reason
    assert all(f.reason for f in report.suppressed)


def test_module_entry_point_runs():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analyze", str(REPO / "src" / "repro" / "analyze"), "--quiet"],
        capture_output=True,
        text=True,
        cwd=str(REPO),
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ---------------------------------------------------------------------------
# satellite: fold_in stream helper + historical executor key parity
# ---------------------------------------------------------------------------
def test_stream_keys_are_pairwise_distinct():
    keys = [stream_key(0, s) for s in STREAMS]
    datas = {bytes(jax.random.key_data(k).tobytes()) for k in keys}
    assert len(datas) == len(STREAMS)


def test_stream_key_index_derivation():
    base = stream_key(3, "serve")
    k0 = stream_key(3, "serve", index=0)
    k1 = stream_key(3, "serve", index=1)
    assert (
        jax.random.key_data(k0).tobytes()
        != jax.random.key_data(k1).tobytes()
        != jax.random.key_data(base).tobytes()
    )


def test_stream_key_unknown_stream():
    with pytest.raises(ValueError):
        stream_key(0, "nope")


def test_historical_executor_keys_unchanged():
    # The sweep executor's PRNGKey(1000 + seed) / PRNGKey(seed + 1) lines are
    # pinned behind suppressions: recorded sweeps must replay byte-identically,
    # so the raw threefry key words are asserted here.
    import numpy as np

    for seed in (0, 7):
        run_key = np.asarray(jax.random.key_data(jax.random.PRNGKey(1000 + seed)))
        data_key = np.asarray(jax.random.key_data(jax.random.PRNGKey(seed + 1)))
        assert run_key.tolist() == [0, 1000 + seed]
        assert data_key.tolist() == [0, seed + 1]
        # the new stream helper must NOT collide with the pinned lines
        folded = np.asarray(jax.random.key_data(stream_key(seed, "protocol")))
        assert folded.tolist() not in (run_key.tolist(), data_key.tolist())
