"""Newton (full Hessian) and GD baselines vs the quasi-Newton protocol."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ProtocolConfig
from repro.core import DPQNProtocol, get_problem
from repro.core.baselines import gd_estimator, newton_estimator
from repro.data.synthetic import make_shards, target_theta

M, N, P = 40, 1000, 8


@pytest.fixture(scope="module")
def shards():
    return make_shards(jax.random.PRNGKey(0), "logistic", M, N, P)


def _err(v):
    return float(jnp.linalg.norm(v - target_theta(P)))


def test_newton_baseline_noiseless_works(shards):
    X, y = shards
    cfg = ProtocolConfig(noiseless=True)
    res = newton_estimator(get_problem("logistic"), cfg,
                           jax.random.PRNGKey(1), X, y)
    assert _err(res.theta) < 0.2
    assert res.bytes_per_machine == 4 * (P + P + P * P)


def test_newton_baseline_suffers_more_under_dp(shards):
    """The paper's budget argument: at equal total eps, the p^2-dim Hessian
    transmission forces much larger noise, so Newton ends up worse than the
    5-vector quasi-Newton protocol."""
    X, y = shards
    cfg = ProtocolConfig(eps=30.0, delta=0.05)
    prob = get_problem("logistic")
    err_newton = sum(_err(newton_estimator(
        prob, cfg, jax.random.PRNGKey(k), X, y).theta) for k in range(3)) / 3
    err_qn = sum(_err(DPQNProtocol(prob, cfg).run(
        jax.random.PRNGKey(k), X, y).theta_qn) for k in range(3)) / 3
    assert err_qn < err_newton


def test_gd_baseline_runs_and_budget_grows_linearly(shards):
    X, y = shards
    cfg = ProtocolConfig(eps=30.0, delta=0.05, noiseless=True)
    res = gd_estimator(get_problem("logistic"), cfg, jax.random.PRNGKey(2),
                       X, y, rounds=25, lr=2.0)
    assert _err(res.theta) < 0.3
    eb, db = res.accountant.total_basic()
    assert abs(eb - 30.0) < 1e-6
    assert res.bytes_per_machine == 4 * P * 25


def test_comm_cost_ordering():
    """5 vectors (qN) < T vectors (GD, T>5) << p^2 (Newton)."""
    p = 100
    qn_bytes = 4 * 5 * p
    gd_bytes = 4 * 20 * p
    newton_bytes = 4 * (2 * p + p * p)
    assert qn_bytes < gd_bytes < newton_bytes
