"""Model-layer numerics: chunked implementations vs oracles, cache
consistency, MoE routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import flash, moe, ssm, xlstm
from repro.models.model import Model


# ------------------------------------------------------------- flash

@pytest.mark.parametrize("S,T,Hq,Hkv,qc,kc", [
    (37, 37, 8, 2, 16, 8),
    (64, 64, 4, 4, 64, 64),
    (17, 17, 6, 3, 5, 7),
])
def test_flash_matches_reference(S, T, Hq, Hkv, qc, kc):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(S * T), 3)
    q = jax.random.normal(k1, (2, S, Hq, 16))
    k = jax.random.normal(k2, (2, T, Hkv, 16))
    v = jax.random.normal(k3, (2, T, Hkv, 16))
    out = flash.flash_attention(q, k, v, causal=True, q_chunk=qc,
                                kv_chunk=kc)
    ref = flash.attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_sliding_window():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (1, 50, 4, 16))
    k = jax.random.normal(k2, (1, 50, 2, 16))
    v = jax.random.normal(k3, (1, 50, 2, 16))
    out = flash.flash_attention(q, k, v, causal=True, window=11,
                                q_chunk=16, kv_chunk=8)
    ref = flash.attention_reference(q, k, v, causal=True, window=11)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# --------------------------------------------------------------- SSD

def test_ssd_chunked_equals_recurrence():
    cfg = get_config("zamba2-7b", reduced=True)
    p = ssm.ssm_init(jax.random.PRNGKey(3), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(4), (2, 67, cfg.d_model))
    y1 = ssm.ssm_forward(p, x, cfg)
    y2 = ssm.ssm_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


# ------------------------------------------------------------- xLSTM

def test_mlstm_chunked_equals_recurrence():
    cfg = get_config("xlstm-125m", reduced=True)
    pm = xlstm.mlstm_init(jax.random.PRNGKey(5), cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(6), (2, 50, cfg.d_model))
    y1 = xlstm.mlstm_forward(pm, x, cfg, chunk=16)
    cache = xlstm.mlstm_cache_init(cfg, 2)
    outs = []
    for t in range(50):
        o, cache = xlstm.mlstm_decode(pm, x[:, t:t + 1], cache, cfg)
        outs.append(o)
    y2 = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


# ------------------------------------------- prefill/decode consistency

@pytest.mark.parametrize("arch", ["glm4-9b", "zamba2-7b", "xlstm-125m",
                                  "musicgen-medium"])
def test_prefill_equals_decode(arch):
    cfg = get_config(arch, reduced=True)
    m = Model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    if cfg.family == "audio":
        toks = jax.random.randint(jax.random.PRNGKey(1),
                                  (B, S, cfg.n_codebooks), 0, cfg.vocab)
    else:
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  cfg.vocab)
    logits_full, _ = m.forward(p, {"tokens": toks})
    cache = m.init_cache(B, S)
    step = jax.jit(m.decode_step)
    logs = []
    for t in range(S):
        tok = toks[:, t:t + 1]
        lg, cache = step(p, cache, {"tokens": tok})
        logs.append(lg)
    logits_dec = jnp.concatenate(logs, 1)
    np.testing.assert_allclose(np.asarray(logits_full, np.float32),
                               np.asarray(logits_dec, np.float32),
                               atol=5e-4, rtol=1e-3)


def test_sliding_window_decode_matches_windowed_prefill():
    cfg = get_config("glm4-9b", reduced=True).with_sliding_window(8)
    m = Model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 1, 20
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    logits_full, _ = m.forward(p, {"tokens": toks})
    cache = m.init_cache(B, S)     # ring buffer of size 8
    step = jax.jit(m.decode_step)
    logs = []
    for t in range(S):
        lg, cache = step(p, cache, {"tokens": toks[:, t:t + 1]})
        logs.append(lg)
    logits_dec = jnp.concatenate(logs, 1)
    np.testing.assert_allclose(np.asarray(logits_full),
                               np.asarray(logits_dec), atol=5e-4, rtol=1e-3)


# --------------------------------------------------------------- MoE

def test_moe_router_load_and_gates():
    cfg = get_config("qwen3-moe-30b-a3b", reduced=True)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, stats = moe.moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert jnp.isfinite(stats["aux_loss"])
    np.testing.assert_allclose(float(stats["load_frac"].sum()), 1.0,
                               atol=1e-5)
    assert float(stats["dropped_frac"]) < 0.5


def test_moe_capacity_overflow_drops_not_corrupts():
    """With capacity_factor tiny, output stays finite and bounded."""
    import dataclasses
    cfg = get_config("qwen3-moe-30b-a3b", reduced=True)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    y, stats = moe.moe_ffn(p, x, cfg)
    assert jnp.isfinite(y).all()
    assert float(stats["dropped_frac"]) > 0.0


def test_vlm_patch_positions_not_scored():
    cfg = get_config("llava-next-mistral-7b", reduced=True)
    m = Model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                     cfg.vocab),
        "patch_embeds": jax.random.normal(jax.random.PRNGKey(3),
                                          (B, cfg.n_patches, 1024)),
    }
    loss, _ = m.loss(p, batch)
    assert jnp.isfinite(loss)
    logits, _ = m.forward(p, batch)
    assert logits.shape[1] == S + cfg.n_patches
