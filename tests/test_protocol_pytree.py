"""Pytree-native protocol core: byte parity of the refactored flat path
against the pre-refactor golden fixture, single-leaf transport parity,
tree L-BFGS vs flat two-loop, per-leaf DP calibration (the grad_agg
global-sigma bugfix), compile-once on the zoo training path, and the
rewritten robust-training example."""
import importlib.util
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attacks import apply_attack
from repro.configs.base import TreeProtocolConfig
from repro.core import bfgs, dp
from repro.core.protocol import protocol_tree_rounds
from repro.core.transport import (tree_dot, tree_leaf_dims, tree_size,
                                  wire_aggregate, wire_corrupt, wire_noise)
from repro.dist.grad_agg import (GradAggConfig, add_dp_noise,
                                 calibrate_leaf_sigmas)
from repro.sweep import SweepExecutor, TrainScenario, build_preset

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "smoke_golden.json")


# ------------------------------------------------- transport layer parity

def test_wire_noise_single_leaf_byte_parity():
    """A single-leaf pytree must consume the transmission key UNSPLIT so
    flat arrays and {'theta': flat} draw identical noise."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 5))
    flat = wire_noise(key, x, 0.3)
    tree = wire_noise(key, {"theta": x}, 0.3)
    assert np.array_equal(np.asarray(flat), np.asarray(tree["theta"]))
    # multi-leaf trees split once per leaf -> leaves get DIFFERENT draws
    two = wire_noise(key, {"a": x, "b": x}, 0.3)
    assert not np.array_equal(np.asarray(two["a"]), np.asarray(two["b"]))


def test_wire_corrupt_single_leaf_byte_parity():
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 5))
    mask = jnp.arange(6) < 2
    flat = wire_corrupt(key, x, mask, attack="signflip", factor=-3.0,
                        round_idx=1)
    tree = wire_corrupt(key, {"theta": x}, mask, attack="signflip",
                        factor=-3.0, round_idx=1)
    assert np.array_equal(np.asarray(flat), np.asarray(tree["theta"]))
    # matches the registry applied directly
    direct = apply_attack(x, mask, attack="signflip", factor=-3.0,
                          key=key, round_idx=1)
    assert np.array_equal(np.asarray(flat), np.asarray(direct))


def test_wire_aggregate_single_leaf_byte_parity():
    x = jax.random.normal(jax.random.PRNGKey(2), (9, 5))
    for method in ("mean", "median", "dcq_mad", "trimmed"):
        flat = wire_aggregate(x, method=method)
        tree = wire_aggregate({"theta": x}, method=method)
        assert np.array_equal(np.asarray(flat),
                              np.asarray(tree["theta"])), method


def test_wire_aggregate_multi_leaf_shapes_and_dtype():
    vals = {"w": jax.random.normal(jax.random.PRNGKey(4), (7, 3, 4)),
            "b": jax.random.normal(jax.random.PRNGKey(5), (7, 2))}
    agg = wire_aggregate(vals, method="median")
    assert agg["w"].shape == (3, 4) and agg["b"].shape == (2,)
    assert agg["w"].dtype == vals["w"].dtype
    # per-leaf dispatch matches aggregating each leaf alone
    for name in vals:
        alone = wire_aggregate(vals[name], method="median")
        assert np.array_equal(np.asarray(agg[name]), np.asarray(alone))


def test_tree_size_and_dims():
    tree = {"w": jnp.zeros((4, 10, 3)), "b": jnp.zeros((4, 2))}
    dims = tree_leaf_dims(tree, machine_axis=True)
    assert dims == {"w": 30, "b": 2}
    assert tree_size({"w": jnp.zeros((10, 3)), "b": jnp.zeros((2,))}) == 32


# ----------------------------------------------------- L-BFGS tree parity

def test_lbfgs_two_loop_tree_matches_flat():
    p, hist = 6, 4
    key = jax.random.PRNGKey(11)
    mem_flat = bfgs.LBFGSMemory.init(hist, p)
    mem_tree = bfgs.LBFGSMemory.init_like(hist, {"theta": jnp.zeros(p)})
    for i in range(3):
        s = jax.random.normal(jax.random.fold_in(key, 2 * i), (p,))
        y = s + 0.1 * jax.random.normal(jax.random.fold_in(key, 2 * i + 1),
                                        (p,))
        mem_flat = mem_flat.push(s, y)
        mem_tree = mem_tree.push({"theta": s}, {"theta": y})
    g = jax.random.normal(jax.random.fold_in(key, 99), (p,))
    d_flat = bfgs.lbfgs_two_loop(mem_flat, g, gamma=0.7)
    d_tree = bfgs.lbfgs_two_loop_tree(mem_tree, {"theta": g}, gamma=0.7)
    assert np.array_equal(np.asarray(d_flat), np.asarray(d_tree["theta"]))
    # splitting the vector over two leaves preserves the direction (the
    # two-loop only consumes inner products, which sum over leaves)
    mem2 = bfgs.LBFGSMemory.init_like(
        hist, {"a": jnp.zeros(4), "b": jnp.zeros(2)})
    mem_flat2 = bfgs.LBFGSMemory.init(hist, p)
    for i in range(3):
        s = jax.random.normal(jax.random.fold_in(key, 2 * i), (p,))
        y = s + 0.1 * jax.random.normal(jax.random.fold_in(key, 2 * i + 1),
                                        (p,))
        mem2 = mem2.push({"a": s[:4], "b": s[4:]},
                         {"a": y[:4], "b": y[4:]})
        mem_flat2 = mem_flat2.push(s, y)
    d2 = bfgs.lbfgs_two_loop_tree(
        mem2, {"a": g[:4], "b": g[4:]}, gamma=0.7)
    np.testing.assert_allclose(
        np.concatenate([d2["a"], d2["b"]]),
        np.asarray(bfgs.lbfgs_two_loop(mem_flat2, g, gamma=0.7)),
        rtol=1e-5, atol=1e-6)


# -------------------------------- per-leaf DP calibration (grad_agg fix)

def test_per_leaf_sigmas_scale_with_leaf_dimension():
    """REGRESSION (the historical grad_agg bug): two leaves with
    different dimensions must get different noise scales — the 16-d bias
    must NOT be noised like the 4096-d matrix."""
    g = {"w": jnp.zeros((4, 2000)), "b": jnp.zeros((4, 50))}
    cfg = GradAggConfig(dp_eps=1.0, dp_n=100)
    sig = calibrate_leaf_sigmas(g, cfg)
    assert sig["w"] != sig["b"]
    np.testing.assert_allclose(sig["w"] / sig["b"],
                               np.sqrt(2000 / 50), rtol=1e-6)
    # and the noise actually drawn matches each leaf's own sigma
    noised = add_dp_noise(g, sig, jax.random.PRNGKey(0))
    std_w = float(jnp.std(noised["w"]))
    std_b = float(jnp.std(noised["b"]))
    np.testing.assert_allclose(std_w, sig["w"], rtol=0.1)
    np.testing.assert_allclose(std_b, sig["b"], rtol=0.15)


def test_add_dp_noise_zero_sigma_noop():
    g = {"w": jnp.ones((3, 5))}
    assert add_dp_noise(g, 0.0, jax.random.PRNGKey(0)) is g


def test_calibrate_tree_sigmas_and_ledger():
    tree = {"w": jnp.zeros((10, 4)), "b": jnp.zeros((2,))}
    sigmas = dp.calibrate_tree_sigmas(tree, n=100, eps=5.0, delta=0.05)
    assert set(sigmas) == set(dp.TREE_TRANSMISSIONS)
    for name in dp.TREE_TRANSMISSIONS:
        assert sigmas[name]["w"] > sigmas[name]["b"]
    ledger = dp.tree_spend_ledger(tree, n=100, eps=5.0, delta=0.05)
    assert len(ledger) == len(dp.TREE_TRANSMISSIONS) * 2
    rec = ledger[0]
    assert {"transmission", "leaf", "dim", "sigma", "eps",
            "delta"} <= set(rec)
    assert rec["eps"] == pytest.approx(1.0)      # eps / 5 per transmission


# ------------------------------------ tree protocol: single-leaf parity

def _toy_problem(m=5, n=12, p=4, key=0):
    k = jax.random.PRNGKey(key)
    X = jax.random.normal(jax.random.fold_in(k, 0), (m, n, p))
    w = jnp.arange(1.0, p + 1)
    y = X @ w + 0.01 * jax.random.normal(jax.random.fold_in(k, 1), (m, n))
    return X, y, w

def test_protocol_tree_single_flat_leaf_byte_parity():
    """{'theta': flat} through the tree engine must be byte-identical to
    the flat array through the same engine — the safety invariant that
    lets one engine serve both the paper head and the model zoo."""
    X, y, _ = _toy_problem()
    cfg = TreeProtocolConfig(hist=3, lr=0.4, eps=2.0)
    theta0 = jnp.zeros(4)
    mask = jnp.arange(5) < 1

    def grad_flat(t, b):
        Xb, yb = b
        r = Xb @ t - yb
        return 0.5 * jnp.mean(r ** 2), Xb.T @ r / Xb.shape[0]

    def grad_tree(t, b):
        loss, g = grad_flat(t["theta"], b)
        return loss, {"theta": g}

    key = jax.random.PRNGKey(42)
    out_flat = protocol_tree_rounds(key, theta0, (X, y), grad_flat, cfg,
                                    byz_mask=mask, attack="scale", n=12)
    out_tree = protocol_tree_rounds(key, {"theta": theta0}, (X, y),
                                    grad_tree, cfg, byz_mask=mask,
                                    attack="scale", n=12)
    for name in ("theta_cq", "theta_os", "theta_qn"):
        a = np.asarray(getattr(out_flat, name))
        b = np.asarray(getattr(out_tree, name)["theta"])
        assert np.array_equal(a, b), name
    assert np.array_equal(np.asarray(out_flat.losses),
                          np.asarray(out_tree.losses))


def test_protocol_tree_trains_multi_leaf_under_attack():
    """The five-transmission engine fits a 2-leaf least-squares model
    through a Byzantine machine + DP noise; memory threads across steps
    and carries curvature."""
    m, n, p = 5, 40, 3
    k = jax.random.PRNGKey(5)
    X = jax.random.normal(jax.random.fold_in(k, 0), (m, n, p))
    w, b0 = jnp.array([1.0, -2.0, 0.5]), 0.7
    y = X @ w + b0

    def grad_fn(t, batch):
        Xb, yb = batch
        r = Xb @ t["w"] + t["b"] - yb
        loss = 0.5 * jnp.mean(r ** 2)
        return loss, {"w": Xb.T @ r / n, "b": jnp.mean(r, keepdims=True)}

    cfg = TreeProtocolConfig(hist=4, lr=0.5, eps=50.0)
    theta = {"w": jnp.zeros(p), "b": jnp.zeros(1)}
    mask = jnp.arange(m) < 1
    key = jax.random.PRNGKey(6)
    losses = []
    step = jax.jit(lambda key, t, mem: protocol_tree_rounds(
        key, t, (X, y), grad_fn, cfg, mem=mem, byz_mask=mask,
        attack="signflip", n=n))
    mem = bfgs.LBFGSMemory.init_like(cfg.hist, theta, machines=m)
    for i in range(25):
        key, sub = jax.random.split(key)
        out = step(sub, theta, mem)
        theta, mem = out.theta_qn, out.mem
        losses.append(float(out.losses.mean()))
    assert losses[-1] < 0.2 * losses[0]
    assert int(mem.count.max()) > 0              # curvature pairs landed


# -------------------------------------------- golden byte parity (smoke)

@pytest.mark.slow
def test_smoke_preset_matches_pre_refactor_golden():
    """The refactored wire path must reproduce the pre-refactor smoke
    artifact BYTE-EXACTLY per key: metrics and per-replicate theta_qn."""
    with open(GOLDEN) as f:
        golden = json.load(f)
    scenarios = build_preset("smoke")
    art = SweepExecutor().run(scenarios, store_thetas=True)
    assert set(art["scenarios"]) == set(golden)
    for sid, want in golden.items():
        got = art["scenarios"][sid]
        assert got["metrics"] == want["metrics"], sid
        assert got["thetas_qn"] == want["thetas_qn"], sid


# ------------------------------------------- zoo scenarios (fast checks)

def test_train_scenario_roundtrip_and_fast_variant():
    from repro.sweep.grid import scenario_from_json
    from repro.sweep.presets import fast_variant, zoo_smoke_scenarios
    s = TrainScenario(arch="glm4-9b", steps=7, eps=5.0, byz_frac=0.25,
                      attack="signflip")
    back = scenario_from_json(json.loads(json.dumps(s.to_json())))
    assert back == s                       # artifact resume round-trip
    assert s.to_json()["kind"] == "train"
    fast = fast_variant([s], reps=2)[0]
    assert fast.steps == 2 and fast.arch == s.arch
    scens = zoo_smoke_scenarios()
    families = {sc.arch for sc in scens}
    assert len(families) == 4              # one reduced config per family
    assert len({sc.scenario_id() for sc in scens}) == len(scens)
    with pytest.raises(ValueError):
        TrainScenario(arch="not-a-model")
    with pytest.raises(ValueError):
        TrainScenario(batch=5, machines=4)


def test_train_launcher_exposes_registry_aggregators():
    """The launcher's ACTUAL parser accepts every registered aggregator
    (qn path included — ``dcq_mad`` is the wire default) and rejects
    typos; both optimizer names parse."""
    from repro.agg import registered
    from repro.launch.train import build_parser
    ap = build_parser()
    for name in registered():
        assert ap.parse_args(["--agg", name]).agg == name
    with pytest.raises(SystemExit):
        ap.parse_args(["--agg", "typo"])
    assert ap.parse_args(["--optimizer", "qn"]).optimizer == "qn"
    assert ap.parse_args(["--config", "glm4-9b"]).arch == "glm4-9b"


# --------------------------------------------- zoo training compile-once

@pytest.mark.slow
def test_zoo_group_compiles_once_and_records_per_leaf_spend():
    """Two DP budgets of one zoo group ride ONE compiled train step
    (sigmas are traced), and the artifact records carry the per-leaf
    spend ledger + the train comm record."""
    common = dict(arch="xlstm-125m", steps=2, batch=4, seq=8, machines=2,
                  aggregator="dcq_mad", attack="signflip", byz_frac=0.5,
                  lr=0.3)
    s1 = TrainScenario(eps=5.0, **common)
    s2 = TrainScenario(eps=50.0, **common)
    assert s1.group_key() == s2.group_key()
    assert s1.scenario_id() != s2.scenario_id()
    ex = SweepExecutor()
    art = ex.run([s1, s2])
    assert ex.trace_counts[s1.group_key()] == 1  # compile-once: 2
    #                                              scenarios x 2 steps
    for s in (s1, s2):
        rec = art["scenarios"][s.scenario_id()]
        assert {"scenario", "metrics", "spend", "comm",
                "timing"} <= set(rec)
        assert rec["scenario"]["kind"] == "train"
        assert len(rec["metrics"]["losses"]) == 2
        assert rec["spend"]["per_leaf"], "per-leaf ledger missing"
        leaves = {r["leaf"] for r in rec["spend"]["per_leaf"]}
        assert len(leaves) > 1                   # one entry per leaf
        assert rec["comm"]["bytes_per_machine"] == \
            5 * rec["comm"]["bytes_per_round"]
    # different budgets -> different per-leaf sigmas in the ledger
    sig1 = art["scenarios"][s1.scenario_id()]["spend"]["sigmas"]
    sig2 = art["scenarios"][s2.scenario_id()]["spend"]["sigmas"]
    assert all(a > b for a, b in zip(sig1, sig2))


# ------------------------------------------------------- example driver

@pytest.mark.slow
def test_robust_llm_training_example_runs():
    path = os.path.join(os.path.dirname(__file__), os.pardir, "examples",
                        "robust_llm_training.py")
    spec = importlib.util.spec_from_file_location("robust_llm_training",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    params, mem, losses = mod.run(steps=2, batch=4, seq=8, machines=2,
                                  aggregator="dcq_mad", attack="signflip",
                                  byz_frac=0.5, log_every=10)
    assert len(losses) == 2
    assert all(np.isfinite(v) for v in losses)
    assert tree_dot(params, params) > 0
