"""Clean pallas usage: static grid from Python ints, interpret threaded
through as a parameter (auto-detected off-TPU by the caller)."""
import jax
from jax.experimental import pallas as pl


def kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def launch(x, tile: int = 128, interpret: bool = False):
    n = x.shape[0]
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(n // tile,),
        interpret=interpret,
    )(x)
