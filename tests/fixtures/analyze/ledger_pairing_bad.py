"""Seeded ledger-pairing violation: DP noise injected with no spend
record anywhere in the caller scope."""
from repro.core.transport import wire_aggregate, wire_noise


def unaccounted_transmission(key, values, sigma):
    noisy = wire_noise(key, values, sigma)   # VIOLATION: no spend record
    return wire_aggregate(noisy, "median")
