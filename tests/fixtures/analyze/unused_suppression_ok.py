"""Clean twin: every waiver earns its keep. One suppression silences a
real key-reuse finding; the other names unused-suppression alongside its
rule, the documented self-waiver for deliberately prophylactic markers."""
import jax


def earned(key):
    a = jax.random.normal(key, (4,))
    # repro: allow(key-reuse) — fixture: deliberate reuse kept for parity.
    b = jax.random.normal(key, (4,))
    return a + b


def prophylactic(key):
    # repro: allow(key-reuse, unused-suppression) — fixture: kept for a
    # platform-dependent path that only reuses the key on some backends.
    return jax.random.normal(key, (4,))
