"""Clean static-argument usage: int tuning knobs and constant float
hyperparameters (one value, one trace) in jitted static slots."""
import functools

import jax
import jax.numpy as jnp


def _kernel(x, tile, beta):
    return jnp.tanh(x) * tile + beta


run = jax.jit(_kernel, static_argnums=(1, 2))


@functools.partial(jax.jit, static_argnames=("tile",))
def launch(x, tile=128):
    return x * tile


def sweep(x, sizes):
    out = []
    for s in sizes:
        out.append(run(x, int(s), 0.2))    # int knob + constant float: fine
        out.append(launch(x, tile=2 * s))  # int expression: fine
    return out
