"""Clean trace discipline: device-side branching inside jit, host casts
only in the un-jitted driver."""
import jax
import jax.numpy as jnp


def pure_step(x):
    x = jnp.where(jnp.mean(x) > 0, x - 1.0, x)
    return x * jnp.max(x)


step = jax.jit(pure_step)


def host_driver(x):
    # NOT jit-reachable: float() on the host side is fine
    return float(jnp.mean(step(x)))
