"""Seeded retrace-hazard violations: float-valued and unhashable
expressions fed into the static-argument slots of jitted callables —
each distinct value is a new compile-cache key (or a TypeError)."""
import functools

import jax
import jax.numpy as jnp


def _kernel(x, tile, beta):
    return jnp.tanh(x) * tile + beta


run = jax.jit(_kernel, static_argnums=(1, 2))


@functools.partial(jax.jit, static_argnames=("tile", "opts"))
def launch(x, tile=128, opts=None):
    return x * tile


def sweep(x, sizes):
    out = []
    for s in sizes:
        out.append(run(x, float(s), 0.2))        # VIOLATION: float(s) static
        out.append(run(x, s * 1.5, 0.2))         # VIOLATION: float expr
        out.append(launch(x, tile=[s, s]))       # VIOLATION: unhashable list
    return out
