"""Suppression round-trip fixture: one violation with a reasoned
suppression (must be silenced and reported as suppressed), one with a
bare marker (must stay active as a 'suppression' finding), one naming an
unknown rule."""
import jax


def allowed_reuse(key):
    a = jax.random.normal(key, (4,))
    # repro: allow(key-reuse) — fixture: deliberate reuse kept for parity.
    b = jax.random.normal(key, (4,))
    return a + b


def bare_marker(key):
    a = jax.random.normal(key, (4,))
    # repro: allow(key-reuse)
    b = jax.random.normal(key, (4,))
    return a + b


def unknown_rule(key):
    # repro: allow(made-up-rule) — no such rule registered.
    return jax.random.normal(key, (4,))
