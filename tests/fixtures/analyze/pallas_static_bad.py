"""Seeded pallas-static violations: traced grid dims and a hardcoded
interpret=True in library-style code."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def launch(x):
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(int(jnp.shape(x)[0]), jnp.argmax(x)),   # VIOLATION: traced dim
        interpret=True,                               # VIOLATION: hardcoded
    )(x)
