"""Clean wire usage: consumers go through the transport primitives."""
from repro.core.transport import wire_aggregate, wire_corrupt


def via_wire(key, values, mask):
    corrupted = wire_corrupt(key, values, mask, attack="scale")
    return wire_aggregate(corrupted, "median")
