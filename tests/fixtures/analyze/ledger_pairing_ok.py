"""Clean ledger pairing: the noise site's module records its spend."""
from repro.core import dp
from repro.core.transport import wire_aggregate, wire_noise


def accounted_transmission(key, values, sigma, acct: dp.PrivacyAccountant):
    noisy = wire_noise(key, values, sigma)
    acct.spend("R1 theta", 1.0, 0.01, float(sigma))
    return wire_aggregate(noisy, "median")
