"""Seeded wire-boundary violations: raw registry dispatch outside the
transport layer."""
from repro import attacks
from repro.agg import aggregate


def raw_aggregate(values):
    return aggregate(values, "median", axis=0)   # VIOLATION


def raw_attack(values, mask, key):
    return attacks.apply_attack(values, mask, "scale", -3.0, key)  # VIOLATION
