"""Seeded unused-suppression violations: reasoned waivers whose rule runs
but never fires here. Both must be flagged as stale — the inline allow on
clean single-use code and the file-wide allow-file whose rule finds
nothing in this module."""
# repro: allow-file(wire-boundary) — VIOLATION: no raw dispatch below.
import jax


def single_use(key):
    # repro: allow(key-reuse) — VIOLATION: the double sample was removed.
    a = jax.random.normal(key, (4,))
    return a
