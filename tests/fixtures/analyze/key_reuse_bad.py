"""Seeded key-reuse violations: a consumed key sampled again, and an
arithmetic seed. The analyzer must flag BOTH sites."""
import jax


def double_sample(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.normal(key, (4,))   # VIOLATION: key already consumed
    return a + b


def arithmetic_seed(seed):
    return jax.random.PRNGKey(1000 + seed)   # VIOLATION: stream collision
