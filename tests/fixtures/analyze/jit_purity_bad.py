"""Seeded jit-purity violations: host syncs and a Python branch on a
traced value inside a jit-reachable function."""
import jax
import jax.numpy as jnp
import numpy as np


def traced_step(x):
    if jnp.mean(x) > 0:          # VIOLATION: Python branch on traced value
        x = x - 1.0
    lr = float(jnp.max(x))       # VIOLATION: host cast under jit
    host = np.asarray(x)         # VIOLATION: numpy sync under jit
    return x * lr + host.sum() + x.sum().item()   # VIOLATION: .item()


step = jax.jit(traced_step)
