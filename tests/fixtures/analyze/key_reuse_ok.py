"""Clean key hygiene: split/fold_in before every consumption, branch-local
consumption, loop-carried splitting. The analyzer must stay silent."""
import jax


def split_then_sample(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (4,))
    b = jax.random.normal(k2, (4,))
    return a + b


def fold_in_stream(key, n):
    total = 0.0
    for i in range(n):
        total = total + jax.random.normal(jax.random.fold_in(key, i), ())
    return total


def branch_exclusive(key, flag):
    if flag:
        return jax.random.normal(key, (2,))
    return jax.random.uniform(key, (2,))


def loop_carried(key, n):
    out = []
    for _ in range(n):
        key, sub = jax.random.split(key)
        out.append(jax.random.normal(sub, ()))
    return out


def indexed_keys(key):
    keys = jax.random.split(key, 4)
    a = jax.random.normal(keys[0], ())
    b = jax.random.normal(keys[1], ())
    return a + b
